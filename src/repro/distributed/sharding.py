"""Sharding rule tables: param/optimizer/batch/cache PartitionSpecs.

Mesh convention (launch.mesh): single-pod ``(16, 16) = ("data", "model")``;
multi-pod ``(2, 16, 16) = ("pod", "data", "model")`` — "pod" composes with
"data" into the DP super-axis for all data-parallel collectives.

Strategy per family (DESIGN.md §4):
* dense/vlm/audio — Megatron TP on "model": QKV/up column-parallel, O/down
  row-parallel, vocab-sharded embedding/head when divisible; DP over
  ("pod","data"); ZeRO-1 optimizer-state sharding over DP.
* moe — experts sharded over "model" (EP); kimi-k2 additionally shards the
  expert FFN dim over "data" (``expert_sharding="2d"`` ⇒ EP×FSDP).
* ssm/hybrid — TP over d_inner/heads for projections; scan is
  sequence-local.
* decode caches — batch over DP; ``global_batch == 1`` (long_500k) shards
  the KV time axis over "data" instead (flash-decoding style); KV heads over
  "model" when divisible, else head_dim, else replicated (MQA).

Every rule is divisibility-guarded: a dim that doesn't divide by its axis
size falls back to replication (recorded per-arch in EXPERIMENTS.md §Dry-run
— e.g. granite/seamless/mamba2 vocab is not 16-divisible).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_name(mesh: Mesh):
    """The DP super-axis as a PartitionSpec entry (tuple iff multi-pod)."""
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _fits(dim: int, mesh: Mesh, name) -> bool:
    return dim % axis_size(mesh, name) == 0


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

COL_PARALLEL = ("wq/w", "wk/w", "wv/w", "up/w", "gate/w", "in_proj/w",
                "lm_head/w")
ROW_PARALLEL = ("wo/w", "down/w", "out_proj/w")
COL_BIAS = ("wq/b", "wk/b", "wv/b", "up/b", "gate/b", "in_proj/b")


def param_spec(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
               cfg) -> P:
    """PartitionSpec for one parameter leaf (leading stack dims replicated)."""
    nd = len(shape)
    spec = [None] * nd

    def last(n=1):
        return nd - n

    if path_str.endswith("embed/w"):
        if _fits(shape[0], mesh, "model"):
            spec[0] = "model"
    elif any(path_str.endswith(s) for s in COL_PARALLEL):
        if _fits(shape[-1], mesh, "model"):
            spec[last()] = "model"
    elif any(path_str.endswith(s) for s in ROW_PARALLEL):
        if _fits(shape[-2], mesh, "model"):
            spec[last(2)] = "model"
    elif any(path_str.endswith(s) for s in COL_BIAS):
        if _fits(shape[-1], mesh, "model"):
            spec[last()] = "model"
    elif path_str.endswith(("w_gate", "w_up")):      # [.., E, H, F]
        if _fits(shape[-3], mesh, "model"):
            spec[last(3)] = "model"
        if getattr(cfg, "expert_sharding", "1d") == "2d" \
                and _fits(shape[-1], mesh, "data"):
            spec[last()] = "data"
    elif path_str.endswith("w_down"):                # [.., E, F, H]
        if _fits(shape[-3], mesh, "model"):
            spec[last(3)] = "model"
        if getattr(cfg, "expert_sharding", "1d") == "2d" \
                and _fits(shape[-2], mesh, "data"):
            spec[last(2)] = "data"
    # conv_w / a_log / d / dt_bias / norms / router / gates → replicated
    return P(*spec)


def params_sharding(param_shapes: Pytree, mesh: Mesh, cfg) -> Pytree:
    def f(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape,
                                              mesh, cfg))
    return jax.tree_util.tree_map_with_path(f, param_shapes)


# ---------------------------------------------------------------------------
# Optimizer-state specs (ZeRO-1 over the DP super-axis)
# ---------------------------------------------------------------------------

def _zero1(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Add the DP axis to the first unsharded, divisible dim (ZeRO-1)."""
    dp = dp_axes(mesh)
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, (d, s) in enumerate(zip(shape, dims)):
        if s is None and d % axis_size(mesh, dp) == 0 and d > 1:
            dims[i] = dp if len(dp) > 1 else dp[0]
            break
    return P(*dims)


def opt_state_sharding(opt_shapes: Pytree, mesh: Mesh, cfg,
                       zero1: bool = True) -> Pytree:
    """Specs for optimizer state.  The state tree embeds param-shaped
    subtrees (m/v for AdamW; factored vr/vc for Adafactor) whose paths END
    with the param path — the same suffix rules apply; then ZeRO-1 adds DP
    sharding."""
    def f(path, leaf):
        ps = _path_str(path)
        spec = param_spec(ps, leaf.shape, mesh, cfg)
        if zero1 and leaf.ndim >= 1 and "step" not in ps:
            spec = _zero1(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, opt_shapes)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_sharding(batch_shapes: Pytree, mesh: Mesh) -> Pytree:
    """Train/prefill batches: leading batch dim over DP."""
    dp = dp_axes(mesh)
    dpn = dp_name(mesh)

    def f(leaf):
        spec = [None] * leaf.ndim
        if leaf.shape and leaf.shape[0] % axis_size(mesh, dp) == 0:
            spec[0] = dpn
        elif leaf.ndim >= 2 and leaf.shape[0] == 1 \
                and leaf.shape[1] % axis_size(mesh, dp) == 0:
            spec[1] = dpn                # batch-1 long context: shard S
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(f, batch_shapes)


def cache_pspec(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                seq_shard: bool = True) -> P:
    """PartitionSpec for one decode-cache leaf.  Leaf patterns (by dict key):
    * k/v:   [..., B, T, kvh, hd] — B→DP (or T→"data" when B==1),
             kvh→"model" (else hd→"model", else replicated),
    * k_u/v_u:   [..., B, T, r]   — B→DP (or T→"data" when B==1); the time
             axis stays model-REPLICATED (§Perf C3, refuted: sharded-softmax
             all-reduces of the [B,kvh,g,T] scores cost 2× the saved reads),
    * k_vt/v_vt: [..., B, r, kvw] — B→DP, kvw→"model",
    * conv:  [..., B, W, ch]      — B→DP, ch→"model",
    * ssm:   [..., B, nh, hd, ds] — B→DP, nh→"model",
    * k_u_pages/v_u_pages: [..., P, page, r] — REPLICATED: pages are
             shared across slots (prefix reuse), so the page axis must
             not follow the DP slot sharding — any slot on any device may
             gather any page,
    * k_pages/v_pages: [..., TP, page, kvh, hd] — page axis replicated
             (same reason), kvh→"model" (else hd→"model") like k/v.

    ``seq_shard=False`` disables the B==1 time-axis ("flash-decoding")
    branch: it belongs to global-batch-1 long-context DECODE caches, not
    to a serving engine's freshly prefilled single-request cache, which
    must stay replicated until it is spliced into the slot-sharded live
    cache.

    Shape-only (works on ShapeDtypeStructs AND traced arrays, so the same
    rules serve ``cache_sharding`` device placement and the
    ``with_sharding_constraint`` calls inside the serving engine's jitted
    step fns).
    """
    dpn = dp_name(mesh)
    dp_sz = axis_size(mesh, dp_axes(mesh))
    leaf_name = path_str.rsplit("/", 1)[-1]
    nd = len(shape)
    spec = [None] * nd
    if leaf_name in ("k", "v"):
        b_dim, t_dim, kvh_dim, hd_dim = nd - 4, nd - 3, nd - 2, nd - 1
        if shape[b_dim] % dp_sz == 0 and shape[b_dim] > 1:
            spec[b_dim] = dpn
        elif seq_shard and shape[b_dim] == 1 \
                and shape[t_dim] % mesh.shape["data"] == 0:
            spec[t_dim] = "data"     # sequence-sharded KV
        if _fits(shape[kvh_dim], mesh, "model") \
                and shape[kvh_dim] > 1:
            spec[kvh_dim] = "model"
        elif _fits(shape[hd_dim], mesh, "model"):
            spec[hd_dim] = "model"
    elif leaf_name in ("k_u", "v_u"):      # [.., B, T, r]
        b_dim, t_dim = nd - 3, nd - 2
        if shape[b_dim] % dp_sz == 0 and shape[b_dim] > 1:
            spec[b_dim] = dpn
        elif seq_shard and shape[b_dim] == 1 \
                and shape[t_dim] % mesh.shape["data"] == 0:
            spec[t_dim] = "data"
        # NOTE (§Perf C3, refuted): sharding U's time axis over
        # "model" cuts U reads ~17% but the sharded-softmax
        # all-reduces of the [B,kvh,g,T] scores cost 2x more than the
        # saving — U stays model-replicated.
    elif leaf_name in ("k_vt", "v_vt"):    # [.., B, r, kvw]
        b_dim, w_dim = nd - 3, nd - 1
        if shape[b_dim] % dp_sz == 0 and shape[b_dim] > 1:
            spec[b_dim] = dpn
        if _fits(shape[w_dim], mesh, "model"):
            spec[w_dim] = "model"
    elif leaf_name in ("k_u_pages", "v_u_pages"):   # [.., P, page, r]
        pass                     # pool pages replicated (shared via refs)
    elif leaf_name in ("k_pages", "v_pages"):   # [.., TP, page, kvh, hd]
        kvh_dim, hd_dim = nd - 2, nd - 1
        if _fits(shape[kvh_dim], mesh, "model") and shape[kvh_dim] > 1:
            spec[kvh_dim] = "model"
        elif _fits(shape[hd_dim], mesh, "model"):
            spec[hd_dim] = "model"
    elif leaf_name == "conv":
        b_dim, ch_dim = nd - 3, nd - 1
        if shape[b_dim] % dp_sz == 0 and shape[b_dim] > 1:
            spec[b_dim] = dpn
        if _fits(shape[ch_dim], mesh, "model"):
            spec[ch_dim] = "model"
    elif leaf_name == "ssm":
        b_dim, nh_dim = nd - 4, nd - 3
        if shape[b_dim] % dp_sz == 0 and shape[b_dim] > 1:
            spec[b_dim] = dpn
        if _fits(shape[nh_dim], mesh, "model"):
            spec[nh_dim] = "model"
    return P(*spec)


def cache_sharding(cache_shapes: Pytree, mesh: Mesh, cfg,
                   seq_shard: bool = True) -> Pytree:
    """NamedSharding per decode-cache leaf (rules: :func:`cache_pspec`)."""
    del cfg                              # rules are shape/name-driven
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(_path_str(path), leaf.shape, mesh,
                              seq_shard=seq_shard)),
        cache_shapes)


def constrain_cache(cache: Pytree, mesh: Optional[Mesh],
                    seq_shard: bool = True) -> Pytree:
    """``with_sharding_constraint`` every cache leaf to its
    :func:`cache_pspec` — used INSIDE the serving engine's jitted step
    functions so GSPMD keeps splice/fold/decode device-local along the
    sharded batch axis.  No-op when ``mesh`` is None."""
    if mesh is None:
        return cache
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh,
                                cache_pspec(_path_str(path), leaf.shape,
                                            mesh, seq_shard=seq_shard))),
        cache)


def token_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    dp = dp_axes(mesh)
    if batch % axis_size(mesh, dp) == 0 and batch > 1:
        return NamedSharding(mesh, P(dp_name(mesh)))
    return NamedSharding(mesh, P(None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
