"""Low-rank gradient compression (PowerSGD-style) with error feedback.

The distributed-optimization tie-in to the paper: gradient matrices are
activations of the communication channel, and the SAME progressive low-rank
machinery D-com builds for activations (subspace iteration / Lanczos-family
methods, ``core.svd_alt.qr_iteration_svd`` is one power step with QR) makes
the DP all-reduce payload rank-r instead of dense.

Protocol per 2-D-reshapeable gradient G [m, n] (1-D tensors stay dense):
  1. G ← G + E (error feedback memory)
  2. P = G Q;  all-reduce(P);  P ← orthonormalize(P)      [one power step]
  3. Q' = Gᵀ P;  all-reduce(Q')
  4. Ĝ = P Q'ᵀ;  E ← G − Ĝ;  emit Ĝ
Under pjit the all-reduces are implicit (GSPMD inserts them for the
DP-sharded batch dim); this module supplies the compress/decompress math
and the error-feedback state so ``runtime.steps`` can wire it as a
``grad_transform``.  Compression ratio per matrix: (m·n)/(r·(m+n)).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _path_seed(path) -> int:
    """Stable per-leaf fold-in seed from the tree path.

    MUST be process-invariant: every DP worker (its own Python process,
    its own PYTHONHASHSEED) has to draw the SAME initial Q or the implicit
    all-reduces of P/Q' average projections taken in different subspaces —
    silently wrong gradients and no run-to-run reproducibility.  Python's
    ``hash(str)`` is salted per process, so we digest with ``zlib.crc32``
    instead (tests/test_compression.py runs the cross-process regression).
    """
    return zlib.crc32(str(path).encode("utf-8")) % (2 ** 31)


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_elems: int = 65_536       # don't compress tiny tensors
    seed: int = 17


def _reshape2d(g: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    shape = g.shape
    if g.ndim == 1:
        return g[None, :], shape
    m = 1
    for d in shape[:-1]:
        m *= d
    return g.reshape(m, shape[-1]), shape


def _orthonormalize(p: jax.Array) -> jax.Array:
    q, _ = jnp.linalg.qr(p)
    return q


def init_state(params: Pytree, cfg: PowerSGDConfig) -> Pytree:
    """Error-feedback memory (zeros, fp32) + fixed random Q per leaf."""
    def one(path, p):
        if p.size < cfg.min_elems or p.ndim < 2:
            return {"e": None, "q": None}
        g2, _ = _reshape2d(jnp.zeros(p.shape, jnp.float32))
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                 _path_seed(path))
        q = jax.random.normal(key, (g2.shape[1], cfg.rank), jnp.float32)
        return {"e": jnp.zeros(p.shape, jnp.float32), "q": q}
    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: hasattr(x, "shape"))


def compress_decompress(grads: Pytree, state: Pytree, cfg: PowerSGDConfig
                        ) -> Tuple[Pytree, Pytree]:
    """Apply PowerSGD round-trip (what the receiver would see) + new state."""
    def one(g, st):
        if st["e"] is None:
            return g, st
        g32 = g.astype(jnp.float32) + st["e"]
        g2, shape = _reshape2d(g32)
        p = _orthonormalize(g2 @ st["q"])         # [m, r] (all-reduced in DP)
        q_new = g2.T @ p                           # [n, r] (all-reduced in DP)
        approx = (p @ q_new.T).reshape(shape)
        err = g32 - approx
        return approx.astype(g.dtype), {"e": err, "q": q_new}
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_s = tdef.flatten_up_to(state)
    out = [one(g, s) for g, s in zip(flat_g, flat_s)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def compression_ratio(params: Pytree, cfg: PowerSGDConfig) -> float:
    """Dense bytes / compressed bytes over the whole gradient pytree."""
    dense = comp = 0
    for p in jax.tree_util.tree_leaves(params):
        n = p.size
        dense += n
        if n < cfg.min_elems or p.ndim < 2:
            comp += n
        else:
            g2, _ = _reshape2d(jnp.zeros(p.shape, jnp.bool_))
            comp += cfg.rank * (g2.shape[0] + g2.shape[1])
    return dense / comp
