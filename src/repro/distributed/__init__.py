"""Distribution layer: sharding rule tables, gradient compression,
collective helpers."""
from .sharding import (batch_sharding, cache_sharding, dp_axes,
                       opt_state_sharding, param_spec, params_sharding,
                       replicated, token_sharding)
