"""Distribution layer: sharding rule tables, gradient compression,
collective helpers."""
from .sharding import (batch_sharding, cache_pspec, cache_sharding,
                       constrain_cache, dp_axes, dp_name,
                       opt_state_sharding, param_spec, params_sharding,
                       replicated, token_sharding)
