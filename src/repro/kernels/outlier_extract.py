"""Channel-wise outlier statistics kernel (paper §4).

Produces, per channel h of the activation X[S, H]:
  * ``count[h]``  — number of elements with |x| > T,
  * ``maxabs[h]`` — channel max |x| (the selection tiebreak).

One streaming pass over X: grid = (H-blocks, f) with the S reduction
expanded f ways; counts/max accumulate in the revisited output block.  The
top-C selection and gather/scatter stay outside the kernel (jnp.top_k /
take) — they touch only C ≈ 0.03·H channels and are not a bottleneck, which
is exactly why the paper chose channel granularity.
"""
from __future__ import annotations

import functools
from typing import Optional


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..engine.platform import resolve_interpret


def _outlier_kernel(x_ref, t_ref, cnt_ref, mx_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        mx_ref[...] = jnp.zeros_like(mx_ref)

    a = jnp.abs(x_ref[...].astype(jnp.float32))          # (Sb, Hb)
    t = t_ref[0, 0]
    cnt_ref[...] += jnp.sum((a > t).astype(jnp.float32), axis=0)[None, :]
    mx_ref[...] = jnp.maximum(mx_ref[...], jnp.max(a, axis=0)[None, :])


@functools.partial(jax.jit, static_argnames=("expansion", "col_block",
                                             "interpret"))
def outlier_stats(x: jax.Array, threshold: jax.Array, *, expansion: int = 8,
                  col_block: int = 512, interpret: Optional[bool] = None):
    """(counts[H] float32, maxabs[H] float32) for |x| > threshold."""
    interpret = resolve_interpret(interpret)
    s_dim, h_dim = x.shape
    assert s_dim % expansion == 0
    blk = s_dim // expansion
    cb = min(col_block, h_dim)
    assert h_dim % cb == 0

    t = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    cnt, mx = pl.pallas_call(
        _outlier_kernel,
        grid=(h_dim // cb, expansion),
        in_specs=[
            pl.BlockSpec((blk, cb), lambda i, j: (j, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cb), lambda i, j: (0, i)),
            pl.BlockSpec((1, cb), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, h_dim), jnp.float32),
            jax.ShapeDtypeStruct((1, h_dim), jnp.float32),
        ],
        interpret=interpret,
    )(x, t)
    return cnt[0], mx[0]
