"""Fused Lanczos re-orthogonalization step — the D-com kernel (paper §5.3).

The latency bottleneck of Lanczos bidiagonalization is the inner-loop
re-orthogonalization (paper Fig. 3): a chain of

    matvec  →  global reduce (Qᵀz)  →  broadcast  →  axpy (z − Q·p)   × 2

which is memory-bound on a GPU/TPU vector unit.  The paper's *Computation
Expansion* replicates the element-wise work across ``f`` partial blocks so
the one long global reduction becomes ``f`` short local reductions plus a
tiny global combine (Fig. 9c).

TPU-native mapping (see DESIGN.md §2): the expansion factor ``f`` is the
Pallas **grid size along the reduction dimension**.  Each grid step owns a
VMEM-resident tile (the paper's per-cluster buffer) and computes

  pass 0:  z_j   = (Aᵀu)_j            and accumulates p1 += Q_jᵀ z_j
  pass 1:  z'_j  = z_j − Q_j p1       and accumulates p2 += Q_jᵀ z'_j
  pass 2:  z''_j = z'_j − Q_j p2      and accumulates ‖z''‖² partials

The p1/p2/nrm accumulators are tiny [1, k] / [1, 1] VMEM scratch — the
paper's "small global memory for broadcast purposes".  The z intermediate
lives in a full-length VMEM scratch so A is streamed from HBM exactly once
per pass (3× total; the unfused chain reads A once but re-reads z/Q five
times from HBM — at k ≥ 16 columns of Q the fused version moves less data,
and all reductions are VMEM-local).

Two symmetric variants:
* ``right``: z = CGS2(Aᵀu, V) — output over columns of A (length H),
* ``left`` : w = CGS2(A v, U) — output over rows of A (length S).

Both are validated against ``ref.py`` in interpret mode; on hardware the
MXU handles the [blk, k] projections and the VPU the element-wise tail.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..engine.platform import resolve_interpret


def _reorth_right_kernel(a_ref, u_ref, q_ref, z_out, nrm_out,
                         z_buf, p1, p2, nrm, *, f: int, blk: int):
    """grid = (3 passes, f column-blocks). A block (S, blk); Q block (blk, k)."""
    p = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((p == 0) & (j == 0))
    def _init():
        p1[...] = jnp.zeros_like(p1)
        p2[...] = jnp.zeros_like(p2)
        nrm[...] = jnp.zeros_like(nrm)

    q = q_ref[...].astype(jnp.float32)            # (blk, k)

    @pl.when(p == 0)
    def _pass0():
        a = a_ref[...].astype(jnp.float32)        # (S, blk)
        u = u_ref[...].astype(jnp.float32)        # (S, 1)
        z = jnp.sum(a * u, axis=0)[None, :]       # (1, blk) — local reduce
        pl.store(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)), z)
        p1[...] += jnp.dot(z, q, preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _pass1():
        z = pl.load(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)))
        z = z - jnp.dot(p1[...], q.T, preferred_element_type=jnp.float32)
        pl.store(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)), z)
        p2[...] += jnp.dot(z, q, preferred_element_type=jnp.float32)

    @pl.when(p == 2)
    def _pass2():
        z = pl.load(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)))
        z = z - jnp.dot(p2[...], q.T, preferred_element_type=jnp.float32)
        z_out[...] = z
        nrm[...] += jnp.sum(z * z)

    # nrm_out is revisited every step; the final write wins.
    @pl.when((p == 2) & (j == f - 1))
    def _fin():
        nrm_out[...] = nrm[...]


def _reorth_left_kernel(a_ref, v_ref, q_ref, z_out, nrm_out,
                        z_buf, p1, p2, nrm, *, f: int, blk: int):
    """grid = (3 passes, f row-blocks). A block (blk, H); Q block (blk, k)."""
    p = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((p == 0) & (j == 0))
    def _init():
        p1[...] = jnp.zeros_like(p1)
        p2[...] = jnp.zeros_like(p2)
        nrm[...] = jnp.zeros_like(nrm)

    q = q_ref[...].astype(jnp.float32)            # (blk, k)

    @pl.when(p == 0)
    def _pass0():
        a = a_ref[...].astype(jnp.float32)        # (blk, H)
        v = v_ref[...].astype(jnp.float32)        # (1, H)
        z = jnp.sum(a * v, axis=1)[:, None]       # (blk, 1) — local reduce
        pl.store(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)), z)
        p1[...] += jnp.dot(z.T, q, preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _pass1():
        z = pl.load(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)))
        z = z - jnp.dot(q, p1[...].T, preferred_element_type=jnp.float32)
        pl.store(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)), z)
        p2[...] += jnp.dot(z.T, q, preferred_element_type=jnp.float32)

    @pl.when(p == 2)
    def _pass2():
        z = pl.load(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)))
        z = z - jnp.dot(q, p2[...].T, preferred_element_type=jnp.float32)
        z_out[...] = z
        nrm[...] += jnp.sum(z * z)

    @pl.when((p == 2) & (j == f - 1))
    def _fin():
        nrm_out[...] = nrm[...]


def _reorth_right_batched_kernel(a_ref, u_ref, q_ref, z_out, nrm_out,
                                 z_buf, p1, p2, nrm, *, f: int, blk: int):
    """grid = (B, 3 passes, f column-blocks) — batch is the OUTERMOST grid
    dim, so one launch covers every prompt and the per-pass scratch
    (z_buf/p1/p2/nrm) is simply re-initialized as each batch element's
    pass 0 begins."""
    p = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((p == 0) & (j == 0))
    def _init():
        p1[...] = jnp.zeros_like(p1)
        p2[...] = jnp.zeros_like(p2)
        nrm[...] = jnp.zeros_like(nrm)

    q = q_ref[0].astype(jnp.float32)              # (blk, k)

    @pl.when(p == 0)
    def _pass0():
        a = a_ref[0].astype(jnp.float32)          # (S, blk)
        u = u_ref[0].astype(jnp.float32)          # (S, 1)
        z = jnp.sum(a * u, axis=0)[None, :]       # (1, blk) — local reduce
        pl.store(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)), z)
        p1[...] += jnp.dot(z, q, preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _pass1():
        z = pl.load(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)))
        z = z - jnp.dot(p1[...], q.T, preferred_element_type=jnp.float32)
        pl.store(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)), z)
        p2[...] += jnp.dot(z, q, preferred_element_type=jnp.float32)

    @pl.when(p == 2)
    def _pass2():
        z = pl.load(z_buf, (pl.dslice(0, 1), pl.dslice(j * blk, blk)))
        z = z - jnp.dot(p2[...], q.T, preferred_element_type=jnp.float32)
        z_out[0] = z
        nrm[...] += jnp.sum(z * z)

    @pl.when((p == 2) & (j == f - 1))
    def _fin():
        nrm_out[0] = nrm[...]


def _reorth_left_batched_kernel(a_ref, v_ref, q_ref, z_out, nrm_out,
                                z_buf, p1, p2, nrm, *, f: int, blk: int):
    """grid = (B, 3 passes, f row-blocks) — batched twin of the left step."""
    p = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((p == 0) & (j == 0))
    def _init():
        p1[...] = jnp.zeros_like(p1)
        p2[...] = jnp.zeros_like(p2)
        nrm[...] = jnp.zeros_like(nrm)

    q = q_ref[0].astype(jnp.float32)              # (blk, k)

    @pl.when(p == 0)
    def _pass0():
        a = a_ref[0].astype(jnp.float32)          # (blk, H)
        v = v_ref[0].astype(jnp.float32)          # (1, H)
        z = jnp.sum(a * v, axis=1)[:, None]       # (blk, 1) — local reduce
        pl.store(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)), z)
        p1[...] += jnp.dot(z.T, q, preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _pass1():
        z = pl.load(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)))
        z = z - jnp.dot(q, p1[...].T, preferred_element_type=jnp.float32)
        pl.store(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)), z)
        p2[...] += jnp.dot(z.T, q, preferred_element_type=jnp.float32)

    @pl.when(p == 2)
    def _pass2():
        z = pl.load(z_buf, (pl.dslice(j * blk, blk), pl.dslice(0, 1)))
        z = z - jnp.dot(q, p2[...].T, preferred_element_type=jnp.float32)
        z_out[0] = z
        nrm[...] += jnp.sum(z * z)

    @pl.when((p == 2) & (j == f - 1))
    def _fin():
        nrm_out[0] = nrm[...]


@functools.partial(jax.jit,
                   static_argnames=("expansion", "interpret"))
def reorth_right_batched(a: jax.Array, u: jax.Array, v_buf: jax.Array,
                         *, expansion: int = 8,
                         interpret: Optional[bool] = None):
    """Batched fused  z_b = CGS2(A_bᵀ·u_b, V_b)  → (z [B, H], ‖z‖² [B]).

    ONE pallas_call for the whole batch: grid (B, 3, f).  H must divide by
    ``expansion``.
    """
    interpret = resolve_interpret(interpret)
    b_dim, s_dim, h_dim = a.shape
    k = v_buf.shape[-1]
    assert h_dim % expansion == 0, (h_dim, expansion)
    blk = h_dim // expansion
    f = expansion

    z, nrm = pl.pallas_call(
        functools.partial(_reorth_right_batched_kernel, f=f, blk=blk),
        grid=(b_dim, 3, f),
        in_specs=[
            pl.BlockSpec((1, s_dim, blk), lambda b, p, j: (b, 0, j)),
            pl.BlockSpec((1, s_dim, 1), lambda b, p, j: (b, 0, 0)),
            pl.BlockSpec((1, blk, k), lambda b, p, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk), lambda b, p, j: (b, 0, j)),
            pl.BlockSpec((1, 1, 1), lambda b, p, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, 1, h_dim), jnp.float32),
            jax.ShapeDtypeStruct((b_dim, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, h_dim), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, u[..., None], v_buf)
    return z[:, 0], nrm[:, 0, 0]


@functools.partial(jax.jit,
                   static_argnames=("expansion", "interpret"))
def reorth_left_batched(a: jax.Array, v: jax.Array, u_buf: jax.Array,
                        *, expansion: int = 8,
                         interpret: Optional[bool] = None):
    """Batched fused  w_b = CGS2(A_b·v_b, U_b)  → (w [B, S], ‖w‖² [B]).
    S % expansion == 0."""
    interpret = resolve_interpret(interpret)
    b_dim, s_dim, h_dim = a.shape
    k = u_buf.shape[-1]
    assert s_dim % expansion == 0, (s_dim, expansion)
    blk = s_dim // expansion
    f = expansion

    z, nrm = pl.pallas_call(
        functools.partial(_reorth_left_batched_kernel, f=f, blk=blk),
        grid=(b_dim, 3, f),
        in_specs=[
            pl.BlockSpec((1, blk, h_dim), lambda b, p, j: (b, j, 0)),
            pl.BlockSpec((1, 1, h_dim), lambda b, p, j: (b, 0, 0)),
            pl.BlockSpec((1, blk, k), lambda b, p, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, 1), lambda b, p, j: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, p, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, s_dim, 1), jnp.float32),
            jax.ShapeDtypeStruct((b_dim, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((s_dim, 1), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, v[:, None, :], u_buf)
    return z[..., 0], nrm[:, 0, 0]


@functools.partial(jax.jit,
                   static_argnames=("expansion", "interpret"))
def reorth_right(a: jax.Array, u: jax.Array, v_buf: jax.Array,
                 *, expansion: int = 8,
                         interpret: Optional[bool] = None):
    """Fused  z = CGS2(Aᵀ·u, V)  → (z [H], ‖z‖² scalar).

    ``expansion`` is the paper's f: the number of column-blocks the
    reduction is expanded over.  H must divide by ``expansion``.
    """
    interpret = resolve_interpret(interpret)
    s_dim, h_dim = a.shape
    k = v_buf.shape[-1]
    assert h_dim % expansion == 0, (h_dim, expansion)
    blk = h_dim // expansion
    f = expansion

    z, nrm = pl.pallas_call(
        functools.partial(_reorth_right_kernel, f=f, blk=blk),
        grid=(3, f),
        in_specs=[
            pl.BlockSpec((s_dim, blk), lambda p, j: (0, j)),   # A columns
            pl.BlockSpec((s_dim, 1), lambda p, j: (0, 0)),     # u
            pl.BlockSpec((blk, k), lambda p, j: (j, 0)),       # V rows
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda p, j: (0, j)),       # z
            pl.BlockSpec((1, 1), lambda p, j: (0, 0)),         # ‖z‖²
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, h_dim), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, h_dim), jnp.float32),               # z intermediate
            pltpu.VMEM((1, k), jnp.float32),                   # p1 = Qᵀz
            pltpu.VMEM((1, k), jnp.float32),                   # p2
            pltpu.VMEM((1, 1), jnp.float32),                   # norm acc
        ],
        interpret=interpret,
    )(a, u[:, None], v_buf)
    return z[0], nrm[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("expansion", "interpret"))
def reorth_left(a: jax.Array, v: jax.Array, u_buf: jax.Array,
                *, expansion: int = 8,
                         interpret: Optional[bool] = None):
    """Fused  w = CGS2(A·v, U)  → (w [S], ‖w‖² scalar).  S % expansion == 0."""
    interpret = resolve_interpret(interpret)
    s_dim, h_dim = a.shape
    k = u_buf.shape[-1]
    assert s_dim % expansion == 0, (s_dim, expansion)
    blk = s_dim // expansion
    f = expansion

    z, nrm = pl.pallas_call(
        functools.partial(_reorth_left_kernel, f=f, blk=blk),
        grid=(3, f),
        in_specs=[
            pl.BlockSpec((blk, h_dim), lambda p, j: (j, 0)),   # A rows
            pl.BlockSpec((1, h_dim), lambda p, j: (0, 0)),     # v
            pl.BlockSpec((blk, k), lambda p, j: (j, 0)),       # U rows
        ],
        out_specs=[
            pl.BlockSpec((blk, 1), lambda p, j: (j, 0)),       # w
            pl.BlockSpec((1, 1), lambda p, j: (0, 0)),         # ‖w‖²
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((s_dim, 1), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, v[None, :], u_buf)
    return z[:, 0], nrm[0, 0]


# -- tunable space (see repro.tune): the decomposition operating point ------
# ``backend`` selects the execution substrate (engine.backends registry);
# ``reorth`` declares the re-orthogonalization cadence — CGS2 is the only
# implemented point today, registered so the axis is tunable the day a
# cheaper cadence lands.
from ..tune.space import (EXPANSION_GRID, TunableParam,  # noqa: E402
                          TunableSpace, register_space)

register_space(TunableSpace("lanczos_reorth", (
    TunableParam("expansion", EXPANSION_GRID, default=8),
    TunableParam("backend", ("reference", "pallas_interpret", "pallas",
                             "pallas_vmap"), default="reference"),
    TunableParam("reorth", ("cgs2",), default="cgs2"),
)))
