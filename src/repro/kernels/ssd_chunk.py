"""Fused intra-chunk SSD kernel (mamba2 hot-spot; flagged in models/mamba2).

The pure-JAX chunked SSD materializes the masked decay tensor
``M[b,c,q,s,n] = (C_q·B_s)·exp(l_q−l_s)·dt_s`` — the measured memory
hot-spot of the mamba2/zamba2 train cells (EXPERIMENTS.md §Perf bonus:
chunk-size U-shape).  This kernel fuses mask, decay, gating and the
``M @ X`` contraction per (chunk, head-block) grid cell so M lives only as
a [Q, Q] VMEM tile per head — HBM sees inputs and the [Q, hd] output
exactly once.

Grid = (batch·chunks, head-blocks); per cell:
    cb    [Q, Q]   = C_chunk · B_chunkᵀ          (precomputed outside: it is
                                                  head-independent)
    l, dt [Q, nhb] running log-decay / step size for the head block
    x     [Q, nhb·hd] chunk inputs
    y     [Q, nhb·hd] = Σ_s tril(cb · exp(l_q − l_s) · dt_s) x_s

The inter-chunk state recurrence stays outside (tiny, sequential).
"""
from __future__ import annotations

import functools
from typing import Optional


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..engine.platform import resolve_interpret


def _ssd_chunk_kernel(cb_ref, l_ref, dt_ref, x_ref, y_ref, *, q: int,
                      nhb: int, hd: int):
    cb = cb_ref[0].astype(jnp.float32)                # [Q, Q]
    l = l_ref[0].astype(jnp.float32)                  # [Q, nhb]
    dt = dt_ref[0].astype(jnp.float32)                # [Q, nhb]
    x = x_ref[0].astype(jnp.float32)                  # [Q, nhb·hd]

    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = row >= col

    y = jnp.zeros((q, nhb * hd), jnp.float32)
    for n in range(nhb):                              # nhb is small (static)
        decay = jnp.exp(l[:, n][:, None] - l[:, n][None, :])
        m = jnp.where(tril, cb * decay * dt[:, n][None, :], 0.0)  # [Q, Q]
        xn = x[:, n * hd:(n + 1) * hd]                # [Q, hd]
        y = y.at[:, n * hd:(n + 1) * hd].set(
            jnp.dot(m, xn, preferred_element_type=jnp.float32))
    y_ref[0] = y


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_chunk_intra(cb: jax.Array, l: jax.Array, dt: jax.Array,
                    x: jax.Array, *, head_block: int = 4,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Intra-chunk SSD term, fused.

    cb [G, Q, Q] (G = batch·chunks), l/dt [G, Q, nh], x [G, Q, nh, hd]
    → y [G, Q, nh, hd].  nh % head_block == 0.
    """
    interpret = resolve_interpret(interpret)
    g, q, nh = l.shape
    hd = x.shape[-1]
    assert nh % head_block == 0, (nh, head_block)
    nblk = nh // head_block
    xf = x.reshape(g, q, nh * hd)

    y = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, q=q, nhb=head_block, hd=hd),
        grid=(g, nblk),
        in_specs=[
            pl.BlockSpec((1, q, q), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, head_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, head_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, head_block * hd), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, q, head_block * hd),
                               lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((g, q, nh * hd), jnp.float32),
        interpret=interpret,
    )(cb, l, dt, xf)
    return y.reshape(g, q, nh, hd)
