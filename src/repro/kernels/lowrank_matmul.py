"""Preserved-compute GEMM  Vᵀ*[k, N] = Vᵀ[k, H] @ W[H, N]  (paper Eq. 6).

The preserved matmul has a *skinny* left operand (k ≤ 32 rows): arithmetic
intensity is ~k FLOPs/byte of W, so for small ranks it is memory-bound on W
exactly like the Lanczos vector chain.  The same expansion treatment
applies: the H reduction is split into ``f`` VMEM-resident blocks streamed
while the previous block multiplies on the MXU; N is tiled independently so
W is read exactly once.

Block shapes are MXU-friendly: the k dimension is zero-padded to a multiple
of 8 sublanes by the wrapper; H/N blocks default to 512/512 (fp32: 8 VMEM
tiles each).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..engine.platform import resolve_interpret
from .matvec_expand import _block_divisor


def _lr_matmul_kernel(vt_ref, w_ref, o_ref):
    """grid = (N-blocks, f) — H reduction sequential in the last dim."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(vt_ref[...].astype(jnp.float32),
                          w_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("expansion", "n_block",
                                             "interpret"))
def lowrank_matmul(vt: jax.Array, w: jax.Array, *, expansion: int = 8,
                   n_block: int = 512, interpret: Optional[bool] = None
                   ) -> jax.Array:
    """Vᵀ[k,H] @ W[H,N] → [k,N] with f-way expanded H reduction."""
    interpret = resolve_interpret(interpret)
    k, h_dim = vt.shape
    h2, n_dim = w.shape
    assert h_dim == h2
    assert h_dim % expansion == 0
    blk = h_dim // expansion
    nb = _block_divisor(n_dim, n_block)

    # Pad k to a sublane multiple so the MXU tile is well-formed.
    k_pad = max(8, (k + 7) // 8 * 8)
    if k_pad != k:
        vt = jnp.pad(vt, ((0, k_pad - k), (0, 0)))

    out = pl.pallas_call(
        _lr_matmul_kernel,
        grid=(n_dim // nb, expansion),
        in_specs=[
            pl.BlockSpec((k_pad, blk), lambda i, j: (0, j)),
            pl.BlockSpec((blk, nb), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((k_pad, nb), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k_pad, n_dim), jnp.float32),
        interpret=interpret,
    )(vt, w)
    return out[:k]


# -- tunable space (see repro.tune): the Eq. 6 GEMM operating point ---------
from ..tune.space import (BLOCK_GRID, EXPANSION_GRID,  # noqa: E402
                          TunableParam, TunableSpace, register_space)

register_space(TunableSpace("lowrank_matmul", (
    TunableParam("expansion", EXPANSION_GRID, default=8),
    TunableParam("n_block", BLOCK_GRID, default=512),
)))
