"""Pallas TPU kernels for the D-com decomposer (validated interpret=True).

Kernels (one module each, ``ops`` wraps, ``ref`` is the jnp oracle):
* ``lanczos_reorth``  — fused matvec+CGS2 re-orthogonalization (paper Fig. 9)
* ``matvec_expand``   — expanded-reduction matvec (paper Fig. 12 primitive)
* ``lowrank_matmul``  — preserved-compute skinny GEMM (paper Eq. 6)
* ``outlier_extract`` — channel outlier statistics pass (paper §4)
* ``dkv_attention``   — flash-decoding through low-rank KV factors
                        (beyond-paper, EXPERIMENTS.md §Perf cell C)
* ``ssd_chunk``       — fused mamba2 intra-chunk SSD (decay tensor stays
                        in VMEM; beyond-paper, §Perf bonus)
"""
from . import ops, ref
from . import (dkv_attention, lanczos_reorth, lowrank_matmul, matvec_expand,
               outlier_extract, ssd_chunk)
