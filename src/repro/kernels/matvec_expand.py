"""Expanded matvec — the microbenchmark kernel behind paper Fig. 12.

A plain  y = A·v  (or  z = Aᵀ·u) is the memory-bound primitive inside every
Lanczos iteration.  *Computation Expansion* splits the long reduction into
``f`` partial blocks: each grid step reduces one block locally in VMEM and
accumulates into the output ref; XLA/Mosaic double-buffers the block DMAs so
block ``j+1`` streams from HBM while block ``j`` computes — the TPU analogue
of giving every replicated compute unit its own memory bank.

``f`` (the number of reduction blocks) trades VMEM footprint against
pipeline depth exactly like the paper's expansion factor: f too small ⇒ one
giant block, no overlap (memory-bound, Fig. 12 left); f too large ⇒ tiny
blocks whose fixed per-step cost dominates (Fig. 12 right).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..engine.platform import resolve_interpret


def _matvec_kernel(a_ref, v_ref, y_ref):
    """grid = (S-blocks, f) — reduction over H is the (sequential) last dim."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[...].astype(jnp.float32)             # (Sb, Hb)
    v = v_ref[...].astype(jnp.float32)             # (1, Hb)
    y_ref[...] += jnp.sum(a * v, axis=1)[:, None]  # local partial reduce


def _rmatvec_kernel(a_ref, u_ref, z_ref):
    """grid = (H-blocks, f) — reduction over S is the (sequential) last dim."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        z_ref[...] = jnp.zeros_like(z_ref)

    a = a_ref[...].astype(jnp.float32)             # (Sb, Hb)
    u = u_ref[...].astype(jnp.float32)             # (Sb, 1)
    z_ref[...] += jnp.sum(a * u, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("expansion", "row_block",
                                             "interpret"))
def matvec(a: jax.Array, v: jax.Array, *, expansion: int = 8,
           row_block: int = 512, interpret: Optional[bool] = None
           ) -> jax.Array:
    """y[S] = A[S,H] @ v[H] with f-way expanded reduction over H."""
    interpret = resolve_interpret(interpret)
    s_dim, h_dim = a.shape
    assert h_dim % expansion == 0
    blk = h_dim // expansion
    rb = _block_divisor(s_dim, row_block)

    y = pl.pallas_call(
        _matvec_kernel,
        grid=(s_dim // rb, expansion),
        in_specs=[
            pl.BlockSpec((rb, blk), lambda i, j: (i, j)),
            pl.BlockSpec((1, blk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_dim, 1), jnp.float32),
        interpret=interpret,
    )(a, v[None, :])
    return y[:, 0]


def _block_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ cap (trace-time; n is static)."""
    return max(d for d in range(1, min(cap, n) + 1) if n % d == 0)


def _matvec_batched_kernel(a_ref, v_ref, y_ref):
    """grid = (B, S-blocks, f) — batch outermost, reduction innermost."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[0].astype(jnp.float32)               # (Sb, Hb)
    v = v_ref[0].astype(jnp.float32)               # (1, Hb)
    y_ref[0] += jnp.sum(a * v, axis=1)[:, None]


def _rmatvec_batched_kernel(a_ref, u_ref, z_ref):
    """grid = (B, H-blocks, f) — batch outermost, reduction innermost."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        z_ref[...] = jnp.zeros_like(z_ref)

    a = a_ref[0].astype(jnp.float32)               # (Sb, Hb)
    u = u_ref[0].astype(jnp.float32)               # (Sb, 1)
    z_ref[0] += jnp.sum(a * u, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("expansion", "row_block",
                                             "interpret"))
def matvec_batched(a: jax.Array, v: jax.Array, *, expansion: int = 8,
                   row_block: int = 512, interpret: Optional[bool] = None
                   ) -> jax.Array:
    """y[B,S] = A[B,S,H] @ v[B,H] — one launch for the whole batch."""
    interpret = resolve_interpret(interpret)
    b_dim, s_dim, h_dim = a.shape
    assert h_dim % expansion == 0
    blk = h_dim // expansion
    rb = _block_divisor(s_dim, row_block)

    y = pl.pallas_call(
        _matvec_batched_kernel,
        grid=(b_dim, s_dim // rb, expansion),
        in_specs=[
            pl.BlockSpec((1, rb, blk), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, 1, blk), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, rb, 1), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_dim, s_dim, 1), jnp.float32),
        interpret=interpret,
    )(a, v[:, None, :])
    return y[..., 0]


@functools.partial(jax.jit, static_argnames=("expansion", "col_block",
                                             "interpret"))
def rmatvec_batched(a: jax.Array, u: jax.Array, *, expansion: int = 8,
                    col_block: int = 512, interpret: Optional[bool] = None
                    ) -> jax.Array:
    """z[B,H] = A[B,S,H]ᵀ @ u[B,S] — one launch for the whole batch."""
    interpret = resolve_interpret(interpret)
    b_dim, s_dim, h_dim = a.shape
    assert s_dim % expansion == 0
    blk = s_dim // expansion
    cb = _block_divisor(h_dim, col_block)

    z = pl.pallas_call(
        _rmatvec_batched_kernel,
        grid=(b_dim, h_dim // cb, expansion),
        in_specs=[
            pl.BlockSpec((1, blk, cb), lambda b, i, j: (b, j, i)),
            pl.BlockSpec((1, blk, 1), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cb), lambda b, i, j: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((b_dim, 1, h_dim), jnp.float32),
        interpret=interpret,
    )(a, u[..., None])
    return z[:, 0]


@functools.partial(jax.jit, static_argnames=("expansion", "col_block",
                                             "interpret"))
def rmatvec(a: jax.Array, u: jax.Array, *, expansion: int = 8,
            col_block: int = 512, interpret: Optional[bool] = None
            ) -> jax.Array:
    """z[H] = A[S,H]ᵀ @ u[S] with f-way expanded reduction over S."""
    interpret = resolve_interpret(interpret)
    s_dim, h_dim = a.shape
    assert s_dim % expansion == 0
    blk = s_dim // expansion
    cb = _block_divisor(h_dim, col_block)

    z = pl.pallas_call(
        _rmatvec_kernel,
        grid=(h_dim // cb, expansion),
        in_specs=[
            pl.BlockSpec((blk, cb), lambda i, j: (j, i)),
            pl.BlockSpec((blk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, h_dim), jnp.float32),
        interpret=interpret,
    )(a, u[:, None])
    return z[0]


# -- tunable space (see repro.tune): the Fig. 12 operating point ------------
from ..tune.space import (BLOCK_GRID, EXPANSION_GRID,  # noqa: E402
                          TunableParam, TunableSpace, register_space)

register_space(TunableSpace("matvec_expand", (
    TunableParam("expansion", EXPANSION_GRID + (64, 128), default=8),
    TunableParam("row_block", BLOCK_GRID, default=512),
)))
