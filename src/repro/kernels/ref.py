"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matvec(a: jax.Array, v: jax.Array) -> jax.Array:
    return a.astype(jnp.float32) @ v.astype(jnp.float32)


def rmatvec(a: jax.Array, u: jax.Array) -> jax.Array:
    return a.astype(jnp.float32).T @ u.astype(jnp.float32)


def _cgs2(z: jax.Array, q: jax.Array) -> jax.Array:
    z = z - q @ (q.T @ z)
    z = z - q @ (q.T @ z)
    return z


def reorth_right(a: jax.Array, u: jax.Array, v_buf: jax.Array):
    """z = CGS2(Aᵀu, V); returns (z, ‖z‖²)."""
    z = _cgs2(rmatvec(a, u), v_buf.astype(jnp.float32))
    return z, jnp.sum(z * z)


def reorth_left(a: jax.Array, v: jax.Array, u_buf: jax.Array):
    """w = CGS2(Av, U); returns (w, ‖w‖²)."""
    w = _cgs2(matvec(a, v), u_buf.astype(jnp.float32))
    return w, jnp.sum(w * w)


def lowrank_matmul(vt: jax.Array, w: jax.Array) -> jax.Array:
    return vt.astype(jnp.float32) @ w.astype(jnp.float32)


def outlier_stats(x: jax.Array, threshold):
    a = jnp.abs(x.astype(jnp.float32))
    cnt = jnp.sum((a > threshold).astype(jnp.float32), axis=0)
    mx = jnp.max(a, axis=0)
    return cnt, mx


def dkv_attention_stats(inner, k_u, v_u):
    """Oracle for kernels.dkv_attention: full-score softmax stats."""
    s = inner.astype(jnp.float32) @ k_u.astype(jnp.float32).T   # [g, T]
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    a = p @ v_u.astype(jnp.float32)                              # [g, r]
    return a, m, l


def ssd_chunk_intra(cb, l, dt, x):
    """Oracle for kernels.ssd_chunk: materialized masked-decay einsum."""
    q = cb.shape[-1]
    decay = jnp.exp(l[:, :, None, :] - l[:, None, :, :])     # [G,Q,Q,nh]
    tril = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
    m = cb[..., None] * jnp.where(tril, decay, 0.0) * dt[:, None, :, :]
    return jnp.einsum("gqsn,gsnd->gqnd", m, x.astype(jnp.float32))
