"""Jit'd public wrappers around the Pallas kernels + Lanczos hook factory.

``INTERPRET`` defaults to True because this container is CPU-only; on a real
TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
``interpret=False``) and the same BlockSpecs compile via Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.lanczos import LanczosHooks
from . import dkv_attention as _dkv, lanczos_reorth, \
    lowrank_matmul as _lrmm, matvec_expand, outlier_extract, ssd_chunk

INTERPRET = True


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def matvec(a, v, *, expansion: int = 8, interpret: Optional[bool] = None):
    a, s = _pad_to(a, 0, 8)
    a, _ = _pad_to(a, 1, expansion)
    v, _ = _pad_to(v, 0, expansion)
    y = matvec_expand.matvec(a, v, expansion=expansion, row_block=min(512, a.shape[0]),
                             interpret=INTERPRET if interpret is None else interpret)
    return y[:s]


def rmatvec(a, u, *, expansion: int = 8, interpret: Optional[bool] = None):
    a, _ = _pad_to(a, 0, expansion)
    a, h = _pad_to(a, 1, 128)
    u, _ = _pad_to(u, 0, expansion)
    z = matvec_expand.rmatvec(a, u, expansion=expansion, col_block=min(512, a.shape[1]),
                              interpret=INTERPRET if interpret is None else interpret)
    return z[:h]


def reorth_right(a, u, v_buf, *, expansion: int = 8,
                 interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_right(a, u, v_buf, expansion=expansion,
                                       interpret=interp)


def reorth_left(a, v, u_buf, *, expansion: int = 8,
                interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_left(a, v, u_buf, expansion=expansion,
                                      interpret=interp)


def lowrank_matmul(vt, w, *, expansion: int = 8,
                   interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return _lrmm.lowrank_matmul(vt, w, expansion=expansion, interpret=interp)


def outlier_stats(x, threshold, *, expansion: int = 8,
                  interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return outlier_extract.outlier_stats(x, threshold, expansion=expansion,
                                         interpret=interp)


def dkv_attention_stats(inner, k_u, v_u, *, expansion: int = 8,
                        interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return _dkv.dkv_attention_stats(inner, k_u, v_u, expansion=expansion,
                                    interpret=interp)


merge_with_tail = _dkv.merge_with_tail


def ssd_chunk_intra(cb, l, dt, x, *, head_block: int = 4,
                    interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return ssd_chunk.ssd_chunk_intra(cb, l, dt, x, head_block=head_block,
                                     interpret=interp)


# ---------------------------------------------------------------------------
# Lanczos hook factory: plugs the fused Pallas steps into core.lanczos
# ---------------------------------------------------------------------------

def make_pallas_hooks(expansion: int = 8,
                      interpret: Optional[bool] = None) -> LanczosHooks:
    """LanczosHooks whose inner steps run the fused D-com kernel.

    Shapes must divide by ``expansion`` (callers pad); normalization stays in
    ``core.lanczos`` (the kernels return unnormalized vectors; the returned
    ‖z‖² is dropped here because _safe_normalize recomputes it — O(H)).
    """
    interp = INTERPRET if interpret is None else interpret

    def right_step(a, u, v_buf):
        z, _ = lanczos_reorth.reorth_right(a, u, v_buf, expansion=expansion,
                                           interpret=interp)
        return z

    def left_step(a, v, u_buf):
        w, _ = lanczos_reorth.reorth_left(a, v, u_buf, expansion=expansion,
                                          interpret=interp)
        return w

    return LanczosHooks(right_step=right_step, left_step=left_step)
