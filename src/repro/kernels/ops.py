"""Jit'd public wrappers around the Pallas kernels + Lanczos hook factory.

``INTERPRET`` is derived ONCE from the platform (``engine.platform``):
interpret mode everywhere except a real TPU, where the same BlockSpecs
compile via Mosaic with no manual flags at call sites.  It stays a mutable
module attribute as the process-wide escape hatch (e.g. forcing interpret
mode on TPU for debugging).

Block sizes (``row_block``/``n_block``/``col_block``) default to ``None``
= the kernel's historical 512; the ``repro.tune`` autotuner passes the
measured operating point through these wrappers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.lanczos import BatchedLanczosHooks, LanczosHooks
from ..engine.platform import default_interpret
from . import dkv_attention as _dkv, lanczos_reorth, \
    lowrank_matmul as _lrmm, matvec_expand, outlier_extract, ssd_chunk

INTERPRET = default_interpret()


@functools.lru_cache(maxsize=None)
def pad_plan(shape: tuple, axis: int, mult: int):
    """Cached pad decision for one axis: (pad widths tuple | None, orig n).

    Keyed on ``(shape, axis, mult)`` so repeated wrapper calls (and the
    engine's per-layer decompose sites) never recompute pad widths or build
    fresh width lists at trace time.
    """
    n = shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return None, n
    widths = [(0, 0)] * len(shape)
    widths[axis] = (0, pad)
    return tuple(widths), n


@functools.lru_cache(maxsize=None)
def padded_dims(s: int, h: int, expansion: int):
    """Cached (S_pad, H_pad) for a fused-Lanczos launch: the left step needs
    S % f == 0, the right step H % f == 0."""
    return s + ((-s) % expansion), h + ((-h) % expansion)


def _pad_to(x: jax.Array, axis: int, mult: int):
    widths, n = pad_plan(x.shape, axis, mult)
    if widths is None:
        return x, n
    return jnp.pad(x, widths), n


def matvec(a, v, *, expansion: int = 8, row_block: Optional[int] = None,
           interpret: Optional[bool] = None):
    a, s = _pad_to(a, 0, 8)
    a, _ = _pad_to(a, 1, expansion)
    v, _ = _pad_to(v, 0, expansion)
    rb = min(row_block or 512, a.shape[0])
    y = matvec_expand.matvec(a, v, expansion=expansion, row_block=rb,
                             interpret=INTERPRET if interpret is None else interpret)
    return y[:s]


def rmatvec(a, u, *, expansion: int = 8, col_block: Optional[int] = None,
            interpret: Optional[bool] = None):
    a, _ = _pad_to(a, 0, expansion)
    a, h = _pad_to(a, 1, 128)
    u, _ = _pad_to(u, 0, expansion)
    cb = min(col_block or 512, a.shape[1])
    z = matvec_expand.rmatvec(a, u, expansion=expansion, col_block=cb,
                              interpret=INTERPRET if interpret is None else interpret)
    return z[:h]


def matvec_batched(a, v, *, expansion: int = 8,
                   row_block: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """y[B,S] = A[B,S,H] @ v[B,H]; pads H like the scalar wrapper."""
    a, _ = _pad_to(a, 2, expansion)
    v, _ = _pad_to(v, 1, expansion)
    y = matvec_expand.matvec_batched(
        a, v, expansion=expansion, row_block=min(row_block or 512,
                                                 a.shape[-2]),
        interpret=INTERPRET if interpret is None else interpret)
    return y


def rmatvec_batched(a, u, *, expansion: int = 8,
                    col_block: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """z[B,H] = A[B,S,H]ᵀ @ u[B,S]; pads S like the scalar wrapper."""
    a, _ = _pad_to(a, 1, expansion)
    u, _ = _pad_to(u, 1, expansion)
    z = matvec_expand.rmatvec_batched(
        a, u, expansion=expansion, col_block=min(col_block or 512,
                                                 a.shape[-1]),
        interpret=INTERPRET if interpret is None else interpret)
    return z


def reorth_right(a, u, v_buf, *, expansion: int = 8,
                 interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_right(a, u, v_buf, expansion=expansion,
                                       interpret=interp)


def reorth_right_batched(a, u, v_buf, *, expansion: int = 8,
                         interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_right_batched(a, u, v_buf,
                                               expansion=expansion,
                                               interpret=interp)


def reorth_left_batched(a, v, u_buf, *, expansion: int = 8,
                        interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_left_batched(a, v, u_buf,
                                              expansion=expansion,
                                              interpret=interp)


def reorth_left(a, v, u_buf, *, expansion: int = 8,
                interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return lanczos_reorth.reorth_left(a, v, u_buf, expansion=expansion,
                                      interpret=interp)


def lowrank_matmul(vt, w, *, expansion: int = 8,
                   n_block: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Vᵀ[k,H] @ W[H,N]; zero-pads the H reduction to a multiple of the
    expansion factor (exact — pad products are 0·0) and N to a multiple
    of 128 so the kernel's block-divisor clamp never collapses to tiny
    N-blocks on prime-ish widths (a vocab-sized N would otherwise run a
    pathological (N, f) grid)."""
    interp = INTERPRET if interpret is None else interpret
    vt, _ = _pad_to(vt, 1, expansion)
    w, _ = _pad_to(w, 0, expansion)
    w, n = _pad_to(w, 1, 128)
    out = _lrmm.lowrank_matmul(vt, w, expansion=expansion,
                               n_block=min(n_block or 512, w.shape[1]),
                               interpret=interp)
    return out[:, :n]


def outlier_stats(x, threshold, *, expansion: int = 8,
                  interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return outlier_extract.outlier_stats(x, threshold, expansion=expansion,
                                         interpret=interp)


def dkv_attention_stats(inner, k_u, v_u, *, expansion: int = 8,
                        interpret: Optional[bool] = None):
    """Rank-space flash stats over an ARBITRARY-length time axis: U_k/U_v
    are zero-padded through the cached pad plan and the kernel masks rows
    at or beyond the true length out of the softmax exactly."""
    interp = INTERPRET if interpret is None else interpret
    k_u, t = _pad_to(k_u, 0, expansion)
    v_u, _ = _pad_to(v_u, 0, expansion)
    return _dkv.dkv_attention_stats(inner, k_u, v_u, expansion=expansion,
                                    interpret=interp, t_valid=t)


def dkv_attention_stats_paged(inner, k_u_pages, v_u_pages, page_ids, *,
                              t_valid: int,
                              interpret: Optional[bool] = None):
    """Paged twin of :func:`dkv_attention_stats`: U blocks are DMA'd by
    prefetched page id out of the pools (no contiguous stream), one grid
    step per block-table entry; bit-compatible with the contiguous kernel
    at ``expansion == len(page_ids)`` on the gathered rows."""
    interp = INTERPRET if interpret is None else interpret
    return _dkv.dkv_attention_stats_paged(inner, k_u_pages, v_u_pages,
                                          page_ids, t_valid=t_valid,
                                          interpret=interp)


merge_with_tail = _dkv.merge_with_tail


def ssd_chunk_intra(cb, l, dt, x, *, head_block: int = 4,
                    interpret: Optional[bool] = None):
    interp = INTERPRET if interpret is None else interpret
    return ssd_chunk.ssd_chunk_intra(cb, l, dt, x, head_block=head_block,
                                     interpret=interp)


# ---------------------------------------------------------------------------
# Lanczos hook factory: plugs the fused Pallas steps into core.lanczos
# ---------------------------------------------------------------------------

def make_pallas_hooks(expansion: int = 8,
                      interpret: Optional[bool] = None) -> LanczosHooks:
    """LanczosHooks whose inner steps run the fused D-com kernel.

    Shapes must divide by ``expansion`` (callers pad); normalization stays in
    ``core.lanczos`` (the kernels return unnormalized vectors; the returned
    ‖z‖² is dropped here because _safe_normalize recomputes it — O(H)).

    The returned hooks are cached per (expansion, RESOLVED interpret) so
    they keep a stable identity — they are static jit arguments in
    ``core.lanczos``, and fresh closures would retrace on every engine
    construction.  The module-level ``INTERPRET`` flag is re-read on every
    call (never baked into a cache key), so flipping it for TPU deployment
    keeps working.
    """
    return _make_pallas_hooks(expansion,
                              INTERPRET if interpret is None else interpret)


@functools.lru_cache(maxsize=None)
def _make_pallas_hooks(expansion: int, interp: bool) -> LanczosHooks:
    def right_step(a, u, v_buf):
        z, _ = lanczos_reorth.reorth_right(a, u, v_buf, expansion=expansion,
                                           interpret=interp)
        return z

    def left_step(a, v, u_buf):
        w, _ = lanczos_reorth.reorth_left(a, v, u_buf, expansion=expansion,
                                          interpret=interp)
        return w

    return LanczosHooks(right_step=right_step, left_step=left_step)


def make_batched_pallas_hooks(expansion: int = 8,
                              interpret: Optional[bool] = None
                              ) -> BatchedLanczosHooks:
    """BatchedLanczosHooks running ONE fused Pallas launch per Lanczos pass
    for the whole prompt batch (grid = (B, 3, f)) — no vmap over pallas_call.

    Shapes must divide by ``expansion`` on the reduced axis (the engine pads
    via the cached :func:`padded_dims` plan).  Cached per (expansion,
    resolved interpret) for stable jit identity, like
    :func:`make_pallas_hooks`; ``INTERPRET`` is re-read per call.
    """
    return _make_batched_pallas_hooks(
        expansion, INTERPRET if interpret is None else interpret)


@functools.lru_cache(maxsize=None)
def _make_batched_pallas_hooks(expansion: int, interp: bool
                               ) -> BatchedLanczosHooks:
    def right_step(a, u, v_buf):
        z, _ = lanczos_reorth.reorth_right_batched(
            a, u, v_buf, expansion=expansion, interpret=interp)
        return z

    def left_step(a, v, u_buf):
        w, _ = lanczos_reorth.reorth_left_batched(
            a, v, u_buf, expansion=expansion, interpret=interp)
        return w

    return BatchedLanczosHooks(right_step=right_step, left_step=left_step)


def make_vmapped_pallas_hooks(expansion: int = 8,
                              interpret: Optional[bool] = None
                              ) -> BatchedLanczosHooks:
    """vmap-of-scalar-kernel fallback hooks (the pre-engine batching scheme).

    Kept as an explicit backend so the engine benchmark can measure batched
    launch vs per-prompt vmap, and as the escape hatch for shapes a native
    batched launch cannot take.
    """
    return _make_vmapped_pallas_hooks(
        expansion, INTERPRET if interpret is None else interpret)


@functools.lru_cache(maxsize=None)
def _make_vmapped_pallas_hooks(expansion: int, interp: bool
                               ) -> BatchedLanczosHooks:
    from ..core.lanczos import batch_hooks
    return batch_hooks(_make_pallas_hooks(expansion, interp))
