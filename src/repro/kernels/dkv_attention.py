"""Flash-decoding THROUGH the low-rank KV factors (beyond-paper kernel).

The decomposed-KV decode step (models/decomposed_kv.py) replaces the
[T, d_kv] cache read with rank-space contractions:

    s_t   = inner · U_k[t]ᵀ          inner = q·Vᵀ_k  (tiny, precomputed)
    out   = softmax(s) · U_v · Vᵀ_v

Both big contractions stream U_{k,v} [T, r] over the time axis — the same
memory-bound skinny pattern as the Lanczos chain, so the same D-com
expansion treatment applies: the grid tiles T into ``f`` blocks, each block
computes its scores AND folds them into a rank-space accumulator with
online-softmax (flash) running statistics:

    m' = max(m, max(s_blk));  c = exp(m − m')
    l' = l·c + Σ exp(s_blk − m')
    a' = a·c + exp(s_blk − m') · U_v[blk]          # a: [g, r] — tiny!

One pass over U_k/U_v, no [T]-length score tensor ever materialized, and
the accumulator lives in rank space (g×r), not head space.  The final
out = (a/l)·Vᵀ_v and the dense-tail merge happen outside (cheap).

Returns per-(batch, kv-head) partial stats (a, m, l) so the caller merges
the exact dense tail with the standard flash combine rule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..engine.platform import resolve_interpret


def _dkv_kernel(inner_ref, ku_ref, vu_ref, a_out, m_out, l_out,
                m_s, l_s, a_s, *, f: int, blk: int, t_valid: int):
    """grid = (f,) time-blocks for ONE (batch, kv-head) slice.

    inner [g, r]; ku/vu block [blk, r]; accumulators in VMEM scratch.
    Rows at or beyond ``t_valid`` are zero-padding (the wrapper pads the
    time axis to a multiple of f) and are masked out of the running
    softmax statistics EXACTLY: their scores never enter the max and their
    probability mass is written as a literal 0, so padded and unpadded
    launches produce bit-identical (a, m, l).
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        a_s[...] = jnp.zeros_like(a_s)

    inner = inner_ref[...].astype(jnp.float32)          # [g, r]
    ku = ku_ref[...].astype(jnp.float32)                # [blk, r]
    s_blk = jnp.dot(inner, ku.T,
                    preferred_element_type=jnp.float32)  # [g, blk]
    # global row index of every score column; -1e30 for pad rows keeps the
    # running max neutral even when a whole block is padding
    rows = j * blk + jax.lax.broadcasted_iota(jnp.int32, s_blk.shape, 1)
    valid = rows < t_valid
    s_blk = jnp.where(valid, s_blk, -1e30)

    m_old = m_s[...]                                     # [g, 1]
    m_new = jnp.maximum(m_old, jnp.max(s_blk, axis=1, keepdims=True))
    c = jnp.exp(m_old - m_new)
    # exp(-1e30 − m) underflows to 0 for every reachable m EXCEPT the
    # all-padding-so-far case (m_new still -1e30, exp(0) = 1) — the where
    # pins pad mass to exactly 0 in both regimes
    p = jnp.where(valid, jnp.exp(s_blk - m_new), 0.0)    # [g, blk]
    vu = vu_ref[...].astype(jnp.float32)                 # [blk, r]
    a_s[...] = a_s[...] * c + jnp.dot(p, vu,
                                      preferred_element_type=jnp.float32)
    l_s[...] = l_s[...] * c + jnp.sum(p, axis=1, keepdims=True)
    m_s[...] = m_new

    @pl.when(j == f - 1)
    def _fin():
        a_out[...] = a_s[...]
        m_out[...] = m_s[...]
        l_out[...] = l_s[...]


@functools.partial(jax.jit, static_argnames=("expansion", "interpret",
                                             "t_valid"))
def dkv_attention_stats(inner: jax.Array, k_u: jax.Array, v_u: jax.Array,
                        *, expansion: int = 8,
                        interpret: Optional[bool] = None,
                        t_valid: Optional[int] = None):
    """Rank-space flash stats for ONE (batch, kv-head) slice.

    inner [g, r] (= scaled q·Vᵀ_k), k_u / v_u [T, r] →
    (a [g, r], m [g, 1], l [g, 1]) with softmax-weighted U_v accumulated
    in rank space.  Arbitrary T: the time axis is zero-padded to a
    multiple of ``expansion`` (the ``ops`` wrapper pads through the cached
    ``pad_plan``; unpadded direct calls pad here) and rows at or beyond
    ``t_valid`` are masked out of the softmax inside the kernel, so any
    cache length works with any f.
    """
    interpret = resolve_interpret(interpret)
    g, r = inner.shape
    t = k_u.shape[0]
    if t_valid is None:
        t_valid = t
    pad = (-t) % expansion
    if pad:
        k_u = jnp.pad(k_u, ((0, pad), (0, 0)))
        v_u = jnp.pad(v_u, ((0, pad), (0, 0)))
    blk = (t + pad) // expansion

    a, m, l = pl.pallas_call(
        functools.partial(_dkv_kernel, f=expansion, blk=blk, t_valid=t_valid),
        grid=(expansion,),
        in_specs=[
            pl.BlockSpec((g, r), lambda j: (0, 0)),
            pl.BlockSpec((blk, r), lambda j: (j, 0)),
            pl.BlockSpec((blk, r), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, r), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, r), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # running denom
            pltpu.VMEM((g, r), jnp.float32),      # rank-space accumulator
        ],
        interpret=interpret,
    )(inner, k_u, v_u)
    return a, m, l


def _dkv_paged_kernel(ids_ref, inner_ref, ku_ref, vu_ref, a_out, m_out,
                      l_out, m_s, l_s, a_s, *, n: int, page: int,
                      t_valid: int):
    """grid = (n,) PAGES for ONE (batch, kv-head) slice.

    The block index maps read the prefetched page-id vector, so each grid
    step DMAs page ``ids[j]`` straight out of the U pools — the gather
    happens in the BlockSpec, no [T, r] contiguous stream is ever
    materialized.  Page j covers logical rows ``j·page … (j+1)·page``;
    rows at or beyond ``t_valid`` (block-table padding, partially filled
    last page) are masked out of the running softmax exactly as in
    :func:`_dkv_kernel`.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        a_s[...] = jnp.zeros_like(a_s)

    inner = inner_ref[...].astype(jnp.float32)          # [g, r]
    ku = ku_ref[0].astype(jnp.float32)                  # [page, r]
    s_blk = jnp.dot(inner, ku.T,
                    preferred_element_type=jnp.float32)  # [g, page]
    rows = j * page + jax.lax.broadcasted_iota(jnp.int32, s_blk.shape, 1)
    valid = rows < t_valid
    s_blk = jnp.where(valid, s_blk, -1e30)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, jnp.max(s_blk, axis=1, keepdims=True))
    c = jnp.exp(m_old - m_new)
    p = jnp.where(valid, jnp.exp(s_blk - m_new), 0.0)
    vu = vu_ref[0].astype(jnp.float32)                  # [page, r]
    a_s[...] = a_s[...] * c + jnp.dot(p, vu,
                                      preferred_element_type=jnp.float32)
    l_s[...] = l_s[...] * c + jnp.sum(p, axis=1, keepdims=True)
    m_s[...] = m_new

    @pl.when(j == n - 1)
    def _fin():
        a_out[...] = a_s[...]
        m_out[...] = m_s[...]
        l_out[...] = l_s[...]


@functools.partial(jax.jit, static_argnames=("interpret", "t_valid"))
def dkv_attention_stats_paged(inner: jax.Array, k_u_pages: jax.Array,
                              v_u_pages: jax.Array, page_ids: jax.Array,
                              *, t_valid: int,
                              interpret: Optional[bool] = None):
    """Rank-space flash stats THROUGH a page table (paged serving).

    inner [g, r]; k_u_pages / v_u_pages [P, page, r] pools; page_ids [n]
    int32 (a slot's block-table row) → (a [g, r], m [g, 1], l [g, 1]).

    Bit-compatible with :func:`dkv_attention_stats` at ``expansion=n`` on
    the gathered rows: the grid tiles the logical sequence page-by-page
    with identical online-softmax block math, but the U blocks are DMA'd
    by PREFETCHED page id (``pltpu.PrefetchScalarGridSpec``) instead of
    streamed contiguously — vLLM-style paged attention in rank space.
    """
    interpret = resolve_interpret(interpret)
    g, r = inner.shape
    n = page_ids.shape[0]
    page = k_u_pages.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((g, r), lambda j, ids: (0, 0)),
            pl.BlockSpec((1, page, r), lambda j, ids: (ids[j], 0, 0)),
            pl.BlockSpec((1, page, r), lambda j, ids: (ids[j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, r), lambda j, ids: (0, 0)),
            pl.BlockSpec((g, 1), lambda j, ids: (0, 0)),
            pl.BlockSpec((g, 1), lambda j, ids: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # running denom
            pltpu.VMEM((g, r), jnp.float32),      # rank-space accumulator
        ],
    )
    a, m, l = pl.pallas_call(
        functools.partial(_dkv_paged_kernel, n=n, page=page,
                          t_valid=t_valid),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((g, r), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_ids.astype(jnp.int32), inner, k_u_pages, v_u_pages)
    return a, m, l


def merge_with_tail(a, m, l, v_vt, tail_scores, tail_v):
    """Flash-combine the prefix rank-space stats with exact dense-tail
    attention.  tail_scores [g, tl] (already masked), tail_v [tl, d].

    Returns out [g, d] — the softmax over [prefix ∪ tail] exactly.
    """
    m_t = jnp.max(tail_scores, axis=1, keepdims=True)
    p_t = jnp.exp(tail_scores - m_t)
    l_t = jnp.sum(p_t, axis=1, keepdims=True)
    o_t = p_t @ tail_v.astype(jnp.float32)               # [g, d]

    m_all = jnp.maximum(m, m_t)
    c_pre, c_t = jnp.exp(m - m_all), jnp.exp(m_t - m_all)
    out_pre = (a @ v_vt.astype(jnp.float32)) * c_pre     # [g, d]
    denom = l * c_pre + l_t * c_t
    return (out_pre + o_t * c_t) / jnp.maximum(denom, 1e-30)


# -- tunable space (see repro.tune): time-axis expansion of the stream ------
from ..tune.space import (EXPANSION_GRID, TunableParam,  # noqa: E402
                          TunableSpace, register_space)

register_space(TunableSpace("dkv_attention", (
    TunableParam("expansion", EXPANSION_GRID, default=8),
)))
