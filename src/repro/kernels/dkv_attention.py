"""Flash-decoding THROUGH the low-rank KV factors (beyond-paper kernel).

The decomposed-KV decode step (models/decomposed_kv.py) replaces the
[T, d_kv] cache read with rank-space contractions:

    s_t   = inner · U_k[t]ᵀ          inner = q·Vᵀ_k  (tiny, precomputed)
    out   = softmax(s) · U_v · Vᵀ_v

Both big contractions stream U_{k,v} [T, r] over the time axis — the same
memory-bound skinny pattern as the Lanczos chain, so the same D-com
expansion treatment applies: the grid tiles T into ``f`` blocks, each block
computes its scores AND folds them into a rank-space accumulator with
online-softmax (flash) running statistics:

    m' = max(m, max(s_blk));  c = exp(m − m')
    l' = l·c + Σ exp(s_blk − m')
    a' = a·c + exp(s_blk − m') · U_v[blk]          # a: [g, r] — tiny!

One pass over U_k/U_v, no [T]-length score tensor ever materialized, and
the accumulator lives in rank space (g×r), not head space.  The final
out = (a/l)·Vᵀ_v and the dense-tail merge happen outside (cheap).

Returns per-(batch, kv-head) partial stats (a, m, l) so the caller merges
the exact dense tail with the standard flash combine rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dkv_kernel(inner_ref, ku_ref, vu_ref, a_out, m_out, l_out,
                m_s, l_s, a_s, *, f: int, blk: int):
    """grid = (f,) time-blocks for ONE (batch, kv-head) slice.

    inner [g, r]; ku/vu block [blk, r]; accumulators in VMEM scratch.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        a_s[...] = jnp.zeros_like(a_s)

    inner = inner_ref[...].astype(jnp.float32)          # [g, r]
    ku = ku_ref[...].astype(jnp.float32)                # [blk, r]
    s_blk = jnp.dot(inner, ku.T,
                    preferred_element_type=jnp.float32)  # [g, blk]

    m_old = m_s[...]                                     # [g, 1]
    m_new = jnp.maximum(m_old, jnp.max(s_blk, axis=1, keepdims=True))
    c = jnp.exp(m_old - m_new)
    p = jnp.exp(s_blk - m_new)                           # [g, blk]
    vu = vu_ref[...].astype(jnp.float32)                 # [blk, r]
    a_s[...] = a_s[...] * c + jnp.dot(p, vu,
                                      preferred_element_type=jnp.float32)
    l_s[...] = l_s[...] * c + jnp.sum(p, axis=1, keepdims=True)
    m_s[...] = m_new

    @pl.when(j == f - 1)
    def _fin():
        a_out[...] = a_s[...]
        m_out[...] = m_s[...]
        l_out[...] = l_s[...]


@functools.partial(jax.jit, static_argnames=("expansion", "interpret"))
def dkv_attention_stats(inner: jax.Array, k_u: jax.Array, v_u: jax.Array,
                        *, expansion: int = 8, interpret: bool = True):
    """Rank-space flash stats for ONE (batch, kv-head) slice.

    inner [g, r] (= scaled q·Vᵀ_k), k_u / v_u [T, r] →
    (a [g, r], m [g, 1], l [g, 1]) with softmax-weighted U_v accumulated
    in rank space.  T % expansion == 0.
    """
    g, r = inner.shape
    t = k_u.shape[0]
    assert t % expansion == 0, (t, expansion)
    blk = t // expansion

    a, m, l = pl.pallas_call(
        functools.partial(_dkv_kernel, f=expansion, blk=blk),
        grid=(expansion,),
        in_specs=[
            pl.BlockSpec((g, r), lambda j: (0, 0)),
            pl.BlockSpec((blk, r), lambda j: (j, 0)),
            pl.BlockSpec((blk, r), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, r), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, r), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),      # running max
            pltpu.VMEM((g, 1), jnp.float32),      # running denom
            pltpu.VMEM((g, r), jnp.float32),      # rank-space accumulator
        ],
        interpret=interpret,
    )(inner, k_u, v_u)
    return a, m, l


def merge_with_tail(a, m, l, v_vt, tail_scores, tail_v):
    """Flash-combine the prefix rank-space stats with exact dense-tail
    attention.  tail_scores [g, tl] (already masked), tail_v [tl, d].

    Returns out [g, d] — the softmax over [prefix ∪ tail] exactly.
    """
    m_t = jnp.max(tail_scores, axis=1, keepdims=True)
    p_t = jnp.exp(tail_scores - m_t)
    l_t = jnp.sum(p_t, axis=1, keepdims=True)
    o_t = p_t @ tail_v.astype(jnp.float32)               # [g, d]

    m_all = jnp.maximum(m, m_t)
    c_pre, c_t = jnp.exp(m - m_all), jnp.exp(m_t - m_all)
    out_pre = (a @ v_vt.astype(jnp.float32)) * c_pre     # [g, d]
    denom = l * c_pre + l_t * c_t
    return (out_pre + o_t * c_t) / jnp.maximum(denom, 1e-30)
