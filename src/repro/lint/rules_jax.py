"""JAX discipline rules: J1 (donated-buffer reuse), J2 (host sync in
serving hot paths), S1 (sharding spec completeness)."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .core import Finding, ModuleCtx, Rule, dotted_name, register

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}
_SYNC_METHODS = {"block_until_ready", "item"}
_SYNC_FUNCS = {"jax.block_until_ready", "jax.device_get"}
# attribute prefixes that name jitted serving dispatches on an engine —
# wrapping one of these in float()/np.asarray() forces a device sync
_DISPATCH_PREFIXES = ("_decode", "_prefill", "_fold", "_splice",
                      "_compress", "_jitted", "sampler")


def _is_jit_call(node: ast.Call) -> bool:
    fn = dotted_name(node.func)
    return fn in _JIT_NAMES


def _donated_positions(node: ast.Call) -> Tuple[int, ...]:
    """Literal donate_argnums of a jax.jit call (empty when absent or
    non-literal — we only reason about what we can see statically)."""
    for kw in node.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            out = []
            for e in v.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    out.append(e.value)
            return tuple(out)
    return ()


def _reads_writes(node: ast.AST) -> Tuple[Set[str], Set[str]]:
    """Dotted names loaded / stored anywhere under ``node``."""
    reads: Set[str] = set()
    writes: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)):
            d = dotted_name(n)
            if d is None:
                continue
            c = getattr(n, "ctx", None)
            if isinstance(c, (ast.Store, ast.Del)):
                writes.add(d)
            elif isinstance(c, ast.Load):
                reads.add(d)
    return reads, writes


@register
class DonatedReuseRule(Rule):
    """J1 — a buffer passed at a donated position must not be read again
    in the same scope.

    ``donate_argnums`` hands the input buffer to XLA for in-place reuse:
    reading the donated array afterwards returns garbage (or raises,
    depending on backend) — the whole fused-decode path (PR 6) donates
    every cache slab, so this mistake produces silently wrong tokens,
    not a crash.  The rule tracks ``g = jax.jit(f, donate_argnums=...)``
    bindings per scope and flags any later load of a variable that was
    passed at a donated position and not rebound first (the sanctioned
    idiom is ``cache = step(..., cache, ...)``).
    """
    id = "J1"
    name = "donated-buffer-reuse"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        scopes: List[ast.AST] = [ctx.tree]
        scopes += [n for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for scope in scopes:
            yield from self._check_scope(ctx, scope)

    def _check_scope(self, ctx: ModuleCtx, scope: ast.AST):
        body = getattr(scope, "body", [])
        donating: Dict[str, Tuple[int, ...]] = {}
        # donated-name -> (call node, donated arg dotted-name)
        for i, stmt in enumerate(body):
            for tgt, val in self._assignments(stmt):
                if isinstance(val, ast.Call) and _is_jit_call(val):
                    pos = _donated_positions(val)
                    if pos:
                        donating[tgt] = pos
            for call in self._calls_of(stmt, donating):
                pos = donating[dotted_name(call.func)]  # type: ignore[index]
                for p in pos:
                    if p >= len(call.args):
                        continue
                    arg = dotted_name(call.args[p])
                    if arg is None:
                        continue
                    rebound = arg in self._stmt_targets(stmt)
                    if rebound:
                        continue
                    use = self._later_read(body[i + 1:], arg)
                    if use is not None:
                        yield ctx.finding(
                            self, use,
                            f"{arg!r} was donated to "
                            f"{dotted_name(call.func)}() (donate_argnums="
                            f"{pos}) and read again — rebind the result "
                            "or copy before donating")

    @staticmethod
    def _assignments(stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                d = dotted_name(t)
                if d:
                    yield d, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            d = dotted_name(stmt.target)
            if d:
                yield d, stmt.value

    @staticmethod
    def _stmt_targets(stmt: ast.stmt) -> Set[str]:
        out: Set[str] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for n in ast.walk(t):
                    d = dotted_name(n)
                    if d:
                        out.add(d)
        return out

    @staticmethod
    def _calls_of(stmt: ast.stmt, donating: Dict[str, Tuple[int, ...]]):
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call):
                d = dotted_name(n.func)
                if d in donating:
                    yield n

    @staticmethod
    def _later_read(stmts: List[ast.stmt], name: str) -> Optional[ast.AST]:
        """First statement reading ``name`` before any rebind, else None."""
        for s in stmts:
            reads, writes = _reads_writes(s)
            if name in reads:
                # `x = f(x)` self-rebind both reads and writes — treat the
                # read as pre-rebind only when it is NOT the same statement
                # rebinding it from a call (conservative: flag it)
                if name in writes and isinstance(s, ast.Assign) \
                        and name not in _reads_writes(s.value)[0]:
                    return None
                return s
            if name in writes:
                return None
        return None


@register
class HostSyncHotPathRule(Rule):
    """J2 — no host-synchronizing calls on device values in the serving
    decode/dispatch hot path (modules under ``repro/serving/``).

    ``.item()``, ``float(jitted(...))``, ``np.asarray(jitted(...))``,
    ``jax.block_until_ready`` and ``jax.device_get`` all block the host
    until the device catches up.  The async prefill pipeline (PR 7)
    only overlaps prefill with decode because dispatches return
    *futures*; one stray sync in ``step()``/``_dispatch_*`` re-serializes
    the whole engine, costing the entire disaggregation win without any
    test failing.  The single sanctioned sync point is the sampler
    readback in ``Engine._sample_host`` (suppressed inline with a
    justification).
    """
    id = "J2"
    name = "host-sync-hot-path"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        if not ctx.in_pkg("repro", "serving"):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn in _SYNC_FUNCS:
                yield ctx.finding(
                    self, node, f"{fn}() blocks the host on device work "
                    "inside the serving hot path — keep dispatches async")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_METHODS \
                    and not node.args and not node.keywords:
                yield ctx.finding(
                    self, node, f".{node.func.attr}() forces a device→host "
                    "sync inside the serving hot path")
            elif fn in ("float", "np.asarray", "numpy.asarray", "asarray") \
                    and node.args and self._wraps_dispatch(node.args[0]):
                yield ctx.finding(
                    self, node, f"{fn}() directly wraps a jitted dispatch — "
                    "this blocks on the result and serializes the async "
                    "pipeline; keep the future and convert at the host edge")

    @staticmethod
    def _wraps_dispatch(arg: ast.AST) -> bool:
        if not isinstance(arg, ast.Call):
            return False
        if isinstance(arg.func, ast.Attribute):
            return arg.func.attr.startswith(_DISPATCH_PREFIXES)
        return False


@register
class ShardingSpecsRule(Rule):
    """S1 — ``shard_map`` must declare BOTH ``in_specs`` and
    ``out_specs``; ``jax.jit`` must pass ``in_shardings`` and
    ``out_shardings`` together or not at all.

    Half-specified shardings compile (JAX infers the missing side) but
    the inferred side can silently change with the input layout — the
    PR 4 mesh work requires EXPLICIT in/out shardings on every sharded
    step so 8-device serving stays byte-identical to 1-device; an
    inferred out-sharding is exactly the kind of drift that broke the
    conformance twin during development.
    """
    id = "S1"
    name = "sharding-specs-complete"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func) or ""
            kws = {kw.arg for kw in node.keywords if kw.arg}
            if fn.rsplit(".", 1)[-1] == "shard_map":
                missing = {"in_specs", "out_specs"} - kws
                if missing:
                    yield ctx.finding(
                        self, node, "shard_map without "
                        f"{'/'.join(sorted(missing))} — declare both so "
                        "per-device layouts are explicit")
            elif fn in _JIT_NAMES:
                has_in = "in_shardings" in kws
                has_out = "out_shardings" in kws
                if has_in != has_out:
                    present = "in_shardings" if has_in else "out_shardings"
                    absent = "out_shardings" if has_in else "in_shardings"
                    yield ctx.finding(
                        self, node, f"jit with {present} but no {absent} — "
                        "an inferred sharding can drift; declare both")
