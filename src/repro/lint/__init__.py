"""dcomlint — the repo's own static analyzer (DESIGN.md §14).

Eight PRs of serving work accumulated invariants that runtime suites
enforce expensively (byte-identical tokens under sharding/fusion/async,
host-side-only observability, atomic persistence, donated-buffer
discipline) and that several past bugs violated in ways a lint pass
catches in seconds: the PYTHONHASHSEED-randomized ``hash()`` PowerSGD
seed (PR 4), the non-atomic ``ThresholdTable.save`` (PR 4), the
``time.time()`` latency stamps (PR 2).  dcomlint turns each of those
into an AST rule that runs on every commit:

======  ===========================  =====================================
 id      name                         invariant
======  ===========================  =====================================
 D1      builtin-hash-or-id           no ``hash()``/``id()`` into persisted
                                      keys, seeds, cache filenames
 D2      wall-clock-interval          ``perf_counter`` for latency math
 D3      non-atomic-write             tmp + ``os.replace`` for every write
 F1      family-table-complete        family dispatch only via the
                                      ModelFns / ServingFamily registries
 J1      donated-buffer-reuse         never read a donated buffer again
 J2      host-sync-hot-path           no device sync in serving hot paths
 O1      obs-token-neutral            obs is host-side; none in traced fns
 P1      pallas-call-invariants       interpret plumbed, index_map arity,
                                      grid divisibility guards
 S1      sharding-specs-complete      shard_map/jit declare in AND out
======  ===========================  =====================================

Usage::

    python -m repro.lint src benchmarks [--json out.json] [--list-rules]

Suppress a single line with ``# dcomlint: disable=D2`` (always pair it
with a justification comment) or a whole file with
``# dcomlint: disable-file=RULE``.
"""
from __future__ import annotations

from .core import (REGISTRY, SCHEMA, Finding, ModuleCtx, Rule, all_rules,
                   check_file, dump_report, iter_py_files,
                   parse_suppressions, register, render_human, report_json,
                   run_paths)
# importing the rule modules populates the registry
from . import rules_determinism  # noqa: F401
from . import rules_family       # noqa: F401
from . import rules_jax          # noqa: F401
from . import rules_obs          # noqa: F401
from . import rules_pallas       # noqa: F401

__all__ = [
    "REGISTRY", "SCHEMA", "Finding", "ModuleCtx", "Rule", "all_rules",
    "check_file", "dump_report", "iter_py_files", "parse_suppressions",
    "register", "render_human", "report_json", "run_paths",
]
