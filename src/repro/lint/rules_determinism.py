"""Determinism & durability rules: D1 (hash/id), D2 (clocks), D3 (atomic
writes).  Each one is a past production bug turned into a gate."""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from .core import Finding, ModuleCtx, Rule, dotted_name, register

# keyword names that mark a value as persisted / seeding / addressable —
# an id() flowing into one of these is process-lifetime-dependent state
_SINK_KWARGS = {"seed", "key", "path", "filename", "name", "fname"}
_SINK_CALLS = {"join", "format", "PRNGKey", "fold_in", "crc32", "md5",
               "sha1", "sha256", "dump", "dumps", "write", "save", "put"}


def _ancestors(node: ast.AST):
    while hasattr(node, "parent"):
        node = node.parent  # type: ignore[attr-defined]
        yield node


@register
class BuiltinHashRule(Rule):
    """D1 — builtin ``hash()``/``id()`` must not feed persisted keys,
    seeds, or cache filenames.

    ``hash(str)`` is salted per process by PYTHONHASHSEED and ``id()`` is
    an allocation address: both break cross-process determinism the
    moment they touch anything persisted or seeded.  Motivated by the
    PR 4 PowerSGD bug, where ``abs(hash(str(path)))`` seeded the Q sketch
    and two hosts silently compressed with *different* random bases —
    fixed to ``zlib.crc32`` (see ``distributed/compression.py``).
    ``hash()`` is flagged unconditionally (use ``zlib.crc32``/``hashlib``
    or a dict keyed on the object); ``id()`` only where it flows into a
    formatting/seeding/path sink, since identity-keyed host-side dicts
    are legitimate.
    """
    id = "D1"
    name = "builtin-hash-or-id"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)):
                continue
            if node.func.id == "hash":
                yield ctx.finding(
                    self, node,
                    "builtin hash() is PYTHONHASHSEED-salted; use "
                    "zlib.crc32/hashlib for anything persisted or seeded")
            elif node.func.id == "id" and self._flows_to_sink(node):
                yield ctx.finding(
                    self, node,
                    "id() is an allocation address; it must not flow into "
                    "persisted keys, seeds, or filenames")

    @staticmethod
    def _flows_to_sink(node: ast.Call) -> bool:
        for anc in _ancestors(node):
            if isinstance(anc, ast.stmt):
                return False
            if isinstance(anc, (ast.FormattedValue, ast.JoinedStr)):
                return True
            if isinstance(anc, ast.BinOp) and isinstance(anc.op, ast.Mod):
                return True        # "%s" % id(x)
            if isinstance(anc, ast.keyword) and anc.arg in _SINK_KWARGS:
                return True
            if isinstance(anc, ast.Call):
                fn = dotted_name(anc.func) or ""
                if fn.rsplit(".", 1)[-1] in _SINK_CALLS:
                    return True
        return False


@register
class WallClockRule(Rule):
    """D2 — no ``time.time()`` for latency/interval math; use
    ``time.perf_counter()`` (or ``monotonic``).

    ``time.time()`` is wall-clock: NTP slews and DST steps make deltas
    taken from it lie, and its resolution is platform-dependent.  PR 2
    already had to convert serving TTFT/ITL stamps to ``perf_counter``;
    this rule stops the next regression.  The rare *legitimate* epoch
    use (comparing against file mtimes, stamping absolute times into
    reports) takes an inline ``# dcomlint: disable=D2`` with a
    justification comment — see ``checkpoint.gc_old``.
    """
    id = "D2"
    name = "wall-clock-interval"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        from_time = {
            a.asname or a.name
            for node in ast.walk(ctx.tree)
            if isinstance(node, ast.ImportFrom) and node.module == "time"
            for a in node.names if a.name == "time"}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn == "time.time" or (fn in from_time if fn else False):
                yield ctx.finding(
                    self, node,
                    "time.time() is wall-clock; use time.perf_counter() "
                    "for intervals (suppress with a justification for "
                    "true epoch-time uses)")


@register
class AtomicWriteRule(Rule):
    """D3 — file writes must use the tmp + ``os.replace`` atomic pattern.

    A bare ``open(path, \"w\")`` truncates the destination first: a crash
    (or a concurrent reader) mid-write observes an empty/partial file.
    PR 4 fixed exactly this in ``ThresholdTable.save`` after a truncated
    threshold JSON took a serving run down; PR 9 found the same latent
    bug in every benchmark report writer.  Any function that opens a
    file for writing must also call ``os.replace``/``os.rename`` in the
    same scope (i.e. stage into a temp path), or — much better — go
    through ``repro.ioutil.atomic_write_text/json``.
    """
    id = "D3"
    name = "non-atomic-write"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "open"):
                continue
            mode = self._mode(node)
            if mode is None or not any(c in mode for c in "wx"):
                continue
            if self._scope_has_replace(node):
                continue
            yield ctx.finding(
                self, node,
                f"open(..., {mode!r}) without os.replace in scope — write "
                "through repro.ioutil.atomic_write_text/json (tmp + "
                "os.replace) so a crash never leaves a truncated file")

    @staticmethod
    def _mode(node: ast.Call) -> Optional[str]:
        if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant) \
                and isinstance(node.args[1].value, str):
            return node.args[1].value
        for kw in node.keywords:
            if kw.arg == "mode" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                return kw.value.value
        return None

    @staticmethod
    def _scope_has_replace(node: ast.AST) -> bool:
        scope: ast.AST = node
        for anc in _ancestors(node):
            scope = anc
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Module)):
                break
        for n in ast.walk(scope):
            if isinstance(n, ast.Call):
                fn = dotted_name(n.func) or ""
                if fn in ("os.replace", "os.rename"):
                    return True
        return False
