"""Family dispatch rule: F1 (family-table-complete)."""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from .core import Finding, ModuleCtx, Rule, register

# the registered dispatch points: the ModelFns table (models.api) and the
# ServingFamily registry (serving.families) — family keys are RESOLVED
# here, once, and everything downstream calls through the returned object
_DISPATCH_FNS = {"model_fns", "serving_family"}


def _is_family_key(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "family") \
        or (isinstance(node, ast.Name) and node.id == "family")


@register
class FamilyDispatchRule(Rule):
    """F1 — no per-family dict/if-chain dispatch in the serving engine or
    the model API outside the registered protocol tables.

    The PR 10 refactor exists because ad-hoc ``cfg.family`` branches
    drift: ``Engine._prefill_args`` grew a vlm/audio if-chain that
    duplicated what became ``ModelFns.prefill_inputs`` — a new family
    silently fell through to the dense arm (wrong prefill inputs, shape
    error at best) instead of failing at registration, and the same
    table had to be patched in two places (``models.api`` spec probes
    and the engine) to stay consistent.  The supported extension points
    are the ``ModelFns`` registry (``models.api.model_fns``) and the
    ``ServingFamily`` registry (``serving.families.serving_family``):
    inside those resolvers a family-keyed table lookup is the design;
    anywhere else in ``repro/serving/`` or ``repro/models/api.py`` a
    ``cfg.family`` comparison or subscript is a second dispatch table
    waiting to go stale.  ``assert cfg.family == ...`` guards are exempt
    — a loud constraint check is the opposite of silent drift.
    """
    id = "F1"
    name = "family-table-complete"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        if not (ctx.in_pkg("repro", "serving")
                or (ctx.in_pkg("repro", "models")
                    and ctx.parts[-1] == "api.py")):
            return
        for node in ast.walk(ctx.tree):
            use = self._family_dispatch(node)
            if use is None or self._exempt(node):
                continue
            yield ctx.finding(
                self, node,
                f"per-family {use} outside the registered dispatch "
                "tables — register a ServingFamily "
                "(serving.families) or extend the ModelFns entry "
                "(models.api) instead of branching on cfg.family")

    @staticmethod
    def _family_dispatch(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Compare):
            if _is_family_key(node.left) \
                    or any(_is_family_key(c) for c in node.comparators):
                return "comparison"
        elif isinstance(node, ast.Subscript):
            if _is_family_key(node.slice):
                return "table lookup"
        return None

    @staticmethod
    def _exempt(node: ast.AST) -> bool:
        cur = node
        while cur is not None:
            if isinstance(cur, ast.Assert):
                return True          # loud guard, not silent dispatch
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and cur.name in _DISPATCH_FNS:
                return True          # inside a registered resolver
            cur = getattr(cur, "parent", None)
        return False
