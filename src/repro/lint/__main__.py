"""``python -m repro.lint`` — run dcomlint over source trees.

Exit codes: 0 clean, 1 findings, 2 usage error.  ``--json`` writes the
machine-readable report (the CI artifact) atomically; human output goes
to stdout either way.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import (all_rules, dump_report, render_human, report_json,
               run_paths)


def _split(ids: Optional[str]) -> Optional[List[str]]:
    return [s.strip() for s in ids.split(",") if s.strip()] if ids else None


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="dcomlint: repo-specific determinism/donation/kernel "
                    "invariant checks (DESIGN.md §14)")
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                    help="files or directories to lint "
                         "(default: src benchmarks)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON report artifact here")
    ap.add_argument("--select", metavar="IDS", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--ignore", metavar="IDS", default=None,
                    help="comma-separated rule ids to skip")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.name}  [{rule.severity}]")
            doc = rule.doc()
            for line in doc.splitlines():
                print(f"    {line}")
            print()
        return 0

    try:
        findings, suppressed, nfiles = run_paths(
            args.paths, select=_split(args.select),
            ignore=_split(args.ignore))
    except (ValueError, OSError) as e:
        print(f"dcomlint: error: {e}", file=sys.stderr)
        return 2

    report = report_json(findings, suppressed, nfiles)
    if args.json:
        dump_report(args.json, report)
    print(render_human(findings, suppressed, nfiles))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
