"""Pallas kernel invariants P1: every ``pl.pallas_call`` site must plumb
``interpret`` from the platform, match index_map arity to grid rank, and
guard block-divisibility."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from .core import Finding, ModuleCtx, Rule, dotted_name, register


def _enclosing_scope(node: ast.AST) -> ast.AST:
    while hasattr(node, "parent"):
        node = node.parent  # type: ignore[attr-defined]
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Module)):
            return node
    return node


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _grid_rank(grid: ast.AST) -> Optional[int]:
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    if isinstance(grid, ast.Constant) and isinstance(grid.value, int):
        return 1
    return None          # dynamic expression — rank unknown statically


def _block_specs(node: ast.AST) -> List[ast.Call]:
    """All BlockSpec(...) constructor calls under ``node``."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            fn = dotted_name(n.func) or ""
            if fn.rsplit(".", 1)[-1] == "BlockSpec":
                out.append(n)
    return out


def _divisibility_guards(scope: ast.AST) -> Set[Tuple[str, str]]:
    """(numerator, denominator) name pairs proven divisible in ``scope``:
    ``assert X % Y == 0`` or ``Y = _block_divisor(X, ...)``."""
    guards: Set[Tuple[str, str]] = set()
    for n in ast.walk(scope):
        if isinstance(n, ast.Assert):
            t = n.test
            if isinstance(t, ast.Compare) and len(t.ops) == 1 \
                    and isinstance(t.ops[0], ast.Eq) \
                    and isinstance(t.left, ast.BinOp) \
                    and isinstance(t.left.op, ast.Mod) \
                    and isinstance(t.comparators[0], ast.Constant) \
                    and t.comparators[0].value == 0:
                x = dotted_name(t.left.left)
                y = dotted_name(t.left.right)
                if x and y:
                    guards.add((x, y))
        elif isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
            fn = (dotted_name(n.value.func) or "").rsplit(".", 1)[-1]
            if fn in ("_block_divisor", "block_divisor") and n.value.args:
                x = dotted_name(n.value.args[0])
                for tgt in n.targets:
                    y = dotted_name(tgt)
                    if x and y:
                        guards.add((x, y))
    return guards


@register
class PallasCallRule(Rule):
    """P1 — Pallas launch-site invariants, distilled from the PR 1/PR 3
    kernel work:

    * ``interpret=`` must be present and *plumbed* (a variable resolved
      via ``engine.platform.resolve_interpret``), never a hardcoded
      bool — the pre-PR-3 kernels defaulted ``interpret=True`` and a
      TPU deployment had to override every call site by hand;
    * every ``BlockSpec`` index_map lambda takes exactly ``len(grid)``
      indices (plus ``num_scalar_prefetch`` leading refs under a
      ``PrefetchScalarGridSpec``) — an arity mismatch is a TypeError at
      trace time *only* on the first unlucky shape that reaches it;
    * a ``X // Y`` grid dimension needs a divisibility guard in scope
      (``assert X % Y == 0`` or ``Y = _block_divisor(X, ...)``) — an
      unguarded remainder silently drops tail rows (the PR 3
      arbitrary-cache-length bug class).
    """
    id = "P1"
    name = "pallas-call-invariants"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func) or ""
            if fn.rsplit(".", 1)[-1] != "pallas_call":
                continue
            yield from self._check_interpret(ctx, node)
            yield from self._check_arity(ctx, node)
            yield from self._check_divisibility(ctx, node)

    # -- interpret plumbing -------------------------------------------------
    def _check_interpret(self, ctx: ModuleCtx, call: ast.Call):
        v = _kw(call, "interpret")
        if v is None:
            yield ctx.finding(
                self, call, "pallas_call without interpret= — plumb the "
                "platform default via engine.platform.resolve_interpret")
        elif isinstance(v, ast.Constant):
            yield ctx.finding(
                self, v, f"interpret={v.value!r} hardcoded — resolve it "
                "via engine.platform.resolve_interpret so TPU and CPU "
                "deployments share one call site")

    # -- index_map arity vs grid rank ----------------------------------------
    def _check_arity(self, ctx: ModuleCtx, call: ast.Call):
        rank: Optional[int] = None
        prefetch = 0
        spec_holders: List[ast.AST] = []
        grid = _kw(call, "grid")
        if grid is not None:
            rank = _grid_rank(grid)
            spec_holders.append(call)
        gs = _kw(call, "grid_spec")
        if gs is not None:
            ctor = self._resolve_grid_spec(call, gs)
            if ctor is not None:
                g = _kw(ctor, "grid")
                rank = _grid_rank(g) if g is not None else None
                np_ = _kw(ctor, "num_scalar_prefetch")
                if isinstance(np_, ast.Constant) \
                        and isinstance(np_.value, int):
                    prefetch = np_.value
                spec_holders.append(ctor)
        if rank is None:
            return
        want = rank + prefetch
        for holder in spec_holders:
            for spec in self._specs_of(holder):
                idx_map = spec.args[1] if len(spec.args) > 1 \
                    else _kw(spec, "index_map")
                if not isinstance(idx_map, ast.Lambda):
                    continue
                got = len(idx_map.args.args)
                if got != want:
                    yield ctx.finding(
                        self, idx_map,
                        f"BlockSpec index_map takes {got} args but the "
                        f"grid has rank {rank}"
                        + (f" + {prefetch} scalar-prefetch ref(s)"
                           if prefetch else "")
                        + f" — expected {want}")

    @staticmethod
    def _specs_of(holder: ast.AST) -> List[ast.Call]:
        specs: List[ast.Call] = []
        if isinstance(holder, ast.Call):
            for name in ("in_specs", "out_specs"):
                v = _kw(holder, name)
                if v is not None:
                    specs.extend(_block_specs(v))
        return specs

    @staticmethod
    def _resolve_grid_spec(call: ast.Call,
                           gs: ast.AST) -> Optional[ast.Call]:
        """grid_spec= value: inline constructor, or a Name assigned from
        one in the enclosing scope."""
        if isinstance(gs, ast.Call):
            return gs
        if not isinstance(gs, ast.Name):
            return None
        scope = _enclosing_scope(call)
        for n in ast.walk(scope):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
                for tgt in n.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == gs.id:
                        return n.value
        return None

    # -- grid divisibility guards --------------------------------------------
    def _check_divisibility(self, ctx: ModuleCtx, call: ast.Call):
        grid = _kw(call, "grid")
        holders: List[ast.AST] = [grid] if grid is not None else []
        gs = _kw(call, "grid_spec")
        if gs is not None:
            ctor = self._resolve_grid_spec(call, gs)
            if ctor is not None:
                g = _kw(ctor, "grid")
                if g is not None:
                    holders.append(g)
        if not holders:
            return
        scope = _enclosing_scope(call)
        guards = _divisibility_guards(scope)
        for holder in holders:
            elts = holder.elts if isinstance(
                holder, (ast.Tuple, ast.List)) else [holder]
            for e in elts:
                if isinstance(e, ast.BinOp) \
                        and isinstance(e.op, ast.FloorDiv):
                    x = dotted_name(e.left)
                    y = dotted_name(e.right)
                    if x and y and (x, y) not in guards:
                        yield ctx.finding(
                            self, e,
                            f"grid dimension {x} // {y} has no "
                            f"divisibility guard in scope — add "
                            f"`assert {x} % {y} == 0` or derive {y} via "
                            "_block_divisor so tail rows can't be "
                            "silently dropped")
