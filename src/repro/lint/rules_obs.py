"""Observability neutrality rule O1: obs stays host-side, and no obs
call ever runs inside a traced function body."""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from .core import Finding, ModuleCtx, Rule, dotted_name, register

_TRACED_WRAPPERS = {"jax.jit", "jit", "pjit", "jax.pjit", "shard_map"}
# engine-attribute roots that reach the obs layer from serving code
_OBS_ATTR_ROOTS = ("self.obs", "self.trace", "self.tracer", "self.stats")


def _is_traced_wrapper(fn: str) -> bool:
    return fn in _TRACED_WRAPPERS or fn.rsplit(".", 1)[-1] == "shard_map"


def collect_traced_bodies(ctx: ModuleCtx) -> List[ast.AST]:
    """Function/lambda nodes that are jitted or shard_mapped in this
    module: first positional arg of a jit/shard_map call (Name resolved
    within the enclosing scope, or an inline Lambda), plus defs
    decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)``."""
    traced: List[ast.AST] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and node.args:
            fn = dotted_name(node.func)
            if fn and _is_traced_wrapper(fn):
                first = node.args[0]
                if isinstance(first, ast.Lambda):
                    traced.append(first)
                elif isinstance(first, ast.Name):
                    traced.extend(_defs_named(ctx, node, first.id))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dotted_name(dec)
                if d and _is_traced_wrapper(d):
                    traced.append(node)
                elif isinstance(dec, ast.Call):
                    dfn = dotted_name(dec.func) or ""
                    if dfn.rsplit(".", 1)[-1] == "partial" and dec.args:
                        inner = dotted_name(dec.args[0])
                        if inner and _is_traced_wrapper(inner):
                            traced.append(node)
    return traced


def _defs_named(ctx: ModuleCtx, call: ast.AST, name: str) -> List[ast.AST]:
    scope: ast.AST = call
    while hasattr(scope, "parent") and not isinstance(
            scope, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
        scope = scope.parent  # type: ignore[attr-defined]
    return [n for n in ast.walk(scope)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name == name]


@register
class ObsNeutralityRule(Rule):
    """O1 — observability is host-side only: ``repro/obs/`` modules must
    not import ``jax.numpy``, and serving code must not call the obs API
    inside a jitted/shard_mapped function body.

    The PR 8 hard rule — conformance-gated at runtime by
    ``test_observability_is_token_neutral`` — is that tokens are
    byte-identical with obs on or off.  That only holds if (a) the obs
    layer never computes on device (a ``jnp`` op in a histogram changes
    dispatch order), and (b) no span/counter call lands inside a traced
    body, where it would either fail tracing or — worse — bake a
    tracer-time value into the compiled program.  This rule makes the
    runtime gate's precondition a static guarantee.
    """
    id = "O1"
    name = "obs-token-neutral"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        if ctx.in_pkg("repro", "obs"):
            yield from self._check_obs_purity(ctx)
        if ctx.in_pkg("repro", "serving"):
            yield from self._check_no_obs_in_traced(ctx)

    def _check_obs_purity(self, ctx: ModuleCtx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax.numpy" or a.name.startswith(
                            "jax.numpy."):
                        yield ctx.finding(
                            self, node, "repro.obs must stay host-side: "
                            "importing jax.numpy pulls device compute "
                            "into the observability layer")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == "jax.numpy" or mod.startswith("jax.numpy."):
                    yield ctx.finding(
                        self, node, "repro.obs must stay host-side: "
                        "importing from jax.numpy pulls device compute "
                        "into the observability layer")
                elif mod == "jax" and any(a.name == "numpy"
                                          for a in node.names):
                    yield ctx.finding(
                        self, node, "repro.obs must stay host-side: "
                        "`from jax import numpy` pulls device compute "
                        "into the observability layer")
            elif isinstance(node, ast.Attribute):
                if dotted_name(node) == "jax.numpy":
                    yield ctx.finding(
                        self, node, "repro.obs must stay host-side: "
                        "jax.numpy use in the observability layer")

    def _check_no_obs_in_traced(self, ctx: ModuleCtx):
        obs_names = self._obs_imports(ctx)
        seen: Set[int] = set()
        for body in collect_traced_bodies(ctx):
            for n in ast.walk(body):
                d = dotted_name(n) if isinstance(
                    n, (ast.Name, ast.Attribute)) else None
                if d is None or id(n) in seen:
                    continue
                root = d.split(".")[0]
                hit = (root in obs_names
                       or any(d == r or d.startswith(r + ".")
                              for r in _OBS_ATTR_ROOTS))
                if hit:
                    seen.add(id(n))
                    for ch in ast.walk(n):
                        seen.add(id(ch))
                    yield ctx.finding(
                        self, n, f"obs API {d!r} inside a jitted/traced "
                        "function body — instrumentation must stay on "
                        "the host side of every dispatch")

    @staticmethod
    def _obs_imports(ctx: ModuleCtx) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.endswith("obs") or ".obs." in mod \
                        or mod.startswith("obs."):
                    for a in node.names:
                        names.add(a.asname or a.name)
        return names
