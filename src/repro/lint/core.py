"""dcomlint core: findings, rule registry, suppressions, file runner.

The analyzer is a thin harness around per-rule AST visitors:

* a **rule** is a class with an ``id`` (``"D1"``), a human ``name``, a
  ``severity`` and a ``check(ctx)`` generator yielding :class:`Finding`s;
* :func:`register` adds it to the process-wide registry consumed by the
  CLI (``python -m repro.lint``) and the test suite;
* inline ``# dcomlint: disable=D1[,D2|all]`` comments suppress findings
  on that physical line; a ``# dcomlint: disable-file=D1`` anywhere in
  the file suppresses the rule for the whole module.  Suppressions are
  *counted* (they appear in the JSON report) so a creeping pile of
  disables is visible in CI artifacts.

Rules never import jax — they parse source text only, so the linter runs
in milliseconds and anywhere (pre-commit, CI, a TPU-less laptop).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

SCHEMA = "repro.lint/v1"

_SUPPRESS_RE = re.compile(
    r"#\s*dcomlint:\s*disable(?P<scope>-file)?=(?P<rules>[A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    path: str
    line: int
    col: int
    rule: str
    severity: str
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.severity}: {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class ModuleCtx:
    """Parsed module handed to every rule: AST (parent-annotated), raw
    lines, and package-path helpers used for module allowlists."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]
        norm = path.replace(os.sep, "/")
        self.parts: Tuple[str, ...] = tuple(
            p for p in norm.split("/") if p not in ("", "."))

    def in_pkg(self, *names: str) -> bool:
        """True when ``names`` appear as consecutive path components,
        e.g. ``ctx.in_pkg("repro", "obs")`` for anything under the obs
        package (works for ``src/repro/obs/x.py`` and fixture trees)."""
        n = len(names)
        return any(self.parts[i:i + n] == names
                   for i in range(len(self.parts) - n + 1))

    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0) + 1,
                       rule.id, rule.severity, message)


class Rule:
    """Base class: subclasses set ``id``/``name`` and implement ``check``.

    The docstring of each concrete rule is its catalog entry (rendered by
    ``--list-rules`` and DESIGN.md §14) and must cite the bug or PR that
    motivated it.
    """
    id: str = ""
    name: str = ""
    severity: str = "error"

    def check(self, ctx: ModuleCtx) -> Iterator[Finding]:
        raise NotImplementedError

    @classmethod
    def doc(cls) -> str:
        return (cls.__doc__ or "").strip()


REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding one rule instance to the registry."""
    if not cls.id or cls.id in REGISTRY:
        raise ValueError(f"rule id {cls.id!r} missing or duplicate")
    REGISTRY[cls.id] = cls()
    return cls


def all_rules() -> List[Rule]:
    return [REGISTRY[k] for k in sorted(REGISTRY)]


# -- suppressions ------------------------------------------------------------

def parse_suppressions(lines: Sequence[str]):
    """→ (``{lineno: {rule,...}}``, ``{rule,...}`` file-wide).  ``all``
    suppresses every rule for that line/file."""
    per_line: Dict[int, set] = {}
    per_file: set = set()
    for i, raw in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        if m.group("scope"):
            per_file |= rules
        else:
            per_line.setdefault(i, set()).update(rules)
    return per_line, per_file


def _suppressed(f: Finding, per_line, per_file) -> bool:
    if "all" in per_file or f.rule in per_file:
        return True
    rules = per_line.get(f.line, ())
    return "all" in rules or f.rule in rules


# -- runner ------------------------------------------------------------------

def iter_py_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__" and not d.startswith("."))
            for fn in sorted(files):
                if fn.endswith(".py"):
                    yield os.path.join(root, fn)


def check_file(path: str, rules: Optional[Iterable[Rule]] = None,
               text: Optional[str] = None
               ) -> Tuple[List[Finding], List[Finding]]:
    """Lint one file → (active findings, suppressed findings).

    A syntax error is itself reported as a finding (rule ``E0``) rather
    than crashing the run — CI must fail loudly on an unparsable file.
    """
    if text is None:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
    try:
        ctx = ModuleCtx(path, text)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, (e.offset or 0) + 1, "E0",
                        "error", f"syntax error: {e.msg}")], []
    per_line, per_file = parse_suppressions(ctx.lines)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for rule in (all_rules() if rules is None else rules):
        for f in rule.check(ctx):
            (suppressed if _suppressed(f, per_line, per_file)
             else active).append(f)
    key = (lambda f: (f.line, f.col, f.rule))
    return sorted(active, key=key), sorted(suppressed, key=key)


def run_paths(paths: Sequence[str], select: Optional[Sequence[str]] = None,
              ignore: Optional[Sequence[str]] = None):
    """Lint every ``.py`` under ``paths`` → (findings, suppressed, nfiles).

    ``select``/``ignore`` filter by rule id; unknown ids raise so a typo
    in CI config can't silently disable a gate.
    """
    rules = all_rules()
    for rid in list(select or []) + list(ignore or []):
        if rid not in REGISTRY:
            raise ValueError(f"unknown rule id {rid!r} "
                             f"(have {sorted(REGISTRY)})")
    if select:
        rules = [r for r in rules if r.id in set(select)]
    if ignore:
        rules = [r for r in rules if r.id not in set(ignore)]
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    nfiles = 0
    for path in iter_py_files(paths):
        nfiles += 1
        a, s = check_file(path, rules)
        findings.extend(a)
        suppressed.extend(s)
    return findings, suppressed, nfiles


def report_json(findings: Sequence[Finding], suppressed: Sequence[Finding],
                nfiles: int) -> dict:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "schema": SCHEMA,
        "files": nfiles,
        "findings": [f.to_json() for f in findings],
        "suppressed": [f.to_json() for f in suppressed],
        "counts": counts,
        "ok": not findings,
    }


def render_human(findings: Sequence[Finding], suppressed: Sequence[Finding],
                 nfiles: int) -> str:
    out = [f.render() for f in findings]
    out.append(f"dcomlint: {len(findings)} finding"
               f"{'' if len(findings) == 1 else 's'} "
               f"({len(suppressed)} suppressed) in {nfiles} files")
    return "\n".join(out)


def dump_report(path: str, report: dict) -> None:
    # dogfood: the linter's own artifact write is atomic (rule D3)
    from ..ioutil import atomic_write_json
    atomic_write_json(path, report, indent=2, sort_keys=True)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
