"""Tokenized data pipeline: synthetic stream + memmap shards, per-host
sharding, background prefetch, deterministic resume.

Design: every batch is a pure function of (seed, step) — ``state = step``
is the entire pipeline state, so checkpoint/restart and elastic re-sharding
are trivial (the restored step replays exactly the same stream), and any
host can compute any shard (straggler re-assignment needs no data motion).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    pad_id: int = 0


class SyntheticLM:
    """Deterministic synthetic LM stream (markov-ish so loss can decrease).

    tokens[t+1] depends on tokens[t] through a fixed random permutation with
    noise — a learnable but non-trivial distribution for the end-to-end
    training example.
    """

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, data: DataConfig):
        self.cfg, self.shape, self.data = cfg, shape, data
        rng = np.random.RandomState(1234)
        self.perm = rng.permutation(cfg.vocab)
        assert shape.global_batch % data.num_hosts == 0
        self.host_batch = shape.global_batch // data.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step (and host) — the resume guarantee."""
        rng = np.random.RandomState(
            ((self.data.seed * 1_000_003 + step) * 4096
             + self.data.host_id) % (2 ** 32))
        b, s, v = self.host_batch, self.shape.seq_len, self.cfg.vocab
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.randint(0, v, b)
        noise = rng.rand(b, s) < 0.1
        rand_tok = rng.randint(0, v, (b, s))
        for t in range(1, s):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        batch = {"tokens": toks[:, :-1].copy() if False else toks,
                 "labels": np.roll(toks, -1, axis=1)}
        batch["labels"][:, -1] = -1          # ignore final position
        if self.cfg.family == "vlm":
            batch["image_embeds"] = rng.randn(
                b, self.cfg.num_image_tokens, self.cfg.d_model
            ).astype(np.float32) * 0.02
        if self.cfg.family == "audio":
            batch["frames"] = rng.randn(b, s, self.cfg.d_model) \
                .astype(np.float32) * 0.02
        return batch


class MemmapShards:
    """Pre-tokenized corpus in .npy shards; host h reads rows ≡ h (mod H).

    Same (seed, step) determinism: the row index set for a step is computed,
    never iterated statefully.
    """

    def __init__(self, paths, cfg: ArchConfig, shape: ShapeSpec,
                 data: DataConfig):
        self.mm = [np.load(p, mmap_mode="r") for p in paths]
        self.rows = sum(m.shape[0] for m in self.mm)
        self.offsets = np.cumsum([0] + [m.shape[0] for m in self.mm])
        self.cfg, self.shape, self.data = cfg, shape, data
        self.host_batch = shape.global_batch // data.num_hosts

    def _row(self, i: int) -> np.ndarray:
        shard = int(np.searchsorted(self.offsets, i, "right") - 1)
        return np.asarray(self.mm[shard][i - self.offsets[shard]])

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.data.seed * 1_000_003 + step) % (2 ** 32))
        idx = rng.randint(0, self.rows, self.shape.global_batch)
        mine = idx[self.data.host_id::self.data.num_hosts][:self.host_batch]
        toks = np.stack([self._row(i)[:self.shape.seq_len] for i in mine]) \
            .astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        return {"tokens": toks, "labels": labels}


class Prefetcher:
    """Background thread computing batch(step+1..step+depth) ahead."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            self.q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
