"""Atomic file-write helpers — the one sanctioned way to persist artifacts.

Every durable file this repo writes (tuner cache, threshold tables,
checkpoint manifests, benchmark reports, metrics/trace exports) must land
atomically: stage into a temp file in the *destination directory* (same
filesystem, so the rename is atomic) and ``os.replace`` over the final
path.  A crash mid-write then leaves either the previous file or the new
one on disk — never a truncated JSON that a later reader half-parses.

This module exists because the pattern was re-implemented (and twice
re-broken: the pre-PR-4 ``ThresholdTable.save``, the pre-PR-9 benchmark
report writers) at every call site.  ``repro.lint`` rule D3 now rejects a
bare ``open(path, "w")`` that is not part of a tmp+``os.replace`` dance,
so new persistence code is pushed here by construction.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any


def atomic_write_text(path: str, text: str) -> None:
    """Write ``text`` to ``path`` atomically (tmp file + ``os.replace``)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".atomic-", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: str, obj: Any, **dump_kw: Any) -> None:
    """``json.dump(obj)`` to ``path`` atomically.  ``dump_kw`` forwards to
    ``json.dumps`` (``indent``, ``sort_keys``, ...)."""
    atomic_write_text(path, json.dumps(obj, **dump_kw))
