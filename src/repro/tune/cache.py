"""Persistent tuning cache: pay the measurement cost once per machine.

One JSON file maps ``device_kind × kernel × shape-bucket × dtype`` to the
winning operating point plus the measured sweep that chose it.  Shape
buckets round every dimension up to a power of two, so a serving engine
whose prompt lengths wander within a bucket reuses one entry (the same
bucketing philosophy as the serving scheduler's prefill buckets).

Layers:

* **in-process**: entries live in a plain dict after first read; the
  tuner's ``tuned_expansion`` adds an ``lru_cache`` on top so the engine's
  per-decompose resolution is a hash lookup.
* **on disk**: ``REPRO_TUNE_CACHE`` (env) or ``~/.cache/repro-tune/
  cache.json``.  Writes are atomic (tmp + rename) and merge-on-save, so
  concurrent processes at worst re-measure, never corrupt.  A missing or
  unreadable file is an empty cache, never an error.

The file doubles as the CI artifact emitted by ``benchmarks/run.py
--tune``.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Sequence

_SCHEMA = 1


def default_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-tune",
                        "cache.json")


def shape_bucket(shape: Sequence[int]) -> tuple:
    """Round every dim up to a power of two (1 stays 1)."""
    return tuple(1 << max(0, int(n) - 1).bit_length() for n in shape)


def entry_key(device_kind: str, kernel: str, shape: Sequence[int],
              dtype: Any) -> str:
    bucket = "x".join(str(n) for n in shape_bucket(shape))
    return f"{device_kind}/{kernel}/{bucket}/{dtype}"


class TuningCache:
    """Dict-like view over one cache file (lazy load, atomic save)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_path()
        self._entries: Optional[Dict[str, Dict[str, Any]]] = None

    # -- persistence -------------------------------------------------------
    def _load(self) -> Dict[str, Dict[str, Any]]:
        if self._entries is None:
            self._entries = self._read_file()
        return self._entries

    def _read_file(self) -> Dict[str, Dict[str, Any]]:
        try:
            with open(self.path) as fh:
                data = json.load(fh)
            if data.get("schema") != _SCHEMA:
                return {}
            entries = data.get("entries", {})
            return entries if isinstance(entries, dict) else {}
        except (OSError, ValueError):
            return {}

    def save(self) -> None:
        """Atomic merge-save: re-read the file and overlay our entries, so
        two processes tuning different kernels both land."""
        entries = dict(self._read_file())
        entries.update(self._load())
        payload = {"schema": _SCHEMA, "entries": entries}
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- dict-ish API ------------------------------------------------------
    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self._load().get(key)

    def put(self, key: str, entry: Dict[str, Any]) -> None:
        self._load()[key] = entry

    def __len__(self) -> int:
        return len(self._load())

    def keys(self):
        return self._load().keys()

    def as_dict(self) -> Dict[str, Dict[str, Any]]:
        return dict(self._load())


_DEFAULT: Optional[TuningCache] = None


def default_cache() -> TuningCache:
    """Process-wide cache instance bound to :func:`default_path`.

    Re-resolved when the path changes (tests point ``REPRO_TUNE_CACHE`` at
    a tmpdir); otherwise one instance serves the whole process so the
    in-memory layer actually caches.
    """
    global _DEFAULT
    path = default_path()
    if _DEFAULT is None or _DEFAULT.path != path:
        _DEFAULT = TuningCache(path)
    return _DEFAULT
