"""Tuner orchestration: space → cost model → (optional) measurement → cache.

``tune`` is the one entry point:

1. **cache** — a persistent entry for (device_kind, kernel, shape-bucket,
   dtype, pinned params) short-circuits everything; tuning cost is paid
   once per machine.
2. **model** — the roofline cost model scores every feasible candidate and
   either answers directly (``measure=False`` — deterministic, O(grid)
   arithmetic, what the engine uses at build/decompose time) or prunes the
   grid to the ``prune`` most promising points.
3. **measure** — survivors are timed by ``measure.measure_candidate``
   (jit warmup + median-of-k); the winner is persisted so step 1 hits next
   time.

``tuned_expansion`` adds the in-process lru layer the engine resolves
``expansion="auto"`` through, and ``resolve_backend`` answers
``backend="auto"`` (cache override → platform heuristic).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from . import cost_model, measure
from ..obs import GLOBAL as _OBS
from .cache import TuningCache, default_cache, entry_key, shape_bucket
from .space import TunableSpace, get_space

#: Production prune width: how many model-ranked candidates a measured
#: tune benchmarks.  One constant so the fig12 A/B replays EXACTLY the
#: pruning the shipped tuner uses.
DEFAULT_PRUNE = 4


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning query (also the shape of a cache entry)."""
    kernel: str
    shape: Tuple[int, ...]               # bucketed shape the entry covers
    dtype: str
    key: str
    best: Dict[str, Any]
    source: str                          # "cache" | "model" | "measured"
    predicted_s: float
    measured_s: Optional[float]
    #: full sweep: (candidate, predicted_s, measured_s-or-None)
    table: Tuple[Tuple[Dict[str, Any], float, Optional[float]], ...]

    def swept_optimum(self) -> Tuple[Dict[str, Any], float]:
        """(candidate, seconds) minimizing the measured column (predicted
        where no measurement exists)."""
        rows = [(c, m if m is not None else p) for c, p, m in self.table]
        return min(rows, key=lambda r: r[1])


def _variant(fix: Optional[Mapping[str, Any]]) -> str:
    if not fix:
        return "-"
    return ",".join(f"{k}={fix[k]}" for k in sorted(fix))


def _feasible(cand: Mapping[str, Any],
              pinned: frozenset = frozenset()) -> bool:
    """Drop operating points this process cannot run (the compiled Mosaic
    backend needs a real TPU).  Pinned params are exempt: an explicitly
    configured backend is the caller's choice — resolution must still
    answer (the engine may be constructed on a CPU host for a TPU
    deployment)."""
    if "backend" not in pinned and cand.get("backend") == "pallas":
        import jax
        return jax.default_backend() == "tpu"
    return True


def candidates_for(kernel: str, fix: Optional[Mapping[str, Any]] = None
                   ) -> Tuple[Dict[str, Any], ...]:
    """Feasible candidate grid of ``kernel`` with ``fix`` params pinned
    (pinned values need not be in the declared choices — the engine may pin
    e.g. an exotic backend)."""
    space: TunableSpace = get_space(kernel)
    fix = dict(fix or {})
    pinned = frozenset(fix)
    out = []
    seen = set()
    for cand in space.candidates():
        cand.update(fix)
        key = tuple(sorted(cand.items()))
        if key in seen:
            continue
        seen.add(key)
        if _feasible(cand, pinned):
            out.append(cand)
    return tuple(out)


def _from_entry(key: str, entry: Mapping[str, Any]) -> TuneResult:
    table = tuple((dict(r["params"]), float(r["predicted_s"]),
                   None if r.get("measured_s") is None
                   else float(r["measured_s"]))
                  for r in entry.get("table", ()))
    return TuneResult(kernel=entry["kernel"], shape=tuple(entry["shape"]),
                      dtype=entry["dtype"], key=key,
                      best=dict(entry["best"]), source="cache",
                      predicted_s=float(entry["predicted_s"]),
                      measured_s=entry.get("measured_s"), table=table)


def _to_entry(res: TuneResult) -> Dict[str, Any]:
    return {"kernel": res.kernel, "shape": list(res.shape),
            "dtype": res.dtype, "best": dict(res.best),
            "source": res.source, "predicted_s": res.predicted_s,
            "measured_s": res.measured_s,
            "table": [{"params": dict(c), "predicted_s": p,
                       "measured_s": m} for c, p, m in res.table]}


def tune(kernel: str, shape: Sequence[int], dtype: Any = "float32", *,
         fix: Optional[Mapping[str, Any]] = None, measure_candidates:
         bool = False, prune: Optional[int] = DEFAULT_PRUNE, reps: int = 5,
         device: Optional[cost_model.DeviceModel] = None,
         cache: Optional[TuningCache] = None, force: bool = False,
         persist: Optional[bool] = None) -> TuneResult:
    """Pick the operating point of ``kernel`` for ``shape``/``dtype``.

    ``measure_candidates=False`` (default) answers from cache or pure cost
    model — cheap enough for the engine's build/decompose path.  With
    ``measure_candidates=True`` the model-ranked top ``prune`` candidates
    (None = all) are benchmarked and the winner persisted.  ``fix`` pins
    params (the engine pins its backend); ``force`` ignores the cache.
    """
    cache = cache if cache is not None else default_cache()
    dev = device or cost_model.detect_device()
    bucket = shape_bucket(shape)
    dt = str(dtype)
    key = entry_key(cost_model.device_kind(), kernel, shape, dt) \
        + "/" + _variant(fix)

    if not force:
        entry = cache.get(key)
        if entry is not None and (entry.get("measured_s") is not None
                                  or not measure_candidates):
            _OBS.counter("tune_resolutions_total",
                         "tuner queries by answer source",
                         kernel=kernel, source="cache").inc()
            return _from_entry(key, entry)

    cands = candidates_for(kernel, fix)
    if not cands:
        raise ValueError(f"no feasible candidate for kernel {kernel!r} "
                         f"with fix={dict(fix or {})!r}")
    scored = sorted(
        ((c, cost_model.predict(kernel, bucket, dt, c, dev))
         for c in cands), key=lambda cp: cp[1])

    if measure_candidates:
        top = scored if prune is None else scored[:max(1, prune)]
        table = tuple(
            (c, p, measure.measure_candidate(kernel, bucket, dtype, c,
                                             reps=reps))
            for c, p in top)
        best, pred, meas = min(table, key=lambda r: r[2])
        res = TuneResult(kernel, bucket, dt, key, dict(best), "measured",
                         pred, meas, table)
    else:
        best, pred = scored[0]
        table = tuple((c, p, None) for c, p in scored)
        res = TuneResult(kernel, bucket, dt, key, dict(best), "model",
                         pred, None, table)

    _OBS.counter("tune_resolutions_total",
                 "tuner queries by answer source",
                 kernel=kernel, source=res.source).inc()
    cache.put(key, _to_entry(res))
    if persist if persist is not None else measure_candidates:
        cache.save()
    return res


# ---------------------------------------------------------------------------
# Engine-facing resolution (the in-process lru layer)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _tuned_expansion(kernel: str, bucket: Tuple[int, ...], dtype: str,
                     backend: Optional[str], cache_path: str) -> int:
    _OBS.counter("tune_lru_misses_total",
                 "in-process tuner lru misses", kernel=kernel).inc()
    fix = {"backend": backend} if backend is not None else None
    res = tune(kernel, bucket, dtype, fix=fix)
    return int(res.best["expansion"])


def tuned_expansion(shape: Sequence[int], dtype: Any = "float32",
                    backend: Optional[str] = None,
                    kernel: str = "lanczos_reorth") -> int:
    """The expansion factor f the engine should run ``kernel`` at for this
    shape-bucket — cache/model resolution behind an in-process lru (keyed
    on the cache path so tests pointing ``REPRO_TUNE_CACHE`` elsewhere
    don't see stale answers)."""
    _OBS.counter("tune_lru_lookups_total",
                 "in-process tuner lru lookups", kernel=kernel).inc()
    return _tuned_expansion(kernel, shape_bucket(shape), str(dtype),
                            backend, default_cache().path)


@functools.lru_cache(maxsize=None)
def _tuned_decode_block(bucket: Tuple[int, ...], dtype: str,
                        cache_path: str) -> int:
    _OBS.counter("tune_lru_misses_total",
                 "in-process tuner lru misses", kernel="decode_block").inc()
    res = tune("decode_block", bucket, dtype)
    return int(res.best["block"])


def tuned_decode_block(shape: Sequence[int], dtype: Any = "float32") -> int:
    """The fused decode-block length N the serving engine should run for
    this (slots, decode horizon, kv width) bucket — answers the engine's
    ``decode_block="auto"`` the same way ``tuned_expansion`` answers
    ``expansion="auto"``."""
    _OBS.counter("tune_lru_lookups_total",
                 "in-process tuner lru lookups", kernel="decode_block").inc()
    return _tuned_decode_block(shape_bucket(shape), str(dtype),
                               default_cache().path)


_BACKEND_KEY_SUFFIX = "engine_backend"


def _backend_key() -> str:
    return f"{cost_model.device_kind()}/{_BACKEND_KEY_SUFFIX}"


def resolve_backend(cache: Optional[TuningCache] = None) -> str:
    """Answer ``backend="auto"``: a measured cache override if
    :func:`tune_backend` ran on this machine, else the platform heuristic
    (compiled Mosaic on TPU; the jnp reference path on CPU, where Pallas
    interpret mode is an emulation and never wins)."""
    cache = cache if cache is not None else default_cache()
    entry = cache.get(_backend_key())
    if entry:
        name = entry.get("best", {}).get("backend")
        from ..engine.backends import available_backends
        if name in available_backends():
            return name
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def tune_backend(shape: Sequence[int] = (4, 256, 512),
                 dtype: Any = "float32", *, reps: int = 5,
                 cache: Optional[TuningCache] = None) -> TuneResult:
    """Measure the Lanczos re-orth step across every feasible backend (at
    each backend's model-best f) and persist the winner as the machine's
    ``backend="auto"`` answer."""
    cache = cache if cache is not None else default_cache()
    from ..engine.backends import available_backends
    rows = []
    for name in available_backends():
        if not _feasible({"backend": name}):
            continue
        res = tune("lanczos_reorth", shape, dtype, fix={"backend": name},
                   measure_candidates=True, prune=2, reps=reps,
                   cache=cache, force=True, persist=False)
        rows.append((res.best, res.predicted_s, res.measured_s))
    best, pred, meas = min(rows, key=lambda r: r[2])
    res = TuneResult("lanczos_reorth", shape_bucket(shape), str(dtype),
                     _backend_key(), dict(best), "measured", pred, meas,
                     tuple(rows))
    cache.put(_backend_key(), _to_entry(res))
    cache.save()
    return res


def pretune(shapes: Mapping[str, Sequence[Sequence[int]]],
            dtype: Any = "float32", *,
            fix: Optional[Mapping[str, Any]] = None,
            measure_candidates: bool = False,
            cache: Optional[TuningCache] = None
            ) -> Dict[str, TuneResult]:
    """Warm the tuning cache for a known workload — e.g. the serving CLI
    pre-tunes its prefill decomposition and dkv-attention shapes before
    the first request lands.  Returns {cache key: result}."""
    out: Dict[str, TuneResult] = {}
    for kernel, kshapes in shapes.items():
        for shape in kshapes:
            res = tune(kernel, shape, dtype, fix=fix,
                       measure_candidates=measure_candidates, cache=cache)
            out[res.key] = res
    return out
