"""Declarative tunable spaces for the compute-expansion kernel family.

Every kernel that exposes an operating point (the paper's expansion factor
``f``, block sizes, backend choice) REGISTERS its space here, next to its
own definition (bottom of each ``repro.kernels`` module) — so the tuner
never hard-codes knowledge about a kernel, and adding a kernel
automatically adds it to ``benchmarks/run.py --tune``.

A :class:`TunableSpace` is pure data: parameter names, choice grids, and
the historical hard-coded defaults (``expansion=8``, ``row_block=512``,
``n_block=512``).  Enumeration order is deterministic (itertools.product
over the declared order), which the tuner relies on for reproducible
tie-breaking.

This module is intentionally a leaf: no jax, no kernel imports — kernel
modules import IT at definition time without cycles.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterator, List, Tuple


@dataclasses.dataclass(frozen=True)
class TunableParam:
    """One axis of a kernel's operating point."""
    name: str
    choices: Tuple[Any, ...]
    default: Any

    def __post_init__(self):
        if self.default not in self.choices:
            raise ValueError(
                f"default {self.default!r} of param {self.name!r} is not "
                f"among its choices {self.choices!r}")


@dataclasses.dataclass(frozen=True)
class TunableSpace:
    """The candidate grid of one kernel, declared where the kernel lives."""
    kernel: str
    params: Tuple[TunableParam, ...]

    def default(self) -> Dict[str, Any]:
        return {p.name: p.default for p in self.params}

    def candidates(self) -> Iterator[Dict[str, Any]]:
        names = [p.name for p in self.params]
        for combo in itertools.product(*(p.choices for p in self.params)):
            yield dict(zip(names, combo))

    def size(self) -> int:
        n = 1
        for p in self.params:
            n *= len(p.choices)
        return n

    def param(self, name: str) -> TunableParam:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(f"space {self.kernel!r} has no param {name!r}")


_REGISTRY: Dict[str, TunableSpace] = {}


def register_space(space: TunableSpace) -> TunableSpace:
    _REGISTRY[space.kernel] = space
    return space


def get_space(kernel: str) -> TunableSpace:
    # Kernel modules register on import; make sure they ran.
    if kernel not in _REGISTRY:
        _import_kernel_spaces()
    try:
        return _REGISTRY[kernel]
    except KeyError:
        raise KeyError(f"no tunable space registered for kernel "
                       f"{kernel!r}; registered: {sorted(_REGISTRY)}") \
            from None


def available_spaces() -> List[str]:
    _import_kernel_spaces()
    return sorted(_REGISTRY)


def _import_kernel_spaces() -> None:
    """Trigger the side-effect registrations in ``repro.kernels`` (lazy to
    keep this module a leaf — kernels import us at definition time)."""
    from ..kernels import (dkv_attention, lanczos_reorth,  # noqa: F401
                           lowrank_matmul, matvec_expand)


# The f grid every expansion kernel shares: powers of two spanning both
# sides of the paper's U-curve (Fig. 12 sweeps 1…128; past ~32 the grid
# overhead dominates every shape we serve, so the searched grid stops
# there and fig12's model section covers the long tail).
EXPANSION_GRID = (1, 2, 4, 8, 16, 32)
BLOCK_GRID = (128, 256, 512)

# Fused serving decode: steps per device launch.  Not a kernel — the
# serving loop registers here directly (there is no kernels module to own
# it).  The grid mirrors its own U-curve: 1 is the classic per-token
# dispatch, large blocks amortize host round-trips but overshoot fold /
# budget horizons (the host then caps the traced bound per block).
DECODE_BLOCK_GRID = (1, 2, 4, 8, 16, 32)
register_space(TunableSpace("decode_block", (
    TunableParam("block", DECODE_BLOCK_GRID, 8),
)))
