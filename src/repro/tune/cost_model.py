"""Analytic roofline cost model of the compute-expansion U-curve (Fig. 12).

Predicts the latency of one kernel launch as a function of the candidate
operating point, per (shape, dtype, device).  The model is the paper's own
explanation of Fig. 12 translated to a roofline (§5.3 + §6.4), reusing the
v5e constants from ``launch.roofline``:

* **memory side** (left of f*): the iterative chain is memory-bound and
  expansion unlocks bandwidth — f partial blocks stream concurrently, so
  utilized bandwidth is ``min(f, f_sat)/f_sat`` of aggregate.  This term is
  NON-INCREASING in f.
* **compute side** (right of f*): the element-wise/combine work is
  replicated per block (``dup·(f−1)``), the grid pays a fixed per-step cost
  (``steps·f·step_overhead`` — the dominant term in Pallas interpret mode),
  and padding the reduced axis to a multiple of f wastes arithmetic
  (``pad_waste``).  Every term is NON-DECREASING in f along a divisibility
  chain (the power-of-two grid in ``space.EXPANSION_GRID``).

``predict`` returns ``max(memory, compute)`` — the max of a non-increasing
and a non-decreasing function, hence provably UNIMODAL along the grid
(non-increasing up to its argmin, non-decreasing after).  The hypothesis
property in tests/test_properties.py pins exactly this.

The model is a PRUNER, not an oracle: the tuner ranks candidates with it
and measures only the survivors (``measure.py``), so constant errors
cancel and only the curve shape matters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Sequence, Tuple

from ..launch.roofline import HBM_BW, PEAK_FLOPS

#: dtype-name → bytes (accepts jnp dtype names and numpy str())
DTYPE_BYTES = {"float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
               "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
               "int32": 4, "int8": 1}


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Roofline denominators of one execution substrate."""
    name: str
    peak_flops: float            # FLOP/s
    hbm_bw: float                # bytes/s aggregate
    f_sat: int                   # blocks in flight at bandwidth saturation
    step_overhead_s: float       # fixed cost per grid step


#: TPU v5e — the deployment target; constants shared with launch.roofline.
V5E = DeviceModel("tpu-v5e", PEAK_FLOPS, HBM_BW, f_sat=8,
                  step_overhead_s=1e-6)

#: Pallas interpret mode on a CPU container: every grid step is executed by
#: the interpreter, so the per-step overhead dwarfs arithmetic and the model
#: correctly prefers small f.
CPU_INTERPRET = DeviceModel("cpu-interpret", 5e10, 2e10, f_sat=4,
                            step_overhead_s=2e-4)


def detect_device() -> DeviceModel:
    """Pick the device model for THIS process (TPU → v5e roofline,
    anything else → interpret-mode CPU)."""
    import jax
    return V5E if jax.default_backend() == "tpu" else CPU_INTERPRET


def device_kind() -> str:
    """Stable cache-key string for the local accelerator."""
    import jax
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', 'unknown')}"


def dtype_bytes(dtype: Any) -> int:
    return DTYPE_BYTES.get(str(dtype), 4)


def _padded(n: int, mult: int) -> int:
    return n + ((-n) % mult)


# ---------------------------------------------------------------------------
# Per-kernel term extraction
#
# Each function maps (shape, dtype_bytes, candidate) to the five roofline
# ingredients: (bytes_streamed, flops_base, dup_flops_per_extra_block,
# grid_steps_per_unit_f, pad_waste(f)).
# ---------------------------------------------------------------------------

Terms = Tuple[float, float, float, float, float]


def _terms_lanczos_reorth(shape: Sequence[int], dtb: int,
                          cand: Mapping[str, Any]) -> Terms:
    """One fused CGS2 re-orth launch, grid = (B, 3, f) over [B, S, H]
    against a k-column Q buffer (shape may carry k as a 4th dim)."""
    if len(shape) == 4:
        b, s, h, k = shape
    else:
        (b, s, h), k = tuple(shape), 16
    f = cand["expansion"]
    s_pad, h_pad = _padded(s, f), _padded(h, f)
    bytes_streamed = b * (3 * s * h * dtb + 2 * (s + h) * k * 4)
    flops_base = b * (2 * s * h + 8 * (s + h) * k)
    dup = b * 4 * (s + h) * k            # replicated correction/combine
    steps = 3 * b                        # grid steps per unit of f
    waste = (s_pad * h_pad) / float(s * h)
    return bytes_streamed, flops_base, dup, steps, waste


def _terms_matvec_expand(shape: Sequence[int], dtb: int,
                         cand: Mapping[str, Any]) -> Terms:
    """y = A·v with the H reduction expanded f ways; grid=(S/rb, f)."""
    if len(shape) == 3:
        b, s, h = shape
    else:
        (s, h), b = tuple(shape), 1
    f = cand["expansion"]
    rb = min(cand.get("row_block", 512), s)
    bytes_streamed = b * s * h * dtb
    flops_base = 2 * b * s * h
    dup = 2 * b * s                      # per-block partial re-accumulate
    steps = b * max(1, -(-s // rb))
    waste = _padded(h, f) / float(h)
    return bytes_streamed, flops_base, dup, steps, waste


def _terms_lowrank_matmul(shape: Sequence[int], dtb: int,
                          cand: Mapping[str, Any]) -> Terms:
    """Vᵀ[k,H] @ W[H,N], H reduction expanded f ways; grid=(N/nb, f)."""
    k, h, n = shape
    f = cand["expansion"]
    nb = min(cand.get("n_block", 512), n)
    k_pad = max(8, -(-k // 8) * 8)
    bytes_streamed = h * n * dtb + k_pad * h * dtb
    flops_base = 2 * k_pad * h * n
    dup = 2 * k_pad * n                  # per-block output re-accumulate
    steps = max(1, -(-n // nb))
    waste = _padded(h, f) / float(h)
    return bytes_streamed, flops_base, dup, steps, waste


def _terms_dkv_attention(shape: Sequence[int], dtb: int,
                         cand: Mapping[str, Any]) -> Terms:
    """Rank-space flash stats over U_k/U_v [T, r], grid=(f,) time blocks."""
    g, t, r = shape
    f = cand["expansion"]
    bytes_streamed = 2 * t * r * dtb
    flops_base = 4 * g * t * r
    dup = 4 * g * r                      # accumulator rescale per block
    steps = 1
    waste = _padded(t, f) / float(t)
    return bytes_streamed, flops_base, dup, steps, waste


KERNEL_TERMS: Dict[str, Callable[[Sequence[int], int, Mapping[str, Any]],
                                 Terms]] = {
    "lanczos_reorth": _terms_lanczos_reorth,
    "matvec_expand": _terms_matvec_expand,
    "lowrank_matmul": _terms_lowrank_matmul,
    "dkv_attention": _terms_dkv_attention,
}

#: Host→device dispatch + sync cost of ONE decode launch (python driver,
#: jit call, logits device→host).  Dominant on small models; the fused
#: loop divides it by the block length.
HOST_DISPATCH_S = 2e-4


def _predict_decode_block(shape: Sequence[int], dtb: int,
                          cand: Mapping[str, Any],
                          dev: DeviceModel) -> float:
    """Per-TOKEN seconds of the fused serving decode loop at block length
    k, for shape (slots b, decode horizon t, kv row width w).

    ``t_step`` is the roofline time of one decode step (stream the [b,t,w]
    K/V working set once, 4·b·t·w flops of attention contraction); on top
    the host dispatch amortizes as ``HOST_DISPATCH_S / min(k, t)`` (a
    block can't outrun the fold/budget horizon ``t``) and a small linear
    penalty models the wasted tail of over-long blocks (early exits and
    horizon caps throw away trace length).  Non-increasing amortization +
    non-decreasing penalty ⇒ unimodal in k along the power-of-two grid,
    matching the expansion model's pruning contract."""
    b, t, w = shape
    k = int(cand["block"])
    if k < 1:
        raise ValueError(f"block must be >= 1, got {k}")
    t_step = max(2 * b * t * w * dtb / dev.hbm_bw,
                 4.0 * b * t * w / dev.peak_flops)
    k_eff = min(k, max(1, t))
    overshoot = (k - k_eff) / float(k)   # trace beyond any usable horizon
    return t_step + HOST_DISPATCH_S / k_eff \
        + t_step * overshoot + 1e-7 * k


def predict(kernel: str, shape: Sequence[int], dtype: Any,
            cand: Mapping[str, Any],
            device: DeviceModel = None) -> float:
    """Predicted seconds for one launch of ``kernel`` at operating point
    ``cand`` — max(memory term, compute term), unimodal in the expansion
    factor along a power-of-two grid.  (For the ``decode_block`` pseudo
    kernel the objective is per-token seconds of the serving loop.)"""
    dev = device or detect_device()
    if kernel == "decode_block":
        return _predict_decode_block(shape, dtype_bytes(dtype), cand, dev)
    try:
        terms = KERNEL_TERMS[kernel]
    except KeyError:
        raise KeyError(f"no cost model for kernel {kernel!r}; "
                       f"known: {sorted(KERNEL_TERMS)}") from None
    f = int(cand["expansion"])
    if f < 1:
        raise ValueError(f"expansion must be >= 1, got {f}")
    bytes_streamed, flops_base, dup, steps, waste = \
        terms(shape, dtype_bytes(dtype), cand)
    bw = dev.hbm_bw * min(f, dev.f_sat) / dev.f_sat
    t_mem = bytes_streamed / bw
    t_comp = (flops_base * waste + dup * (f - 1)) / dev.peak_flops \
        + steps * f * dev.step_overhead_s
    return max(t_mem, t_comp)


def predict_curve(kernel: str, shape: Sequence[int], dtype: Any,
                  candidates: Sequence[Mapping[str, Any]],
                  device: DeviceModel = None
                  ) -> Tuple[Tuple[Dict[str, Any], float], ...]:
    """(candidate, predicted_s) per candidate, in candidate order."""
    dev = device or detect_device()
    return tuple((dict(c), predict(kernel, shape, dtype, c, dev))
                 for c in candidates)
