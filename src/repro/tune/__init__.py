"""``repro.tune`` — cost-model-guided autotuner for the compute-expansion
kernel family (see DESIGN.md §6).

The paper's 6.2× decomposition speedup is a statement about choosing the
right operating point on the Fig. 12 U-curve; this package owns that
choice end to end:

* ``space``      — declarative tunable spaces, registered next to each
                   kernel in ``repro.kernels``;
* ``cost_model`` — analytic roofline U-curve (prunes the grid, provably
                   unimodal in f along the power-of-two grid);
* ``measure``    — jit-warmup + median-of-k empirical harness;
* ``cache``      — persistent JSON keyed device_kind × kernel ×
                   shape-bucket × dtype, with an in-process lru layer;
* ``tuner``      — orchestration + the engine-facing resolvers
                   (``tuned_expansion`` answers ``expansion="auto"``,
                   ``resolve_backend`` answers ``backend="auto"``).
"""
from .cache import TuningCache, default_cache, default_path, entry_key, \
    shape_bucket
from .cost_model import (CPU_INTERPRET, V5E, DeviceModel, detect_device,
                         device_kind, predict, predict_curve)
from .measure import measure_candidate, timeit
from .space import (BLOCK_GRID, DECODE_BLOCK_GRID, EXPANSION_GRID,
                    TunableParam, TunableSpace, available_spaces, get_space,
                    register_space)
from .tuner import (DEFAULT_PRUNE, TuneResult, candidates_for, pretune,
                    resolve_backend, tune, tune_backend, tuned_decode_block,
                    tuned_expansion)

__all__ = [
    "BLOCK_GRID", "CPU_INTERPRET", "DECODE_BLOCK_GRID", "DEFAULT_PRUNE",
    "DeviceModel", "EXPANSION_GRID",
    "TunableParam", "TunableSpace", "TuneResult", "TuningCache", "V5E",
    "available_spaces", "candidates_for", "default_cache", "default_path",
    "detect_device", "device_kind", "entry_key", "get_space",
    "measure_candidate", "predict", "predict_curve", "pretune",
    "register_space", "resolve_backend", "shape_bucket", "timeit", "tune",
    "tune_backend", "tuned_decode_block", "tuned_expansion",
]
