"""Empirical measurement harness: jit warmup + median-of-k wall clock.

The cost model (``cost_model.py``) ranks candidates; this module times the
survivors on the REAL kernels with deterministic synthetic inputs.  Every
benchmark closure goes through the same public entry points the engine
uses (``kernels.ops`` wrappers, which pad via the cached pad plans), so
the measured number includes the padding and dispatch cost the production
path pays.

Kernel imports are lazy (function-local): kernel modules import
``tune.space`` at definition time to register their spaces, so this module
must not import them back at module level.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp


def timeit(fn: Callable[[], Any], *, warmup: int = 2, reps: int = 5
           ) -> float:
    """Median wall-clock seconds per call (blocks on jax outputs).

    True median: the two middle samples are averaged for even ``reps``
    (``ts[k//2]`` alone would be the MAX at reps=2 — worst-case, not
    typical-case, and needlessly noisy as a ranking signal)."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    k = len(ts)
    return (ts[k // 2] + ts[(k - 1) // 2]) / 2.0


def _rand(key: int, shape: Sequence[int], dtype) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(key), tuple(shape),
                             jnp.float32).astype(dtype)


def _bench_lanczos_reorth(shape, dtype, cand) -> Callable[[], Any]:
    """One fused right re-orth step over [B, S, H] against a k-column
    buffer, through the candidate's backend."""
    from ..core.lanczos import DEFAULT_BATCHED_HOOKS
    from ..kernels import ops
    if len(shape) == 4:
        b, s, h, k = shape
    else:
        (b, s, h), k = tuple(shape), 16
    f = int(cand["expansion"])
    backend = cand.get("backend", "pallas_interpret")
    s_pad, h_pad = ops.padded_dims(s, h, f)
    a = _rand(0, (b, s_pad, h_pad), dtype)
    u = _rand(1, (b, s_pad), jnp.float32)
    vbuf = jnp.zeros((b, h_pad, k), jnp.float32)
    if backend == "reference":
        step = jax.jit(DEFAULT_BATCHED_HOOKS.right_step)
        return lambda: step(a, u, vbuf)
    if backend == "pallas_vmap":
        hooks = ops.make_vmapped_pallas_hooks(f, interpret=True)
        return lambda: hooks.right_step(a, u, vbuf)
    # measure EXACTLY what the backend executes: pallas_interpret hooks are
    # built with interpret=True even on TPU (backends.py), so the platform
    # default must not leak in here
    interp = backend == "pallas_interpret"
    return lambda: ops.reorth_right_batched(a, u, vbuf, expansion=f,
                                            interpret=interp)


def _bench_matvec_expand(shape, dtype, cand) -> Callable[[], Any]:
    if len(shape) == 3:
        b, s, h = shape
        a = _rand(0, (b, s, h), dtype)
        v = _rand(1, (b, h), dtype)

        def run():
            from ..kernels import ops
            return ops.matvec_batched(a, v, expansion=int(cand["expansion"]),
                                      row_block=cand.get("row_block"))
        return run
    s, h = shape
    a = _rand(0, (s, h), dtype)
    v = _rand(1, (h,), dtype)

    def run():
        from ..kernels import ops
        return ops.matvec(a, v, expansion=int(cand["expansion"]),
                          row_block=cand.get("row_block"))
    return run


def _bench_lowrank_matmul(shape, dtype, cand) -> Callable[[], Any]:
    k, h, n = shape
    vt = _rand(0, (k, h), dtype)
    w = _rand(1, (h, n), dtype)

    def run():
        from ..kernels import ops
        return ops.lowrank_matmul(vt, w, expansion=int(cand["expansion"]),
                                  n_block=cand.get("n_block"))
    return run


def _bench_dkv_attention(shape, dtype, cand) -> Callable[[], Any]:
    g, t, r = shape
    inner = _rand(0, (g, r), jnp.float32)
    k_u = _rand(1, (t, r), dtype)
    v_u = _rand(2, (t, r), dtype)

    def run():
        from ..kernels import ops
        return ops.dkv_attention_stats(inner, k_u, v_u,
                                       expansion=int(cand["expansion"]))
    return run


def _bench_decode_block(shape, dtype, cand) -> Callable[[], Any]:
    """Serving decode-loop proxy, normalized PER TOKEN: every candidate
    decodes the same 32 tokens, block length k just repartitions them into
    ``ceil(32/k)`` jitted ``fori_loop`` launches (each launch blocks, like
    the engine's per-block host sync), so the measured per-call medians
    are comparable across k after the caller's own normalization — the
    tuner minimizes median seconds per call, hence we fold the
    launch-count difference into the closure by running ALL launches of
    one 32-token decode per call."""
    b, t, w = shape
    k = int(cand["block"])
    tokens = 32
    launches = max(1, -(-tokens // k))
    kv = _rand(0, (b, t, w), dtype)
    q0 = _rand(1, (b, w), jnp.float32)

    @jax.jit
    def block(q, kv):
        def body(_, q):
            s = jnp.einsum("bw,btw->bt", q, kv.astype(jnp.float32))
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bt,btw->bw", p, kv.astype(jnp.float32))
        return jax.lax.fori_loop(0, k, body, q)

    def run():
        q = q0
        for _ in range(launches):
            q = jax.block_until_ready(block(q, kv))
        return q
    return run


_BENCH = {
    "lanczos_reorth": _bench_lanczos_reorth,
    "matvec_expand": _bench_matvec_expand,
    "lowrank_matmul": _bench_lowrank_matmul,
    "dkv_attention": _bench_dkv_attention,
    "decode_block": _bench_decode_block,
}


def measure_candidate(kernel: str, shape: Sequence[int], dtype: Any,
                      cand: Mapping[str, Any], *, warmup: int = 2,
                      reps: int = 5) -> float:
    """Median seconds per launch of ``kernel`` at operating point ``cand``
    on deterministic synthetic inputs of ``shape``/``dtype``."""
    try:
        builder = _BENCH[kernel]
    except KeyError:
        raise KeyError(f"no measurement harness for kernel {kernel!r}; "
                       f"known: {sorted(_BENCH)}") from None
    fn = builder(tuple(int(d) for d in shape), jnp.dtype(dtype), dict(cand))
    return timeit(fn, warmup=warmup, reps=reps)
