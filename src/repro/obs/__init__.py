"""``repro.obs`` — host-side observability: metrics, tracing, exposition.

The serving stack's measurement layer (DESIGN.md §13).  One hard rule
everywhere: instrumentation is PURELY host-side — no device ops, no new
jit inputs — so served tokens are byte-identical with observability on or
off (conformance-gated in tests/test_serving_conformance.py).

* ``registry``   — :class:`MetricsRegistry` of counters / gauges /
                   O(1)-memory log-bucketed streaming histograms
                   (p50/p95/p99 without retaining samples);
* ``trace``      — request-lifecycle spans → Chrome trace-event JSON
                   (Perfetto-loadable), plus the phase stack;
* ``watch``      — jit compile-watch (recompile count + wall time per
                   phase, via ``jax.monitoring``);
* ``exposition`` — Prometheus text format + JSON snapshot writers (and
                   the strict parser CI gates on);
* ``snapshot``   — the uniform engine-metrics schema every benchmark
                   artifact embeds.

``Observability`` bundles one engine's registry + tracer; construct with
``trace=True`` to record spans (``serving.Engine(obs=…)``), default off.
"""
from __future__ import annotations

from typing import Optional

from .exposition import (parse_prometheus, to_prometheus, write_json_snapshot,
                         write_prometheus)
from .registry import (BUCKETS_PER_DECADE, GLOBAL, Counter, Gauge, Histogram,
                       LatencySeries, MetricsRegistry, bucket_label,
                       global_registry)
from .snapshot import engine_snapshot, stats_snapshot
from .trace import (NULL_SPAN, Span, Tracer, current_phase, phase_scope,
                    validate_trace)
from .watch import compile_stats, install_compile_watch

__all__ = [
    "BUCKETS_PER_DECADE", "Counter", "GLOBAL", "Gauge", "Histogram",
    "LatencySeries", "MetricsRegistry", "NULL_SPAN", "Observability",
    "Span", "Tracer", "bucket_label", "compile_stats", "current_phase",
    "engine_snapshot", "global_registry", "install_compile_watch",
    "parse_prometheus", "phase_scope", "stats_snapshot", "to_prometheus",
    "validate_trace", "write_json_snapshot", "write_prometheus",
]


class Observability:
    """One serving engine's observability bundle: a private metrics
    registry (``EngineStats`` mounts its counters/histograms there) and a
    tracer (disabled unless ``trace=True``).  Constructing one also
    installs the process-wide jit compile-watch (idempotent)."""

    def __init__(self, trace: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=trace)
        install_compile_watch()

    @property
    def trace_enabled(self) -> bool:
        return self.tracer.enabled
