"""Uniform serving-metrics snapshot schema.

Every benchmark artifact (``benchmarks/serving_*.py``) and the serve
CLI's periodic stats embed the SAME dict shape for one engine's counters
and latency distributions, so fields are named consistently across
artifacts instead of each benchmark hand-rolling its own keys
(``stalls`` vs ``n_stalls``, ``mean_ttft_s`` vs ``ttft``, …).

Schema (``"schema": "repro.obs/v1"``): flat counters straight off
``EngineStats`` plus three latency blocks —

    {"mean_s": …, "p50_s": …, "p95_s": …, "p99_s": …, "count": n}

for ``ttft`` / ``ttft_queue`` / ``ttft_compute`` / ``itl``.  Quantiles
come from the O(1)-memory streaming histograms, so they are available
for any run length without retaining raw samples.
"""
from __future__ import annotations

from typing import Optional


def _latency_block(series) -> dict:
    h = series.hist
    return {"mean_s": h.mean, "p50_s": h.quantile(0.50),
            "p95_s": h.quantile(0.95), "p99_s": h.quantile(0.99),
            "count": h.count}


def engine_snapshot(eng, wall_s: Optional[float] = None,
                    **extra) -> dict:
    """The uniform metrics snapshot of one ``serving.Engine`` (or of a
    bare ``EngineStats`` via ``stats_snapshot``).  ``wall_s`` overrides
    the stats-accrued wall clock (benchmarks time their own window);
    ``extra`` keys are merged verbatim (benchmark-specific fields like
    ``sched_steps`` or ``peak_resident_cache_bytes``)."""
    snap = stats_snapshot(eng.stats, wall_s=wall_s)
    pg = getattr(eng, "pager", None)
    if pg is not None:
        snap["paged"] = {
            "page": pg.page, "pool_pages": pg.num_pages,
            "tail_pool_pages": pg.num_tail_pages,
            "free_pages": pg.alloc.free_pages,
            "free_tail_pages": pg.talloc.free_pages,
            "prefix_entries": len(pg.prefix) if pg.prefix is not None
            else 0,
        }
    snap.update(extra)
    return snap


def stats_snapshot(s, wall_s: Optional[float] = None) -> dict:
    wall = s.wall_s if wall_s is None else wall_s
    return {
        "schema": "repro.obs/v1",
        "prefills": s.prefills,
        "prefill_batches": s.prefill_batches,
        "decode_steps": s.decode_steps,
        "blocks": s.blocks,
        "tokens_out": s.tokens_out,
        "tail_folds": s.tail_folds,
        "stopped_eos": s.stopped_eos,
        "stopped_budget": s.stopped_budget,
        "prefix_hits": s.prefix_hits,
        "prefix_misses": s.prefix_misses,
        "stalls": s.stalls,
        "prefill_inflight_peak": s.prefill_inflight_peak,
        "wall_s": wall,
        "tokens_per_s": s.tokens_out / max(wall, 1e-9),
        "ttft": _latency_block(s.ttft_s),
        "ttft_queue": _latency_block(s.ttft_queue_s),
        "ttft_compute": _latency_block(s.ttft_compute_s),
        "itl": _latency_block(s.itl_s),
    }
