"""Metrics registry: counters, gauges, and O(1)-memory streaming histograms.

Everything here is HOST-side Python arithmetic — no jax imports, no device
ops, no new jit inputs.  That is the subsystem's one hard rule (DESIGN.md
§13): served tokens must stay byte-identical with observability on or off,
so instrumentation may only ever read host scalars the engine already has.

Histograms are log-bucketed: a sample ``v > 0`` lands in bucket
``floor(BUCKETS_PER_DECADE · log10 v)``, so the whole stream is a sparse
``{bucket: count}`` dict — O(number of distinct decades touched), never
O(samples) — and any quantile is answered by a cumulative walk with
relative error bounded by half a bucket width
(``10^(0.5/BUCKETS_PER_DECADE) − 1`` ≈ 5.9% at the default 20/decade).
A small capped reservoir of the most recent raw samples rides along for
the back-compat "give me the list" view (``EngineStats.itl_s`` et al.):
the reservoir is what iteration returns, while ``len()``, ``sum`` and the
quantiles come from the exact streaming state.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

#: log-bucket resolution: buckets per decade.  20 → quantile relative
#: error ≤ 10^(1/40) − 1 ≈ 5.9% (half a bucket either side).
BUCKETS_PER_DECADE = 20

#: default recent-sample reservoir capacity (per histogram)
RESERVOIR_CAP = 512

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Metric:
    """Base: a name, a help string, and one immutable label set."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.help = help
        self.labels: Dict[str, str] = dict(labels or {})


class Counter(Metric):
    """Monotone-by-convention accumulator.  ``add`` accepts negative
    deltas (the serving cancel path unwinds dispatch-side counts), so this
    is a counter in the Prometheus-exposition sense, not an enforced one."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        super().__init__(name, help, labels)
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    add = inc

    def set(self, v) -> None:
        self.value = v


class Gauge(Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        super().__init__(name, help, labels)
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def max(self, v) -> None:
        """Ratchet: keep the high-water mark."""
        if v > self.value:
            self.value = v


class Histogram(Metric):
    """Streaming log-bucketed histogram with exact count/sum/min/max.

    Memory is O(buckets touched) + O(reservoir cap); observation is O(1).
    Non-positive samples (a 0.0 latency from two perf_counter calls in the
    same tick) land in a dedicated zero bucket ordered below every
    positive bucket, so quantiles stay well defined.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 reservoir: int = RESERVOIR_CAP):
        super().__init__(name, help, labels)
        self._buckets: Dict[int, int] = {}
        self._zero = 0                    # samples ≤ 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.recent: deque = deque(maxlen=max(1, int(reservoir)))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._zero += 1
        else:
            k = math.floor(BUCKETS_PER_DECADE * math.log10(v))
            self._buckets[k] = self._buckets.get(k, 0) + 1
        self.recent.append(v)

    def quantile(self, q: float) -> float:
        """The q-quantile (0 ≤ q ≤ 1) by cumulative bucket walk: the value
        returned is the geometric midpoint of the bucket holding the
        nearest-rank sample, clamped to the exact observed [min, max]."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q * self.count))   # nearest-rank
        if rank <= self._zero:
            return min(0.0, self.max)
        cum = self._zero
        for k in sorted(self._buckets):
            cum += self._buckets[k]
            if cum >= rank:
                mid = 10.0 ** ((k + 0.5) / BUCKETS_PER_DECADE)
                return float(min(max(mid, self.min), self.max))
        return float(self.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class LatencySeries:
    """Back-compat list view over a :class:`Histogram`.

    The pre-obs ``EngineStats`` kept every latency sample in an unbounded
    Python list; this keeps the list API — ``append``/``extend``,
    iteration, ``np.asarray``, truthiness — while the storage is the
    histogram's O(1) streaming state plus its capped recent-sample
    reservoir.  ``len()`` is the TOTAL observation count (the histogram
    counter), which is what preserves the ``len(itl_s) == tokens_out``
    invariant after the raw samples stop being retained; iteration yields
    only the most recent ``reservoir`` samples.
    """

    def __init__(self, hist: Histogram):
        self.hist = hist

    def append(self, v: float) -> None:
        self.hist.observe(v)

    def extend(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.hist.observe(v)

    def __len__(self) -> int:
        return self.hist.count

    def __iter__(self) -> Iterator[float]:
        return iter(self.hist.recent)

    def __getitem__(self, i):
        return list(self.hist.recent)[i]

    def __array__(self, dtype=None, copy=None):
        import numpy as np
        return np.asarray(list(self.hist.recent), dtype=dtype)

    def __repr__(self) -> str:
        return (f"LatencySeries(n={self.hist.count}, "
                f"recent={len(self.hist.recent)})")

    # convenience passthroughs
    @property
    def mean(self) -> float:
        return self.hist.mean

    def quantile(self, q: float) -> float:
        return self.hist.quantile(q)


class MetricsRegistry:
    """Named metric store.  ``counter``/``gauge``/``histogram`` get or
    create the metric for (name, labels) — the same call site hits the
    same object every time, so hot-path instrumentation is one dict
    lookup.  Thread-safe creation (jax.monitoring listeners may fire from
    compile threads); mutation of a metric is plain GIL-atomic arithmetic.
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelKey], Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **kw) -> Metric:
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, help, labels, **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  reservoir: int = RESERVOIR_CAP, **labels) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         reservoir=reservoir)

    def metrics(self) -> List[Metric]:
        return list(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> Dict[str, list]:
        """JSON-able view: ``{name: [{labels, ...fields}, ...]}``.
        Counters/gauges carry ``value``; histograms carry count/sum/
        min/max and the p50/p95/p99 quantiles."""
        out: Dict[str, list] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                row = {"labels": dict(m.labels), "count": m.count,
                       "sum": m.sum,
                       "min": m.min if m.count else 0.0,
                       "max": m.max if m.count else 0.0,
                       "mean": m.mean,
                       "p50": m.quantile(0.50), "p95": m.quantile(0.95),
                       "p99": m.quantile(0.99)}
            else:
                row = {"labels": dict(m.labels), "value": m.value}
            out.setdefault(m.name, []).append(row)
        return out


#: process-global registry: decomposition telemetry, tuner cache counters,
#: and the jit compile-watch land here (they are not tied to one serving
#: engine); per-engine serving stats live in each EngineStats' registry.
GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return GLOBAL


def bucket_label(*dims: int) -> str:
    """Power-of-two shape-bucket label (mirrors ``tune.shape_bucket``
    without importing the tuner): ``bucket_label(3, 24, 96) → "4x32x128"``.
    """
    def pow2(n: int) -> int:
        return 1 << max(0, int(n) - 1).bit_length()
    return "x".join(str(pow2(d)) for d in dims)
