"""jit compile-watch: count + wall time of XLA recompilations.

``jax.monitoring`` emits a ``/jax/core/compile/backend_compile_duration``
duration event for every real backend compile (trace-cache hits fire
nothing), so listening to it is a zero-device-op way to catch the classic
serving regression — a step fn silently retracing per call because some
argument stopped hashing stably.  Each compile is attributed to the
innermost active :func:`~repro.obs.trace.phase_scope` (``prefill`` /
``decode`` / ``fold`` / ``splice`` / …), which is how "recompiles per
step fn" is answered without wrapping every jit wrapper.

Counters land in the GLOBAL registry:

* ``jit_compiles_total{phase=…}``        — backend compiles
* ``jit_compile_seconds_total{phase=…}`` — wall time inside XLA
* ``jit_traces_total{phase=…}``          — jaxpr traces (cheaper, noisier)

``install_compile_watch`` is idempotent; the listener stays registered
for the life of the process (jax has no per-listener removal).
"""
from __future__ import annotations

from .registry import GLOBAL, MetricsRegistry
from .trace import current_phase

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"

_installed = False


def install_compile_watch(registry: MetricsRegistry = GLOBAL) -> bool:
    """Register the monitoring listener (once per process).  Returns True
    if this call installed it, False if it was already live."""
    global _installed
    if _installed:
        return False
    try:
        import jax.monitoring as monitoring
    except Exception:                     # pragma: no cover - jax absent
        return False

    def on_duration(event: str, duration: float, **kw) -> None:
        if event == _COMPILE_EVENT:
            phase = current_phase()
            registry.counter(
                "jit_compiles_total",
                "XLA backend compiles (recompile watch)",
                phase=phase).inc()
            registry.counter(
                "jit_compile_seconds_total",
                "wall seconds spent in XLA backend compiles",
                phase=phase).add(float(duration))
        elif event == _TRACE_EVENT:
            registry.counter(
                "jit_traces_total", "jaxpr traces",
                phase=current_phase()).inc()

    monitoring.register_event_duration_secs_listener(on_duration)
    _installed = True
    return True


def compile_stats(registry: MetricsRegistry = GLOBAL) -> dict:
    """{phase: {"compiles": n, "seconds": s}} view of the watch counters."""
    out: dict = {}
    for m in registry.metrics():
        if m.name == "jit_compiles_total":
            out.setdefault(m.labels.get("phase", "other"),
                           {"compiles": 0, "seconds": 0.0})["compiles"] \
                = m.value
        elif m.name == "jit_compile_seconds_total":
            out.setdefault(m.labels.get("phase", "other"),
                           {"compiles": 0, "seconds": 0.0})["seconds"] \
                = m.value
    return out
