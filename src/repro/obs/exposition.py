"""Exposition: Prometheus text format + JSON snapshots.

``to_prometheus`` renders one or more registries as Prometheus text
exposition (format 0.0.4).  Histograms are exported as SUMMARY metrics —
``{quantile="0.5|0.95|0.99"}`` series plus ``_sum``/``_count`` — because
the log-bucketed quantiles are computed here, host-side, rather than by a
remote query engine.  ``parse_prometheus`` is the strict grammar check the
CI smoke and tests gate on (no external client library in the image).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Tuple

from ..ioutil import atomic_write_json, atomic_write_text
from .registry import Histogram, MetricsRegistry

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LINE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[-+]?(?:[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?"
    r"|Inf|NaN))$")
_LABEL = re.compile(r'^(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]'
                    r'|\\.)*)"$')

#: default metric-name prefix for everything this repo exports
NAMESPACE = "repro"


def _sanitize(name: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not _NAME_OK.match(out):
        out = "_" + out
    return out


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize(k)}="{_escape(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_val(v) -> str:
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return repr(v) if v != int(v) else str(int(v))


def to_prometheus(*registries: MetricsRegistry,
                  namespace: str = NAMESPACE) -> str:
    """Render registries as Prometheus text exposition.  Later registries
    win nothing — names are expected disjoint per label set; duplicate
    (name, labels) pairs across registries are all emitted (Prometheus
    treats that as an error, so keep engine vs global metrics distinct)."""
    by_name: Dict[str, list] = {}
    kinds: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for reg in registries:
        for m in reg.metrics():
            name = _sanitize(f"{namespace}_{m.name}" if namespace
                             else m.name)
            by_name.setdefault(name, []).append(m)
            kinds.setdefault(name, "summary" if isinstance(m, Histogram)
                             else m.kind)
            if m.help and name not in helps:
                helps[name] = m.help
    lines: List[str] = []
    for name in sorted(by_name):
        if name in helps:
            lines.append(f"# HELP {name} {helps[name]}")
        lines.append(f"# TYPE {name} {kinds[name]}")
        for m in by_name[name]:
            if isinstance(m, Histogram):
                for q in (0.5, 0.95, 0.99):
                    lb = dict(m.labels, quantile=str(q))
                    lines.append(f"{name}{_fmt_labels(lb)} "
                                 f"{_fmt_val(m.quantile(q))}")
                lines.append(f"{name}_sum{_fmt_labels(m.labels)} "
                             f"{_fmt_val(m.sum)}")
                lines.append(f"{name}_count{_fmt_labels(m.labels)} "
                             f"{_fmt_val(m.count)}")
            else:
                lines.append(f"{name}{_fmt_labels(m.labels)} "
                             f"{_fmt_val(m.value)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, List[Tuple[Dict[str, str],
                                                        float]]]:
    """Strict parse of text exposition → ``{name: [(labels, value)]}``.
    Raises ``ValueError`` on any line that is neither a comment nor a
    well-formed sample — the CI smoke step's whole job."""
    out: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for ln, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"prometheus line {ln} malformed: {raw!r}")
        labels: Dict[str, str] = {}
        body = m.group("labels")
        if body:
            for part in _split_labels(body, ln, raw):
                lm = _LABEL.match(part)
                if not lm:
                    raise ValueError(
                        f"prometheus line {ln} bad label {part!r}")
                labels[lm.group("k")] = lm.group("v")
        out.setdefault(m.group("name"), []).append(
            (labels, float(m.group("value"))))
    return out


def _split_labels(body: str, ln: int, raw: str) -> List[str]:
    """Split ``k="v",k2="v2"`` on commas outside quotes."""
    parts, cur, in_q, esc = [], [], False, False
    for ch in body:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if in_q:
        raise ValueError(f"prometheus line {ln} unterminated quote: {raw!r}")
    if cur:
        parts.append("".join(cur))
    return parts


def write_prometheus(path: str, *registries: MetricsRegistry,
                     namespace: str = NAMESPACE) -> str:
    text = to_prometheus(*registries, namespace=namespace)
    parse_prometheus(text)                # never write what we can't parse
    atomic_write_text(path, text)
    return text


def write_json_snapshot(path: str, *registries: MetricsRegistry) -> dict:
    snap: dict = {}
    for reg in registries:
        for name, rows in reg.snapshot().items():
            snap.setdefault(name, []).extend(rows)
    atomic_write_json(path, snap, indent=2)
    return snap
