"""Request-lifecycle tracing: host-side spans → Chrome trace-event JSON.

Spans are wall-clock intervals (``time.perf_counter``) recorded as Chrome
trace-event ``"X"`` (complete) events, loadable in Perfetto / chrome://
tracing.  Tracks (one ``tid`` each, named via ``thread_name`` metadata
events) separate the concurrent stories serving interleaves:

* ``engine``   — the step loop: ``step`` spans containing ``admit`` /
  ``decode-block`` / ``fold`` / ``drain-pool`` children (nesting is time
  containment on one tid, which is exactly how Perfetto renders it);
* ``tickets``  — in-flight async ``PrefillTicket``s (dispatch → splice),
  on their own track so the P/D overlap is visible as spans running UNDER
  the engine's decode spans;
* ``req/<uid>`` — one track per request: a ``request`` span
  (submit → finish) containing ``queue`` (submit → dispatch),
  ``prefill`` (dispatch → first token) and ``decode`` (first → last
  token) child spans.

Everything is plain Python list-append on the host — a disabled tracer
(the default) reduces every call to one attribute check and shared no-op
objects, and an enabled tracer never touches device state, so tokens are
byte-identical either way (the §13 zero-device-op rule; conformance-gated
in tests/test_serving_conformance.py).

The module also owns the PHASE stack used to attribute jit recompiles:
``phase_scope("decode")`` marks host-side sections that launch device
programs, and the compile-watch (``watch.py``) labels every XLA compile
event with the innermost active phase.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional


class Span:
    """One open interval on a track; ``end()`` records the event."""

    __slots__ = ("tracer", "name", "track", "args", "t0", "_done")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 args: Optional[Dict[str, Any]] = None):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.args = dict(args or {})
        self.t0 = time.perf_counter()
        self._done = False

    def annotate(self, **kw) -> "Span":
        self.args.update(kw)
        return self

    def end(self, **kw) -> None:
        if self._done:                    # idempotent: double-end is a no-op
            return
        self._done = True
        if kw:
            self.args.update(kw)
        self.tracer._record(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class _NullSpan:
    """Shared no-op span for a disabled tracer (zero allocation per call)."""

    __slots__ = ()

    def annotate(self, **kw) -> "_NullSpan":
        return self

    def end(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Span recorder.  ``enabled=False`` (the default engine state) makes
    ``begin``/``span``/``instant`` constant-time no-ops."""

    def __init__(self, enabled: bool = True, max_events: int = 200_000):
        self.enabled = enabled
        self.events: List[dict] = []
        self.max_events = max_events      # hard bound: tracing may never
        #                                   become the unbounded-memory bug
        #                                   it exists to prevent
        self.dropped = 0
        self._t0 = time.perf_counter()
        self._tids: Dict[str, int] = {}

    # -- recording --------------------------------------------------------
    def begin(self, name: str, track: str = "engine",
              args: Optional[Dict[str, Any]] = None):
        """Open a span; the caller ends it (possibly in another scope —
        request-lifecycle spans end steps later than they begin)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, track, args)

    span = begin                          # context-manager idiom: with t.span(..)

    def instant(self, name: str, track: str = "engine",
                args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append({
            "name": name, "ph": "i", "s": "t",
            "ts": (time.perf_counter() - self._t0) * 1e6,
            "pid": 0, "tid": self._tid(track),
            "args": dict(args or {})})

    def _record(self, sp: Span) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        ts = (sp.t0 - self._t0) * 1e6
        self.events.append({
            "name": sp.name, "ph": "X", "ts": ts,
            "dur": (time.perf_counter() - self._t0) * 1e6 - ts,
            "pid": 0, "tid": self._tid(sp.track), "args": sp.args})

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
        return tid

    # -- export -----------------------------------------------------------
    def to_json(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": "repro.serving"}}]
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": track}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        from ..ioutil import atomic_write_json
        atomic_write_json(path, self.to_json())


def validate_trace(obj) -> int:
    """Validate Chrome trace-event JSON (a dict, JSON text, or a path).

    Checks the structural contract Perfetto's JSON importer needs — a
    ``traceEvents`` list whose entries carry ``ph``/``ts``/``pid``/``tid``
    (``dur`` too for ``"X"`` events) — and returns the number of complete
    spans.  Raises ``ValueError`` on any malformed event (CI smoke gates
    on this).
    """
    if isinstance(obj, str):
        if "\n" not in obj and not obj.lstrip().startswith(("{", "[")):
            with open(obj) as f:
                obj = json.load(f)
        else:
            obj = json.loads(obj)
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError("trace: expected {'traceEvents': [...]}")
    spans = 0
    for ev in obj["traceEvents"]:
        if not isinstance(ev, dict):
            raise ValueError(f"trace event is not an object: {ev!r}")
        for fld in ("ph", "pid", "tid"):
            if fld not in ev:
                raise ValueError(f"trace event missing {fld!r}: {ev!r}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"trace event missing numeric ts: {ev!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                raise ValueError(f"X event needs dur >= 0: {ev!r}")
            spans += 1
    return spans


# ---------------------------------------------------------------------------
# Phase stack (jit compile attribution)
# ---------------------------------------------------------------------------

_phase = threading.local()


def current_phase() -> str:
    stack = getattr(_phase, "stack", None)
    return stack[-1] if stack else "other"


class phase_scope:
    """Mark a host section that launches device programs, so compile
    events fired while it is active are attributed to it (two list ops —
    always on, independent of any tracer)."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __enter__(self):
        stack = getattr(_phase, "stack", None)
        if stack is None:
            stack = _phase.stack = []
        stack.append(self.name)
        return self

    def __exit__(self, *exc):
        _phase.stack.pop()
