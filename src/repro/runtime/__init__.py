"""Fault-tolerant runtime: step functions, training driver, watchdogs."""
from . import steps
