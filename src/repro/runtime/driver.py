"""Fault-tolerant training driver.

* checkpoint/restart: atomic versioned saves every ``ckpt_every`` steps via
  the async checkpointer; on (re)start the driver restores the LATEST
  checkpoint and the data pipeline replays from the restored step (data is
  a pure function of step — see ``repro.data``).
* failure injection: ``failure_hook(step)`` raising ``SimulatedFailure``
  exercises the restart path in-process (tests/test_runtime.py).
* straggler watchdog: per-step wall time EWMA; steps slower than
  ``k·ewma`` are flagged and counted (on real multi-host deployments the
  flag feeds the re-shard decision; here it feeds metrics + logs).
* elastic re-mesh: ``restore_for_mesh`` re-shards any checkpoint onto a new
  mesh via checkpoint.restore(shardings=...).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax

from .. import checkpoint as ckpt
from ..configs.base import ArchConfig, ShapeSpec
from ..data import DataConfig, Prefetcher, SyntheticLM
from ..optim import make_optimizer
from . import steps as steps_mod

Pytree = Any


class SimulatedFailure(RuntimeError):
    """Raised by failure hooks to exercise checkpoint/restart."""


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time monitor: flags steps slower than ``threshold × ewma``."""
    alpha: float = 0.1
    threshold: float = 2.5
    ewma: Optional[float] = None
    flagged: int = 0
    history: List[float] = dataclasses.field(default_factory=list)

    def observe(self, dt: float) -> bool:
        self.history.append(dt)
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


@dataclasses.dataclass
class TrainLoopResult:
    step: int
    losses: List[float]
    restarts: int
    straggler_flags: int


def train_loop(cfg: ArchConfig, shape: ShapeSpec, *, total_steps: int,
               ckpt_dir: str, ckpt_every: int = 20, keep: int = 2,
               seed: int = 0, log_every: int = 10,
               failure_hook: Optional[Callable[[int], None]] = None,
               max_restarts: int = 3,
               print_fn: Callable[[str], None] = print) -> TrainLoopResult:
    """Run (or resume) training with checkpoint/restart until total_steps."""
    opt = make_optimizer(cfg)
    train_step = jax.jit(steps_mod.make_train_step(cfg, opt))
    restarts = 0
    losses: List[float] = []
    watchdog = StragglerWatchdog()
    saver = None

    while True:
        try:
            # ---- (re)start: restore latest or init fresh ----------------
            t_params, t_opt = jax.eval_shape(
                lambda k: steps_mod.init_train_state(cfg, k, opt),
                jax.random.PRNGKey(seed))
            template = {"params": t_params, "opt": t_opt}
            start = ckpt.latest_step(ckpt_dir)
            if start is not None:
                state = ckpt.restore(template, ckpt_dir)
                params, opt_state = state["params"], state["opt"]
                start += 1
                print_fn(f"[driver] restored step {start - 1}; resuming")
            else:
                params, opt_state = steps_mod.init_train_state(
                    cfg, jax.random.PRNGKey(seed), opt)
                start = 0

            source = SyntheticLM(cfg, shape, DataConfig(seed=seed))
            prefetch = Prefetcher(source, start_step=start)
            saver = ckpt.AsyncCheckpointer(ckpt_dir, keep=keep)

            for step, batch in prefetch:
                if step >= total_steps:
                    prefetch.stop()
                    saver.wait()
                    ckpt.save({"params": params, "opt": opt_state},
                              ckpt_dir, step - 1)
                    return TrainLoopResult(step, losses, restarts,
                                           watchdog.flagged)
                if failure_hook is not None:
                    failure_hook(step)
                # perf_counter, not time.time(): the watchdog's straggler
                # EWMA is interval math and must not see NTP slew (lint D2)
                t0 = time.perf_counter()
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if watchdog.observe(dt):
                    print_fn(f"[watchdog] straggler step {step}: "
                             f"{dt:.2f}s vs ewma {watchdog.ewma:.2f}s")
                losses.append(loss)
                if step % log_every == 0:
                    print_fn(f"[train] step {step} loss {loss:.4f} "
                             f"({dt * 1e3:.0f} ms)")
                if step % ckpt_every == ckpt_every - 1:
                    saver.save({"params": params, "opt": opt_state}, step)

        except SimulatedFailure as e:
            restarts += 1
            print_fn(f"[driver] failure at restart #{restarts}: {e}")
            try:
                prefetch.stop()
            except Exception:
                pass
            # drain the in-flight async save BEFORE the restart re-reads /
            # re-writes the checkpoint dir — an abandoned writer thread
            # racing the resumed loop's saves was a real corruption window
            # (write errors it reports are moot: we're restarting anyway)
            try:
                saver.wait()
            except Exception:
                pass
            if restarts > max_restarts:
                raise
            continue


def restore_for_mesh(cfg: ArchConfig, ckpt_dir: str, mesh, *,
                     optimizer=None) -> Pytree:
    """Elastic restore: load the latest checkpoint RE-SHARDED for ``mesh``.

    The saved mesh is irrelevant — shards are rebuilt from the host copy via
    make_array_from_callback against the new sharding rules.
    """
    from ..distributed import sharding as sh
    opt = optimizer or make_optimizer(cfg)
    template = jax.eval_shape(
        lambda k: steps_mod.init_train_state(cfg, k, opt),
        jax.random.PRNGKey(0))
    params_abs, opt_abs = template
    shardings = {
        "params": sh.params_sharding(params_abs, mesh, cfg),
        "opt": sh.opt_state_sharding(opt_abs, mesh, cfg),
    }
    template_tree = {"params": params_abs, "opt": opt_abs}
    return ckpt.restore(template_tree, ckpt_dir, shardings=shardings)
