"""Jit-able step functions: train_step (grad + clip + optimizer [+ optional
low-rank gradient compression]), prefill_step, decode_step, and the
decomposed-execution steps (which obtain decomposition exclusively through a
:class:`~repro.engine.DecomposeEngine`).

These are the functions the dry-run lowers and the drivers execute; they are
pure (params/opt_state in → out) so checkpoint/restart and elastic re-mesh
are trivial.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..engine import DecomposeEngine, EngineConfig
from ..models import api
from ..optim import clip_by_global_norm, make_optimizer

Pytree = Any


def make_train_step(cfg: ArchConfig, optimizer=None, max_grad_norm: float = 1.0,
                    microbatches: int = 1,
                    grad_transform: Optional[Callable] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics).

    ``microbatches`` > 1 accumulates gradients with a scan over batch shards
    (memory knob); ``grad_transform`` hooks gradient compression
    (distributed.compression) between backward and optimizer.
    """
    fns = api.model_fns(cfg)
    opt = optimizer or make_optimizer(cfg)

    def loss_of(params, batch):
        return fns.loss_fn(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def reshape(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(reshape, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_fn(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_of)(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zero), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    fns = api.model_fns(cfg)

    def eval_step(params, batch):
        return {"loss": fns.loss_fn(params, cfg, batch)}
    return eval_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    fns = api.model_fns(cfg)

    def prefill_step(params, *inputs):
        return fns.prefill(params, cfg, *inputs)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    fns = api.model_fns(cfg)

    def decode_step(params, token, cache, pos):
        return fns.decode_step(params, cfg, token, cache, pos)
    return decode_step


# ---------------------------------------------------------------------------
# Decomposed-execution steps — one DecomposeEngine per step factory
# ---------------------------------------------------------------------------

def _resolve_engine(engine) -> DecomposeEngine:
    if engine is None:
        return DecomposeEngine(EngineConfig())
    if isinstance(engine, EngineConfig):
        return DecomposeEngine(engine)
    return engine


def _resolve_policy_engine(engine) -> DecomposeEngine:
    engine = _resolve_engine(engine)
    if engine.config.policy is None:
        raise ValueError(
            "decomposed forward/quality steps need a DecompositionPolicy: "
            "pass a DecomposeEngine (or EngineConfig) whose policy is set")
    return engine


def make_decomposed_forward_step(cfg: ArchConfig, engine) -> Callable:
    """forward(params, tokens) → logits with policy-selected decomposed
    execution.  ``engine`` is a DecomposeEngine or an EngineConfig (with a
    policy); the engine is resolved ONCE here and threaded through every
    block — no per-callsite rank/hook plumbing.
    """
    engine = _resolve_policy_engine(engine)
    from ..models import decomposed as D
    runtime = D.DecomposedRuntime(engine=engine)

    def forward_step(params, tokens):
        return D.forward(params, cfg, tokens, runtime)
    return forward_step


def make_decomposed_quality_step(cfg: ArchConfig, engine) -> Callable:
    """quality(params, tokens) → KL(base ‖ decomposed) over the vocab."""
    engine = _resolve_policy_engine(engine)
    from ..models import decomposed as D
    runtime = D.DecomposedRuntime(engine=engine)

    def quality_step(params, tokens):
        return D.logit_kl(params, cfg, tokens, runtime)
    return quality_step


def make_dkv_prefill_step(cfg: ArchConfig, rank: int, tail: int = 128,
                          engine=None, exact: bool = False) -> Callable:
    """prefill(params, tokens) → (logits, decomposed KV cache) through the
    engine's backend."""
    engine = _resolve_engine(engine)
    from ..models import decomposed_kv as DK

    def prefill_step(params, tokens):
        return DK.prefill_dkv(params, cfg, tokens, rank, tail=tail,
                              exact=exact, engine=engine)
    return prefill_step


def init_train_state(cfg: ArchConfig, key, optimizer=None
                     ) -> Tuple[Pytree, Pytree]:
    fns = api.model_fns(cfg)
    opt = optimizer or make_optimizer(cfg)
    params = fns.init(key, cfg)
    return params, opt.init(params)


def abstract_train_state(cfg: ArchConfig, optimizer=None):
    """(params, opt_state) ShapeDtypeStructs — dry-run state, no allocation."""
    fns = api.model_fns(cfg)
    opt = optimizer or make_optimizer(cfg)

    def mk(key):
        params = fns.init(key, cfg)
        return params, opt.init(params)
    return jax.eval_shape(mk, jax.random.PRNGKey(0))
