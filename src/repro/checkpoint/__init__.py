"""Atomic, versioned, elastic checkpointing.

* Per-host shard files: each host writes only the array shards it owns
  (``addressable_shards``); a tiny JSON manifest records the pytree
  structure + global shapes.
* Atomic: writes land in ``step_N.tmp`` then ``os.rename`` to ``step_N``
  (restart-safe — a crash mid-save never corrupts the latest checkpoint).
* Elastic restore: arrays are rebuilt against the CURRENT mesh/sharding via
  ``jax.make_array_from_callback`` — a checkpoint saved on mesh A restores
  on mesh B with any sharding (tested in tests/test_checkpoint.py).
* Async save: ``AsyncCheckpointer`` moves the serialize+write off the step
  loop; GC keeps the newest ``keep`` steps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_MANIFEST = "manifest.json"
_STAGING_PREFIX = ".staging.tmp-"
# a staging dir untouched this long belongs to a dead writer, not a slow one
_STAGING_STALE_S = 3600.0

# np.save/np.load can't round-trip ml_dtypes (bfloat16 etc.) — store them
# through a same-width uint view and restore via the manifest dtype string.
_VIEW_MAP = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _VIEW_MAP:
        return arr.view(_VIEW_MAP[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_MAP:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_names(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out.append((name, leaf))
    return out


def save(tree: Pytree, directory: str, step: int) -> str:
    """Synchronous atomic save.  Returns the final directory.

    The staging directory is UNIQUE PER WRITER (``mkdtemp``), not the
    shared ``step_N.tmp`` it used to be: an abandoned async writer (e.g.
    left behind by a crash/restart cycle) racing a new save for the same
    step must never delete or rename the directory another writer is still
    filling.  Whichever writer renames first wins; the loser's staging dir
    is discarded — both hold the same deterministic state for a given
    step, so durability is unaffected."""
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=_STAGING_PREFIX)
    try:
        return _save_into(tree, tmp, final, step)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _save_into(tree: Pytree, tmp: str, final: str, step: int) -> str:
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for name, leaf in _flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        savable, dtype_name = _to_savable(arr)
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), savable)
        manifest["leaves"][name] = {"file": fname,
                                    "shape": list(arr.shape),
                                    "dtype": dtype_name}
    # treedef via example pytree of leaf names
    flat, treedef = jax.tree_util.tree_flatten(tree)
    manifest["num_leaves"] = len(flat)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    try:
        os.rename(tmp, final)      # atomicity boundary
    except OSError:
        # final already exists: it can only have appeared through a
        # completed rename (finals are never partially written), so a
        # concurrent writer for the same step won with the same
        # deterministic payload — never delete the durable winner, just
        # drop our staging dir.  Anything else is a real I/O failure and
        # must surface, or the caller would believe the step is durable.
        shutil.rmtree(tmp, ignore_errors=True)
        if not os.path.isdir(final):
            raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(template: Pytree, directory: str, step: Optional[int] = None,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``template`` (shapes/dtypes enforced).

    ``shardings`` (same treedef) re-shards each array for the CURRENT mesh —
    the elastic-restore path; None places on the default device.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)

    named = dict(_flatten_with_names(template))
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_t))

    restored = []
    for (name, leaf), shd in zip(_flatten_with_names(template), shard_flat):
        meta = manifest["leaves"][name]
        arr = _from_saved(np.load(os.path.join(d, meta["file"])),
                          meta["dtype"])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{name}: ckpt shape {arr.shape} != "
                             f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shd is not None:
            out = jax.make_array_from_callback(
                arr.shape, shd, lambda idx, a=arr: a[idx])
        else:
            out = jnp.asarray(arr)
        restored.append(out)
    return jax.tree_util.tree_unflatten(treedef, restored)


def gc_old(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)
    # sweep staging dirs abandoned by hard-killed writers (in-process
    # failures clean up in save(); a LIVE writer's dir is mtime-fresh —
    # np.save touches it continuously — so the age gate never races one)
    # epoch time on purpose: compared against os.path.getmtime, which is
    # wall-clock — perf_counter has no defined epoch to compare against
    now = time.time()  # dcomlint: disable=D2
    for d in os.listdir(directory):
        if not d.startswith(_STAGING_PREFIX):
            continue
        p = os.path.join(directory, d)
        try:
            stale = now - os.path.getmtime(p) > _STAGING_STALE_S
        except OSError:
            continue                        # renamed/removed under us
        if stale:
            shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """One background writer thread; ``save`` snapshots to host then returns."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree: Pytree, step: int) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(host_tree, self.directory, step)
                gc_old(self.directory, self.keep)
            except BaseException as e:          # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
