"""Architecture + shape registries (deliverable f).

Every assigned architecture is an ``ArchConfig``; every workload shape is a
``ShapeSpec``.  The dry-run iterates the cross product; smoke tests use
``reduced()`` variants of the same configs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (plus the paper's Llama-2-7b)."""
    name: str
    family: str                      # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free (mamba2)
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    activation: str = "silu"         # silu | geglu | gelu
    gated_mlp: bool = True
    use_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert FFN dim (d_ff used for dense)
    n_shared_experts: int = 0
    first_k_dense: int = 0           # leading dense layers (kimi-style)
    capacity_factor: float = 1.25
    expert_sharding: str = "1d"      # "1d" = EP only; "2d" = EP x data (1T)
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    # --- hybrid (zamba2) ---
    attn_period: int = 0             # one shared attention block every N layers
    # --- VLM ---
    cross_attn_period: int = 0       # cross-attn layer every N layers
    num_image_tokens: int = 0
    # --- enc-dec (audio) ---
    enc_layers: int = 0              # decoder layers = num_layers - enc_layers
    num_audio_frames: int = 0        # encoder memory length for decode shapes
    # --- numerics / training ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    optimizer: str = "adamw"         # adafactor for the 1T MoE
    remat: bool = True               # activation checkpointing per block
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    seq_parallel: bool = False       # Megatron-SP: residual stream sharded
                                     # [.., S/model, H]; AG before attn/MLP,
                                     # RS after (beyond-paper perf knob)
    # --- paper technique applicability note (DESIGN.md §Arch-applicability) ---
    decompose_note: str = "full"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/head table rows padded to a 128 multiple (Megatron-style)
        so the vocab dim shards on any mesh axis and aligns to the MXU; the
        logits tail is masked in ``logits_head``.  Logical ``vocab`` is
        unchanged (granite 49155→49280, seamless 256206→256256,
        mamba2 50280→50304)."""
        return (self.vocab + 127) // 128 * 128

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dec_layers(self) -> int:
        return self.num_layers - self.enc_layers

    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(1)-state long-context decode."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.attn_period or
                           self.cross_attn_period else 2),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else None,
            d_ff=256,
            vocab=512,
            remat=False,
        )
        if self.num_experts:
            kw.update(num_experts=8, top_k=2, moe_d_ff=64,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_k_dense=min(self.first_k_dense, 1))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32)
        if self.attn_period:
            kw.update(attn_period=2)
        if self.cross_attn_period:
            kw.update(cross_attn_period=2, num_image_tokens=16)
        if self.enc_layers:
            kw.update(num_layers=4, enc_layers=2, num_audio_frames=32)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One workload shape (LM-family shared set)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    # Import every per-arch module once; each calls register().
    from . import (deepseek_7b, gemma_2b, granite_3_2b,  # noqa: F401
                   kimi_k2, llama2_7b, llama32_vision_11b, mamba2_780m,
                   olmoe_1b_7b, seamless_m4t_medium, starcoder2_7b,
                   zamba2_1_2b)


def cells(arch: ArchConfig) -> Tuple[str, ...]:
    """Shape names that apply to this arch (long_500k only for sub-quadratic;
    skip recorded in DESIGN.md §5 / EXPERIMENTS.md §Dry-run)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.sub_quadratic:
        names.append("long_500k")
    return tuple(names)
