"""deepseek-7b [dense] — llama-arch, MHA (kv=32), SwiGLU. [arXiv:2401.02954; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab=102400,
    activation="silu", gated_mlp=True,
    decompose_note="full: QKV/O/up/gate/down decomposable",
))
