"""seamless-m4t-medium [audio] — enc-dec transformer backbone; audio frontend
STUBBED (input_specs supplies precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab=256206,
    enc_layers=12,                 # 12 enc + 12 dec ("12L" per stack)
    num_audio_frames=4096,         # encoder memory length for decode shapes
    activation="gelu", gated_mlp=False, use_bias=True,
    decompose_note="full: enc self-attn, dec self/cross-attn, FFNs",
))
