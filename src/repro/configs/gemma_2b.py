"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1). [arXiv:2403.08295; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab=256000,
    activation="geglu", gated_mlp=True, tie_embeddings=True,
    decompose_note="full: QKV/O/up/gate/down decomposable",
))
