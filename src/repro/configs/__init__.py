"""Per-architecture configs (deliverable f) + shape registry."""
from .base import (SHAPES, ArchConfig, ShapeSpec, all_archs, cells, get_arch,
                   register)
