"""granite-3-2b [dense] — GQA kv=8, SwiGLU, tied embeddings.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab=49155,
    activation="silu", gated_mlp=True, tie_embeddings=True,
    decompose_note="full: QKV/O/up/gate/down decomposable",
))
