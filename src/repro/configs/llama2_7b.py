"""llama2-7b — the PAPER's own evaluation model (Tables 2-3, Figs. 4/10/11).
Not in the assigned pool; registered so benchmarks run the paper's exact
configuration axes."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama2-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab=32000,
    activation="silu", gated_mlp=True,
    decompose_note="paper's model: Table 2/3 layer lists apply directly",
))
