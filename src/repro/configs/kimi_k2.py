"""kimi-k2-1t-a32b [moe] — trillion-param MoE: 384 experts top-8, 1 shared
expert, first layer dense.  Adafactor optimizer; weights stay bf16.
[arXiv:2501.kimi2; unverified — paper-table config]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432, vocab=163840,
    num_experts=384, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, first_k_dense=1,
    expert_sharding="2d",
    activation="silu", gated_mlp=True,
    optimizer="adafactor",
    decompose_note=("attention-path + pre-router hidden only (same as "
                    "olmoe); expert weights 2-D sharded (EP x data)"),
))
