"""zamba2-1.2b [hybrid] — Mamba2 backbone + one SHARED attention+MLP block
invoked every ``attn_period`` layers (weight reuse, zamba2-style; the
per-invocation LoRA deltas of the released model are omitted — noted in
DESIGN.md).  [arXiv:2411.15242; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_period=6,
    activation="silu", gated_mlp=True,
    decompose_note=("projections + shared-attn QKV; SSD scan consumes "
                    "full-rank x_t (V-track reconstruct, cheap)"),
))
