"""olmoe-1b-7b [moe] — 64 experts top-8, per-expert d_ff=1024, MHA.
[arXiv:2409.02060; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab=50304,
    num_experts=64, top_k=8, moe_d_ff=1024,
    activation="silu", gated_mlp=True,
    decompose_note=("attention-path + pre-router hidden only: post-router "
                    "token-permuted expert slices break per-prompt low-rank "
                    "structure (DESIGN.md §5)"),
))
