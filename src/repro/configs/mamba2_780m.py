"""mamba2-780m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    decompose_note=("projections only (attention-free): in/out projections "
                    "decompose per Eq. 8 with W = d_inner"),
))
