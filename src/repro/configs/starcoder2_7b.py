"""starcoder2-7b [dense] — GQA kv=4, RoPE, biased linears, ungated GELU MLP.
[arXiv:2402.19173; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab=49152,
    activation="gelu", gated_mlp=False, use_bias=True,
    decompose_note="full: QKV/O/up/down decomposable",
))
