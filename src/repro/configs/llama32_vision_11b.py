"""llama-3.2-vision-11b [vlm] — dense LM + cross-attn image layers every 5th
layer; vision frontend STUBBED (input_specs supplies precomputed patch
embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab=128256,
    cross_attn_period=5, num_image_tokens=1601,
    activation="silu", gated_mlp=True,
    decompose_note=("full on text side; vision KV decomposed offline like "
                    "weights (frontend stubbed)"),
))
