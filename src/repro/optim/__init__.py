"""Optimizers (dependency-free): AdamW, Adafactor, schedules, clipping.

AdamW keeps fp32 m/v (ZeRO-1-shardable — see distributed.sharding);
Adafactor keeps factored row/col second moments (the 1T-MoE choice: state is
~(r+c)/(r·c) of param size).  Gradient accumulation is a microbatch scan in
``runtime.steps``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Pytree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[Array], Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: Pytree) -> Dict[str, Pytree]:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads: Pytree, state: Dict[str, Pytree],
               params: Pytree) -> Tuple[Pytree, Dict[str, Pytree]]:
        step = state["step"] + 1
        lr = self.lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g32
            v = self.b2 * v + (1 - self.b2) * g32 * g32
            u = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; for the 1T MoE)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[Array], Array]
    decay: float = 0.8          # beta2_t = 1 - step**-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    def init(self, params: Pytree) -> Dict[str, Pytree]:
        def st(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree_util.tree_map(st, params,
                                              is_leaf=lambda x: hasattr(
                                                  x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads: Pytree, state: Dict[str, Pytree],
               params: Pytree) -> Tuple[Pytree, Dict[str, Pytree]]:
        step = state["step"] + 1
        lr = self.lr(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-self.decay)

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if self._factored(p.shape):
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                u = g32 / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
                           + 1e-12)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = g32 / (jnp.sqrt(v) + 1e-12)
                new_st = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["fac"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"fac": new_s, "step": step}


def make_optimizer(cfg, base_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000):
    sched = cosine_schedule(base_lr, warmup, total)
    if cfg.optimizer == "adafactor":
        return Adafactor(lr=sched)
    return AdamW(lr=sched)
