"""Token-choice top-k MoE (olmoe-1b-7b, kimi-k2-1t-a32b).

Dispatch is sort-based with static capacity buffers — the GSPMD-provable
formulation (einsum expert matmuls over [E, C, H] buffers; scatter/gather
carry no FLOPs):

  1. router top-k → (expert_id, gate) per token-slot,
  2. rank-in-expert via sorted-run arithmetic (no [T·k, E] one-hot cumsum),
  3. token indices scattered into an [E, C] slot table (overflow drops — the
     classic capacity-factor semantics),
  4. expert FFN as one batched einsum over [E, C, H] (E shards over "model"
     = expert parallelism; GSPMD inserts the dispatch/combine collectives),
  5. combine = gather + gate-weighted sum over the k slots.

kimi-k2 extras: ``first_k_dense`` leading dense blocks and
``n_shared_experts`` always-on shared expert(s) added to the MoE output.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]

AUX_LOSS_COEF = 0.01

# Explicit-EP mesh (set by launch.dryrun / launch.train before tracing).
# When not None, moe_ffn routes through the shard_map expert-parallel path
# (moe_ffn_shard_map) instead of the GSPMD formulation — the §Perf fix for
# GSPMD replicating the [E, C, H] dispatch buffer (see EXPERIMENTS.md).
SHARD_MAP_MESH = None


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------

def moe_ffn_init(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    dt = cfg.jax_dtype
    e, h, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale = h ** -0.5

    def w(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    p = {
        "router": {"w": w(ks[0], (h, e))},
        "w_gate": w(ks[1], (e, h, f)),
        "w_up": w(ks[2], (e, h, f)),
        "w_down": (jax.random.normal(ks[3], (e, f, h), jnp.float32)
                   * f ** -0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], h, f * cfg.n_shared_experts, dt,
                                 cfg.gated_mlp)
    return p


def moe_ffn(p: Params, x: Array, cfg) -> Tuple[Array, Array]:
    """x [B, S, H] → (y [B, S, H], aux_loss scalar)."""
    if SHARD_MAP_MESH is not None:
        return moe_ffn_shard_map(p, x, cfg, SHARD_MAP_MESH)
    b, s, h = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    cap = max(1, math.ceil(t * k * cfg.capacity_factor / e))

    xf = x.reshape(t, h)
    logits = jnp.einsum("th,he->te", xf.astype(jnp.float32), p["router"]["w"]
                        .astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate_vals, eidx = jax.lax.top_k(probs, k)                  # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce) * AUX_LOSS_COEF

    # ---- rank-in-expert via sorted runs --------------------------------
    slots_e = eidx.reshape(t * k)                              # [T·k]
    slot_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(slots_e)
    sorted_e = slots_e[order]
    counts = jnp.bincount(slots_e, length=e)                   # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    inv = jnp.argsort(order)
    rank = rank_sorted[inv]                                    # [T·k]

    # ---- dispatch: slot table then gather -------------------------------
    slot_table = jnp.full((e, cap), t, jnp.int32)              # t = OOB row
    slot_table = slot_table.at[slots_e, rank].set(slot_tok, mode="drop")
    x_pad = jnp.concatenate([xf, jnp.zeros((1, h), xf.dtype)], axis=0)
    buf = x_pad[slot_table]                                    # [E, C, H]

    # ---- expert FFN (EP einsum) -----------------------------------------
    act = L.activation_fn(cfg.activation)
    hidden = act(jnp.einsum("ech,ehf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ech,ehf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efh->ech", hidden, p["w_down"])  # [E, C, H]

    # ---- combine ---------------------------------------------------------
    in_cap = (rank < cap)
    y_slots = out_buf[slots_e, jnp.minimum(rank, cap - 1)]     # [T·k, H]
    y_slots = jnp.where(in_cap[:, None], y_slots, 0.0)
    y = jnp.sum(y_slots.reshape(t, k, h)
                * gate_vals.astype(y_slots.dtype)[..., None], axis=1)
    y = y.reshape(b, s, h).astype(x.dtype)

    if "shared" in p:
        y = y + L.mlp(p["shared"], x, cfg.activation)
    return y, aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel path (shard_map)
# ---------------------------------------------------------------------------
# Why: under pure GSPMD, scatter/gather between data-sharded tokens and the
# model-sharded [E, C, H] capacity buffer lowers to zero-pad + full-buffer
# all-reduce (~150 GB/layer at kimi scale — measured 10.8 TB/step/device).
# With shard_map the structure is explicit and nearly collective-free:
#   * activations are data-sharded and model-REPLICATED, so every model
#     shard already holds the tokens it needs — dispatch is local;
#   * each model shard builds buffers only for its own E/TP experts;
#   * 2-D ("expert_sharding=2d") weights all_gather their F shards over
#     "data" (FSDP-style, the unavoidable 1T-model term);
#   * combine is one psum over "model" of the gate-weighted outputs.
# Capacity becomes per-(data-shard, expert) — same expected load, documented
# semantic difference vs the global-capacity GSPMD path.

def moe_ffn_shard_map(p: Params, x: Array, cfg, mesh) -> Tuple[Array, Array]:
    from jax.sharding import PartitionSpec as P
    e, k, h = cfg.num_experts, cfg.top_k, cfg.d_model
    two_d = getattr(cfg, "expert_sharding", "1d") == "2d"
    tp = mesh.shape["model"]
    dp_names = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def inner(xl, router_w, wg, wu, wd):
        b, s, _ = xl.shape
        t = b * s
        e_loc = wg.shape[0]
        cap = max(1, math.ceil(t * k * cfg.capacity_factor / e))
        m_idx = jax.lax.axis_index("model")

        xf = xl.reshape(t, h)
        logits = jnp.einsum("th,he->te", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eidx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32),
                      axis=0)
        aux = e * jnp.sum(me * ce) * AUX_LOSS_COEF
        aux = jax.lax.pmean(aux, dp_names)

        # local rank-in-expert (global expert ids, local tokens)
        slots_e = eidx.reshape(t * k)
        slot_tok = jnp.arange(t * k, dtype=jnp.int32) // k
        order = jnp.argsort(slots_e)
        counts = jnp.bincount(slots_e, length=e)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        rank_sorted = jnp.arange(t * k, dtype=jnp.int32) \
            - starts[slots_e[order]]
        rank = rank_sorted[jnp.argsort(order)]

        # keep only this model shard's experts; OOB rows drop
        local_e = slots_e - m_idx * e_loc
        owned = (local_e >= 0) & (local_e < e_loc) & (rank < cap)
        le = jnp.where(owned, local_e, e_loc)
        rk = jnp.where(owned, rank, cap)
        slot_table = jnp.full((e_loc, cap), t, jnp.int32)
        slot_table = slot_table.at[le, rk].set(slot_tok, mode="drop")
        x_pad = jnp.concatenate([xf, jnp.zeros((1, h), xf.dtype)], axis=0)
        buf = x_pad[slot_table]                          # [E_loc, C, H]

        if two_d:                                        # FSDP F-gather
            wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=1, tiled=True)

        act = L.activation_fn(cfg.activation)
        hidden = act(jnp.einsum("ech,ehf->ecf", buf, wg)) \
            * jnp.einsum("ech,ehf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efh->ech", hidden, wd)  # [E_loc, C, H]

        y_slots = out_buf[jnp.minimum(le, e_loc - 1),
                          jnp.minimum(rk, cap - 1)]
        y_slots = jnp.where(owned[:, None], y_slots, 0.0)
        y = jnp.sum(y_slots.reshape(t, k, h)
                    * gate_vals.astype(y_slots.dtype)[..., None], axis=1)
        # local gate-weighted sum accumulates fp32; the cross-shard combine
        # rides bf16 (halves the psum payload — A2 in EXPERIMENTS.md §Perf;
        # ≤ TP-width shards summed, bf16 is the production norm).
        y = jax.lax.psum(y.astype(xl.dtype), "model")
        return y.reshape(b, s, h), aux

    dp = dp_names if len(dp_names) > 1 else dp_names[0]
    w_f_spec = "data" if two_d else None
    # jax.shard_map is 0.5+; this tree pins 0.4.x where it lives under
    # jax.experimental (same semantics, same kwargs).
    from jax.experimental.shard_map import shard_map as _shard_map
    out, aux = _shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P("model", None, w_f_spec), P("model", None, w_f_spec),
                  P("model", w_f_spec, None)),
        out_specs=(P(dp, None, None), P()),
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        out = out + L.mlp(p["shared"], x, cfg.activation)
    return out, aux


# ---------------------------------------------------------------------------
# Blocks / model
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    dt = cfg.jax_dtype
    return {
        "attn_norm": L.norm_init(cfg.d_model, dt),
        "attn": L.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim, dt),
        "mlp_norm": L.norm_init(cfg.d_model, dt),
        "moe": moe_ffn_init(ks[1], cfg),
    }


def moe_block(p: Params, x: Array, positions: Array, cfg) -> Tuple[Array, Array]:
    x = x + L.causal_attention(p["attn"], L.rmsnorm(p["attn_norm"], x,
                                                    cfg.norm_eps),
                               cfg, positions)
    y, aux = moe_ffn(p["moe"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return x + y, aux


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    nd, nm = cfg.first_k_dense, cfg.num_layers - cfg.first_k_dense
    p: Params = {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "layers": jax.vmap(lambda k: init_moe_block(k, cfg))(
            jax.random.split(ks[1], nm)),
        "final_norm": L.norm_init(cfg.d_model, dt),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt),
    }
    if nd:
        p["dense_layers"] = jax.vmap(lambda k: T.init_block(k, cfg))(
            jax.random.split(ks[3], nd))
    return p


def forward(p: Params, cfg, tokens: Array) -> Tuple[Array, Array]:
    """tokens [B, S] → (logits, aux_loss)."""
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    if "dense_layers" in p:
        dense_body = L.ckpt(T.block, cfg, static_argnums=(3,))
        x, _ = L.xscan(
            lambda x, lp: (dense_body(lp, x, positions, cfg), None),
            x, p["dense_layers"])

    body = L.ckpt(moe_block, cfg, static_argnums=(3,))

    def scan_fn(x, lp):
        x, aux = body(lp, x, positions, cfg)
        return x, aux

    x, auxs = L.xscan(scan_fn, x, p["layers"])
    logits = T.logits_head(p, x, cfg)
    return logits, jnp.sum(auxs)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    logits, aux = forward(p, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> Params:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    nd, nm = cfg.first_k_dense, cfg.num_layers - cfg.first_k_dense
    c = {"moe": {"k": jnp.zeros((nm, batch, max_len, kvh, hd), cfg.jax_dtype),
                 "v": jnp.zeros((nm, batch, max_len, kvh, hd), cfg.jax_dtype)}}
    if nd:
        c["dense"] = {
            "k": jnp.zeros((nd, batch, max_len, kvh, hd), cfg.jax_dtype),
            "v": jnp.zeros((nd, batch, max_len, kvh, hd), cfg.jax_dtype)}
    return c


def prefill(p: Params, cfg, tokens: Array, max_len: Optional[int] = None
            ) -> Tuple[Array, Params]:
    b, s = tokens.shape
    t = max_len or s
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)
    pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
    cache: Params = {}

    def kv_of(lp, x):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        k = L.apply_rope(L._split_heads(L.dense(lp["attn"]["wk"], h),
                                        cfg.num_kv_heads), positions,
                         cfg.rope_theta)
        v = L._split_heads(L.dense(lp["attn"]["wv"], h), cfg.num_kv_heads)
        return {"k": jnp.pad(k.astype(cfg.jax_dtype), pad),
                "v": jnp.pad(v.astype(cfg.jax_dtype), pad)}

    if "dense_layers" in p:
        def scan_d(x, lp):
            kv = kv_of(lp, x)
            return T.block(lp, x, positions, cfg), kv
        x, cache["dense"] = L.xscan(scan_d, x, p["dense_layers"])

    def scan_m(x, lp):
        kv = kv_of(lp, x)
        x, _ = moe_block(lp, x, positions, cfg)
        return x, kv

    x, cache["moe"] = L.xscan(scan_m, x, p["layers"])
    logits = T.logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, cache


def decode_step(p: Params, cfg, token: Array, cache: Params, pos: Array
                ) -> Tuple[Array, Params]:
    x = p["embed"]["w"][token][:, None, :]
    new_cache: Params = {}

    if "dense_layers" in p:
        def scan_d(x, inp):
            lp, c = inp
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            a, c = L.decode_attention(lp["attn"], h, c, pos, cfg)
            x = x + a
            x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["mlp_norm"], x,
                                               cfg.norm_eps), cfg.activation)
            return x, c
        x, new_cache["dense"] = L.xscan(scan_d, x,
                                             (p["dense_layers"],
                                              cache["dense"]))

    def scan_m(x, inp):
        lp, c = inp
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        a, c = L.decode_attention(lp["attn"], h, c, pos, cfg)
        x = x + a
        y, _ = moe_ffn(lp["moe"], L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps),
                       cfg)
        return x + y, c

    x, new_cache["moe"] = L.xscan(scan_m, x, (p["layers"], cache["moe"]))
    return T.logits_head(p, x, cfg)[:, 0], new_cache
