"""Unified per-family model API (used by launch/, serving/, tests/).

``model_fns(cfg)`` returns a ``ModelFns`` with a common signature across the
six families; ``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins
for every input of the requested workload kind (the dry-run pattern — no
device allocation ever happens for full configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, hybrid, mamba2, moe, transformer, vlm

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init: Callable                      # (key, cfg) -> params
    loss_fn: Callable                   # (params, cfg, batch) -> scalar
    prefill: Callable                   # (params, cfg, *inputs) -> (logits, cache)
    decode_step: Callable               # (params, cfg, token, cache, pos)
    init_cache: Callable                # (cfg, batch, max_len) -> cache
    forward: Optional[Callable] = None


_FAMILY = {
    "dense": ModelFns(transformer.init, transformer.loss_fn,
                      transformer.prefill, transformer.decode_step,
                      transformer.init_cache, transformer.forward),
    "moe": ModelFns(moe.init, moe.loss_fn, moe.prefill, moe.decode_step,
                    moe.init_cache, moe.forward),
    "ssm": ModelFns(mamba2.init, mamba2.loss_fn, mamba2.prefill,
                    mamba2.decode_step,
                    lambda cfg, b, m: mamba2.init_state(cfg, b),
                    mamba2.forward),
    "hybrid": ModelFns(hybrid.init, hybrid.loss_fn, hybrid.prefill,
                       hybrid.decode_step, hybrid.init_state, hybrid.forward),
    "vlm": ModelFns(vlm.init, vlm.loss_fn, vlm.prefill, vlm.decode_step,
                    vlm.init_cache, vlm.forward),
    "audio": ModelFns(encdec.init, encdec.loss_fn, encdec.prefill,
                      encdec.decode_step, encdec.init_cache, encdec.forward),
}


def model_fns(cfg: ArchConfig) -> ModelFns:
    return _FAMILY[cfg.family]


def abstract_params(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocating anything."""
    fns = model_fns(cfg)
    return jax.eval_shape(lambda k: fns.init(k, cfg), jax.random.PRNGKey(0))


def param_count(cfg: ArchConfig) -> int:
    import math
    shapes = abstract_params(cfg)
    return sum(math.prod(l.shape)
               for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ArchConfig) -> int:
    """Matmul-active params per token for the 6·N·D MODEL_FLOPS convention:
    MoE counts top_k of num_experts; the input embedding is excluded when
    untied (pure gather — no FLOPs), kept once when tied (it IS the head)."""
    total = param_count(cfg)
    if not cfg.tie_embeddings:
        total -= cfg.padded_vocab * cfg.d_model      # gather-only embed table
    if not cfg.num_experts:
        return total
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    expert_params = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe_layers * (cfg.num_experts - cfg.top_k) * expert_params
    return total - inactive


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, per workload kind)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     cfg.jax_dtype)
    if cfg.family == "audio":
        specs["frames"] = _sds((b, s, cfg.d_model), cfg.jax_dtype)
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Positional inputs of fns.prefill after (params, cfg)."""
    b, s = shape.global_batch, shape.seq_len
    tokens = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        return (tokens, _sds((b, cfg.num_image_tokens, cfg.d_model),
                             cfg.jax_dtype))
    if cfg.family == "audio":
        return (_sds((b, s, cfg.d_model), cfg.jax_dtype), tokens)
    return (tokens,)


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """(token, cache, pos) specs for fns.decode_step."""
    b, s = shape.global_batch, shape.seq_len
    fns = model_fns(cfg)
    cache = jax.eval_shape(lambda: fns.init_cache(cfg, b, s))
    return (_sds((b,), jnp.int32), cache, _sds((b,), jnp.int32))


def make_fake_batch(cfg: ArchConfig, shape: ShapeSpec, key=None
                    ) -> Dict[str, Array]:
    """Concrete synthetic batch matching train_batch_specs (smoke/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = train_batch_specs(cfg, shape)
    out: Dict[str, Array] = {}
    for name, sp in sorted(specs.items()):
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sp.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sp.shape, 0, cfg.vocab,
                                           sp.dtype)
        else:
            out[name] = jax.random.normal(sub, sp.shape, jnp.float32) \
                .astype(sp.dtype)
    return out
