"""Unified per-family model API (used by launch/, serving/, tests/).

``model_fns(cfg)`` returns a ``ModelFns`` with a common signature across the
six families; ``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins
for every input of the requested workload kind (the dry-run pattern — no
device allocation ever happens for full configs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, hybrid, mamba2, moe, transformer, vlm

Array = jax.Array


def tokens_prefill_inputs(cfg, tokens, make, mem_len=None):
    """Default ``ModelFns.prefill_inputs``: the token matrix is the whole
    prefill input (dense, moe, ssm, hybrid)."""
    return (tokens,)


def no_batch_extras(cfg, b, s, make):
    """Default ``ModelFns.batch_extras``: tokens/labels are the whole
    training batch."""
    return {}


@dataclasses.dataclass(frozen=True)
class ModelFns:
    """Per-family model surface.

    ``prefill_inputs``/``batch_extras`` describe the family's EXTRA
    positional prefill inputs and training-batch members (vlm image
    embeddings, audio encoder frames) through one table, so every
    consumer — spec builders here, the serving engine's admission path —
    reads the same contract instead of growing its own ``cfg.family``
    if-chain (the per-family table drift ``splice_cache`` warns about).
    ``make(shape, dtype)`` is the leaf constructor: ShapeDtypeStruct for
    specs, ``jnp.zeros`` for the serving engine's placeholder inputs.
    """
    init: Callable                      # (key, cfg) -> params
    loss_fn: Callable                   # (params, cfg, batch) -> scalar
    prefill: Callable                   # (params, cfg, *inputs) -> (logits, cache)
    decode_step: Callable               # (params, cfg, token, cache, pos)
    init_cache: Callable                # (cfg, batch, max_len) -> cache
    forward: Optional[Callable] = None
    # (cfg, tokens, make, mem_len) -> positional prefill inputs
    prefill_inputs: Callable = tokens_prefill_inputs
    # (cfg, b, s, make) -> {name: leaf} extra training-batch members
    batch_extras: Callable = no_batch_extras


def run_decode_block(step: Callable, sampler: Callable, max_block: int,
                     tok: Array, cache, pos: Array, n_steps,
                     stop_table: Array, key, round0):
    """Bounded on-device multi-token decode loop — N steps, ONE dispatch.

    Every family's ``decode_step`` already has a scan-able signature (all
    array arguments, static shapes), so one loop serves them all:
    ``step(tok, cache, pos) -> (logits, cache)`` is the single-token fn
    closed over params/config (and any loop-invariant extras such as the
    decomposed cache's ``frozen_len``).

    The carry is ``(i, done_mask, last_tok, cache, pos, token_buf)``; the
    sampler runs ON DEVICE each iteration (``sampler(logits, 1)``, plus a
    per-round PRNG key ``fold_in(key, round0 + i)`` when the sampler
    declares ``takes_key = True`` — the host's single-step path folds the
    same round index, so stochastic sampling stays byte-identical across
    block sizes).  The loop exits EARLY the first step any slot emits one
    of its stop tokens (``stop_table`` int32 [B, W], −1-padded rows, one
    row per slot): stops can then only land on the final returned step, so
    the host's one-pass EOS/stop/budget bookkeeping at the block boundary
    replays the single-step engine's decisions exactly (slots free and
    admission retries happen at the same round they would have).

    Returns ``(token_buf [max_block, B], steps_done, done_mask, cache)``;
    rows of ``token_buf`` at or beyond ``steps_done`` are zeros.
    """
    takes_key = bool(getattr(sampler, "takes_key", False))
    b = tok.shape[0]
    buf0 = jnp.zeros((max_block, b), jnp.int32)
    done0 = jnp.zeros((b,), bool)
    n_steps = jnp.asarray(n_steps, jnp.int32)
    round0 = jnp.asarray(round0, jnp.int32)

    def cond(carry):
        i, done = carry[0], carry[1]
        return (i < n_steps) & ~done.any()

    def body(carry):
        i, _, tok, cache, pos, buf = carry
        logits, cache = step(tok, cache, pos)
        if takes_key:
            nxt = sampler(logits, 1, jax.random.fold_in(key, round0 + i))
        else:
            nxt = sampler(logits, 1)
        nxt = nxt.astype(jnp.int32)
        buf = jax.lax.dynamic_update_index_in_dim(buf, nxt, i, 0)
        done = (nxt[:, None] == stop_table).any(axis=1)
        return (i + 1, done, nxt, cache, pos + 1, buf)

    i, done, _, cache, _, buf = jax.lax.while_loop(
        cond, body, (jnp.int32(0), done0, tok, cache, pos, buf0))
    return buf, i, done, cache


_FAMILY = {
    "dense": ModelFns(transformer.init, transformer.loss_fn,
                      transformer.prefill, transformer.decode_step,
                      transformer.init_cache, transformer.forward),
    "moe": ModelFns(moe.init, moe.loss_fn, moe.prefill, moe.decode_step,
                    moe.init_cache, moe.forward),
    "ssm": ModelFns(mamba2.init, mamba2.loss_fn, mamba2.prefill,
                    mamba2.decode_step, mamba2.init_state, mamba2.forward),
    "hybrid": ModelFns(hybrid.init, hybrid.loss_fn, hybrid.prefill,
                       hybrid.decode_step, hybrid.init_state, hybrid.forward),
    "vlm": ModelFns(vlm.init, vlm.loss_fn, vlm.prefill, vlm.decode_step,
                    vlm.init_cache, vlm.forward,
                    prefill_inputs=vlm.prefill_inputs,
                    batch_extras=vlm.batch_extras),
    "audio": ModelFns(encdec.init, encdec.loss_fn, encdec.prefill,
                      encdec.decode_step, encdec.init_cache, encdec.forward,
                      prefill_inputs=encdec.prefill_inputs,
                      batch_extras=encdec.batch_extras),
}


def model_fns(cfg: ArchConfig) -> ModelFns:
    return _FAMILY[cfg.family]


# ---------------------------------------------------------------------------
# Cache splicing (per-slot admission support, every family)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def cache_batch_axes(cfg: ArchConfig):
    """Pytree (matching ``init_cache``'s structure) of each leaf's batch
    axis, derived by probing ``init_cache`` at two batch sizes — no
    per-family table to drift when a family adds a cache leaf.  The batch
    axis is NOT uniform across families (hybrid mamba state and vlm self
    KV carry leading group axes), which is why gang admission used to be
    the only safe policy for them."""
    fns = model_fns(cfg)
    a = jax.eval_shape(lambda: fns.init_cache(cfg, 2, 8))
    b = jax.eval_shape(lambda: fns.init_cache(cfg, 5, 8))

    def axis(x, y):
        d = [i for i, (m, n) in enumerate(zip(x.shape, y.shape)) if m != n]
        assert len(d) == 1, f"ambiguous batch axis for leaf {x.shape}"
        return d[0]

    return jax.tree_util.tree_map(axis, a, b)


def cache_shardings(cfg: ArchConfig, cache, mesh, seq_shard: bool = True):
    """NamedSharding tree for any family's decode cache (dense k/v AND the
    low-rank ``k_u``/``k_vt`` leaves); the serving engine places every
    cache it allocates through this (with ``seq_shard=False`` — slot-axis
    DP only).  Rules live in ``distributed.sharding.cache_pspec``."""
    from ..distributed import sharding as sh
    return sh.cache_sharding(cache, mesh, cfg, seq_shard=seq_shard)


def splice_cache(cfg: ArchConfig, old, new, slot_indices,
                 src_indices=None):
    """Scatter batch rows ``src_indices`` (default ``0…n−1``) of ``new``
    into ``old`` at ``slot_indices`` along each leaf's batch axis.  ``new``
    may carry more batch rows than ``len(slot_indices)`` (bucketed prefill
    padding); the excess rows are dropped.  Live slots' rows are untouched,
    so admission never re-prefills in-flight sequences — any family."""
    axes = cache_batch_axes(cfg)
    idx = jnp.asarray(slot_indices, jnp.int32)      # traced-input friendly
    src = jnp.arange(idx.shape[0], dtype=jnp.int32) \
        if src_indices is None else jnp.asarray(src_indices, jnp.int32)

    def one(o, nw, ax):
        om = jnp.moveaxis(o, ax, 0)
        nm = jnp.moveaxis(nw, ax, 0)[src].astype(o.dtype)
        return jnp.moveaxis(om.at[idx].set(nm), 0, ax)

    return jax.tree_util.tree_map(one, old, new, axes)


def tree_ready(tree) -> bool:
    """Non-blocking done-probe over a pytree of in-flight jax arrays.

    ``jax.Array.is_ready()`` asks the runtime whether the producing
    computation has finished WITHOUT synchronizing on it — this is the
    cheap fence the async serving engine polls at step boundaries to
    decide whether an in-flight prefill/Lanczos result can be spliced.
    Leaves without ``is_ready`` (numpy arrays, python scalars) count as
    ready."""
    for leaf in jax.tree_util.tree_leaves(tree):
        probe = getattr(leaf, "is_ready", None)
        if probe is not None and not probe():
            return False
    return True


def splice_on_ready(cfg: ArchConfig, old, new, slot_indices,
                    src_indices=None):
    """Splice-if-done: returns ``splice_cache(...)`` when every leaf of
    ``new`` is ready (its producing prefill has finished on device), or
    ``None`` — meaning "not yet, keep decoding" — without blocking.
    The async engine's ticket pool is built on this entry point's
    probe+splice pairing."""
    if not tree_ready(new):
        return None
    return splice_cache(cfg, old, new, slot_indices, src_indices)


@dataclasses.dataclass(frozen=True)
class DecomposedFns:
    """Decomposed-execution surface, bound to ONE DecomposeEngine.

    ``forward``/``logit_kl`` run policy-selected decomposed blocks;
    ``prefill_dkv``/``decode_step_dkv``/``compress_tail`` are the
    decomposed-KV-cache serving path.  Obtain via :func:`decomposed_fns`.
    """
    engine: Any
    forward: Callable               # (params, tokens) -> logits
    logit_kl: Callable              # (params, tokens) -> scalar
    prefill_dkv: Callable           # (params, tokens, rank, ...) -> (logits, cache)
    decode_step_dkv: Callable       # (params, token, cache, pos, frozen_len)
    compress_tail: Callable         # (cache, rank[, frozen_len, fold]) -> cache
    splice_dkv: Callable = None     # (live, fresh, slot_indices) -> cache


def decomposed_fns(cfg: ArchConfig, engine) -> DecomposedFns:
    """Bind the decomposed-execution entry points to ``engine``.

    The engine (a ``repro.engine.DecomposeEngine``) is the ONLY source of
    decomposition for everything returned here — consumers never touch
    ranks, hooks, or backends directly.  Dense family only (the engine's
    decomposed paths are implemented for the dense transformer).
    """
    assert cfg.family == "dense", "decomposed execution: dense family"
    from . import decomposed as D
    from . import decomposed_kv as DK
    runtime = D.DecomposedRuntime(engine=engine) \
        if engine.config.policy is not None else None

    def forward(params, tokens, wfactors=None):
        assert runtime is not None, "engine has no decomposition policy"
        return D.forward(params, cfg, tokens, runtime, wfactors)

    def logit_kl(params, tokens, wfactors=None):
        assert runtime is not None, "engine has no decomposition policy"
        return D.logit_kl(params, cfg, tokens, runtime, wfactors)

    def prefill_dkv(params, tokens, rank=None, tail=None, exact=False):
        return DK.prefill_dkv(
            params, cfg, tokens,
            engine.config.kv_rank if rank is None else rank,
            tail=engine.config.kv_tail if tail is None else tail,
            exact=exact, engine=engine)

    def decode_step_dkv(params, token, cache, pos, frozen_len):
        return DK.decode_step_dkv(params, cfg, token, cache, pos, frozen_len)

    def compress_tail(cache, rank=None, frozen_len=None, fold=None):
        return DK.compress_tail(
            cache, cfg, engine.config.kv_rank if rank is None else rank,
            frozen_len=frozen_len, fold=fold)

    return DecomposedFns(engine, forward, logit_kl, prefill_dkv,
                         decode_step_dkv, compress_tail, DK.splice_dkv)


def abstract_params(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs without allocating anything."""
    fns = model_fns(cfg)
    return jax.eval_shape(lambda k: fns.init(k, cfg), jax.random.PRNGKey(0))


def param_count(cfg: ArchConfig) -> int:
    import math
    shapes = abstract_params(cfg)
    return sum(math.prod(l.shape)
               for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ArchConfig) -> int:
    """Matmul-active params per token for the 6·N·D MODEL_FLOPS convention:
    MoE counts top_k of num_experts; the input embedding is excluded when
    untied (pure gather — no FLOPs), kept once when tied (it IS the head)."""
    total = param_count(cfg)
    if not cfg.tie_embeddings:
        total -= cfg.padded_vocab * cfg.d_model      # gather-only embed table
    if not cfg.num_experts:
        return total
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    expert_params = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe_layers * (cfg.num_experts - cfg.top_k) * expert_params
    return total - inactive


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, per workload kind)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    specs.update(model_fns(cfg).batch_extras(cfg, b, s, _sds))
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Positional inputs of fns.prefill after (params, cfg)."""
    b, s = shape.global_batch, shape.seq_len
    tokens = _sds((b, s), jnp.int32)
    return model_fns(cfg).prefill_inputs(cfg, tokens, _sds, mem_len=s)


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """(token, cache, pos) specs for fns.decode_step."""
    b, s = shape.global_batch, shape.seq_len
    fns = model_fns(cfg)
    cache = jax.eval_shape(lambda: fns.init_cache(cfg, b, s))
    return (_sds((b,), jnp.int32), cache, _sds((b,), jnp.int32))


def make_fake_batch(cfg: ArchConfig, shape: ShapeSpec, key=None
                    ) -> Dict[str, Array]:
    """Concrete synthetic batch matching train_batch_specs (smoke/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = train_batch_specs(cfg, shape)
    out: Dict[str, Array] = {}
    for name, sp in sorted(specs.items()):
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sp.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sp.shape, 0, cfg.vocab,
                                           sp.dtype)
        else:
            out[name] = jax.random.normal(sub, sp.shape, jnp.float32) \
                .astype(sp.dtype)
    return out
