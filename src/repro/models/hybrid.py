"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

Layer layout (total = ``num_layers``): groups of (attn_period − 1) mamba
blocks followed by one invocation of the single shared attention+MLP block
(same weights every time, distinct KV cache per invocation), plus a tail of
leftover mamba blocks.  E.g. zamba2-1.2b: 38 = 6 × (5 mamba + shared attn)
+ 2 mamba.

The released model also applies per-invocation LoRA deltas to the shared
block; omitted here (noted in DESIGN.md — orthogonal to the paper's
technique).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as M
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def _layout(cfg) -> Tuple[int, int, int]:
    """(groups, mamba_per_group, tail_mamba)."""
    per = cfg.attn_period
    groups = cfg.num_layers // per
    tail = cfg.num_layers - groups * per
    return groups, per - 1, tail


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    dt = cfg.jax_dtype
    groups, mpg, tail = _layout(cfg)
    p: Params = {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "mamba": jax.vmap(jax.vmap(lambda k: M.init_block(k, cfg)))(
            jax.random.split(ks[1], groups * mpg).reshape(groups, mpg, 2)),
        "shared": T.init_block(ks[2], cfg),
        "final_norm": L.norm_init(cfg.d_model, dt),
        "lm_head": L.dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt),
    }
    if tail:
        p["mamba_tail"] = jax.vmap(lambda k: M.init_block(k, cfg))(
            jax.random.split(ks[4], tail))
    return p


def forward(p: Params, cfg, tokens: Array) -> Array:
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    mblock = L.ckpt(M.block, cfg, static_argnums=(2,))
    ablock = L.ckpt(T.block, cfg, static_argnums=(3,))

    def group_fn(x, gp):
        x, _ = L.xscan(lambda x, lp: (mblock(lp, x, cfg), None), x, gp)
        x = ablock(p["shared"], x, positions, cfg)
        return x, None

    x, _ = L.xscan(group_fn, x, p["mamba"])
    if "mamba_tail" in p:
        x, _ = L.xscan(lambda x, lp: (mblock(lp, x, cfg), None),
                            x, p["mamba_tail"])
    return T.logits_head(p, x, cfg)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    return L.cross_entropy(forward(p, cfg, batch["tokens"]), batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_len: int) -> Params:
    groups, mpg, tail = _layout(cfg)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    st: Params = {
        "mamba": {
            "conv": jnp.zeros((groups, mpg, batch, cfg.ssm_conv_width - 1,
                               conv_ch), cfg.jax_dtype),
            "ssm": jnp.zeros((groups, mpg, batch, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)},
        "attn": {"k": jnp.zeros((groups, batch, max_len, kvh, hd),
                                cfg.jax_dtype),
                 "v": jnp.zeros((groups, batch, max_len, kvh, hd),
                                cfg.jax_dtype)},
    }
    if tail:
        st["tail"] = {
            "conv": jnp.zeros((tail, batch, cfg.ssm_conv_width - 1, conv_ch),
                              cfg.jax_dtype),
            "ssm": jnp.zeros((tail, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)}
    return st


def _mamba_state_of(lp, h_in, cfg, b, s):
    """Final (conv, ssm) state of a mamba block given its normed input."""
    proj = L.dense(lp["ssd"]["in_proj"], h_in)
    _, xbc, dt_raw = M._split_proj(proj, cfg)
    conv_tail = xbc[:, -(cfg.ssm_conv_width - 1):, :].astype(cfg.jax_dtype)
    xbc_f = M._conv_causal(xbc, lp["ssd"]["conv_w"], lp["ssd"]["conv_b"])
    di, ds, nh, hd = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_head_dim)
    xh = xbc_f[..., :di].reshape(b, s, nh, hd).astype(jnp.float32)
    bm = xbc_f[..., di:di + ds].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["ssd"]["dt_bias"])
    da = dtv * (-jnp.exp(lp["ssd"]["a_log"]))
    l = jnp.cumsum(da, axis=1)
    decay_to_end = jnp.exp(l[:, -1:, :] - l)
    ssm = jnp.einsum("bsd,bsn,bsnp->bnpd", bm, dtv * decay_to_end, xh)
    return {"conv": conv_tail, "ssm": ssm}


def prefill(p: Params, cfg, tokens: Array, max_len: Optional[int] = None
            ) -> Tuple[Array, Params]:
    b, s = tokens.shape
    t = max_len or s
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)
    pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
    state: Params = {}

    def mamba_scan(x, lp):
        h_in = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        st = _mamba_state_of(lp, h_in, cfg, b, s)
        return x + M.ssd_apply(lp["ssd"], h_in, cfg), st

    def group_fn(x, gp):
        x, mst = L.xscan(mamba_scan, x, gp)
        h = L.rmsnorm(p["shared"]["attn_norm"], x, cfg.norm_eps)
        k = L.apply_rope(L._split_heads(L.dense(p["shared"]["attn"]["wk"], h),
                                        cfg.num_kv_heads), positions,
                         cfg.rope_theta)
        v = L._split_heads(L.dense(p["shared"]["attn"]["wv"], h),
                           cfg.num_kv_heads)
        kv = {"k": jnp.pad(k.astype(cfg.jax_dtype), pad),
              "v": jnp.pad(v.astype(cfg.jax_dtype), pad)}
        x = T.block(p["shared"], x, positions, cfg)
        return x, (mst, kv)

    x, (mst, kv) = L.xscan(group_fn, x, p["mamba"])
    state["mamba"], state["attn"] = mst, kv
    if "mamba_tail" in p:
        x, tst = L.xscan(mamba_scan, x, p["mamba_tail"])
        state["tail"] = tst
    logits = T.logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, state


def decode_step(p: Params, cfg, token: Array, state: Params, pos: Array
                ) -> Tuple[Array, Params]:
    x = p["embed"]["w"][token][:, None, :]

    def mamba_step(x, inp):
        lp, st = inp
        y, st = M.ssd_decode(lp["ssd"], L.rmsnorm(lp["norm"], x, cfg.norm_eps),
                             st, cfg)
        return x + y, st

    def group_fn(x, inp):
        gp, mst, kv = inp
        x, mst = L.xscan(mamba_step, x, (gp, mst))
        h = L.rmsnorm(p["shared"]["attn_norm"], x, cfg.norm_eps)
        a, kv = L.decode_attention(p["shared"]["attn"], h, kv, pos, cfg)
        x = x + a
        x = x + L.mlp(p["shared"]["mlp"],
                      L.rmsnorm(p["shared"]["mlp_norm"], x, cfg.norm_eps),
                      cfg.activation)
        return x, (mst, kv)

    x, (mst, kv) = L.xscan(group_fn, x,
                                (p["mamba"], state["mamba"], state["attn"]))
    new_state: Params = {"mamba": mst, "attn": kv}
    if "mamba_tail" in p:
        x, tst = L.xscan(mamba_step, x,
                              (p["mamba_tail"], state["tail"]))
        new_state["tail"] = tst
    return T.logits_head(p, x, cfg)[:, 0], new_state
