"""Llama-3.2-Vision-style VLM backbone: dense decoder + cross-attention
layers every ``cross_attn_period`` layers.  The vision frontend is a STUB —
``input_specs`` supplies precomputed patch embeddings [B, n_img, H] (already
projected to d_model), per the brief.

Layout: groups of (period − 1) self-attn blocks + 1 cross-attn block.
40 layers, period 5 → 8 × (4 self + 1 cross).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def _layout(cfg) -> Tuple[int, int]:
    per = cfg.cross_attn_period
    groups = cfg.num_layers // per
    assert groups * per == cfg.num_layers, "vlm layout must tile evenly"
    return groups, per - 1


def init_cross_block(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    dt = cfg.jax_dtype
    return {
        "attn_norm": L.norm_init(cfg.d_model, dt),
        "xattn": L.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.resolved_head_dim, dt),
        "gate_attn": jnp.zeros((), jnp.float32),      # tanh-gated (llama 3.2)
        "mlp_norm": L.norm_init(cfg.d_model, dt),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.gated_mlp),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    dt = cfg.jax_dtype
    groups, spg = _layout(cfg)
    return {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "self": jax.vmap(jax.vmap(lambda k: T.init_block(k, cfg)))(
            jax.random.split(ks[1], groups * spg).reshape(groups, spg, 2)),
        "cross": jax.vmap(lambda k: init_cross_block(k, cfg))(
            jax.random.split(ks[2], groups)),
        "final_norm": L.norm_init(cfg.d_model, dt),
        "lm_head": L.dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt),
    }


def cross_block(cp: Params, x: Array, image_embeds: Array, cfg) -> Array:
    kv = L.memory_kv(cp["xattn"], image_embeds, cfg.num_kv_heads)
    h = L.cross_attention(cp["xattn"],
                          L.rmsnorm(cp["attn_norm"], x, cfg.norm_eps), kv, cfg)
    x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * h
    m = L.mlp(cp["mlp"], L.rmsnorm(cp["mlp_norm"], x, cfg.norm_eps),
              cfg.activation)
    return x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * m


def forward(p: Params, cfg, tokens: Array, image_embeds: Array) -> Array:
    """tokens [B, S]; image_embeds [B, n_img, H] (stub frontend output)."""
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    sblock = L.ckpt(T.block, cfg, static_argnums=(3,))
    xblock = L.ckpt(cross_block, cfg, static_argnums=(3,))

    def group_fn(x, gp):
        sp, cp = gp
        x, _ = L.xscan(
            lambda x, lp: (sblock(lp, x, positions, cfg), None), x, sp)
        x = xblock(cp, x, image_embeds, cfg)
        return x, None

    x, _ = L.xscan(group_fn, x, (p["self"], p["cross"]))
    return T.logits_head(p, x, cfg)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    logits = forward(p, cfg, batch["tokens"], batch["image_embeds"])
    return L.cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill_inputs(cfg, tokens, make, mem_len=None):
    """``ModelFns.prefill_inputs``: tokens plus the image-embedding block
    (``num_image_tokens`` rows — fixed by the cross-KV cache contract,
    independent of the prompt length)."""
    b = tokens.shape[0]
    return (tokens, make((b, cfg.num_image_tokens, cfg.d_model),
                         cfg.jax_dtype))


def batch_extras(cfg, b, s, make):
    """``ModelFns.batch_extras``: training batches carry image embeddings."""
    return {"image_embeds": make((b, cfg.num_image_tokens, cfg.d_model),
                                 cfg.jax_dtype)}


def init_cache(cfg, batch: int, max_len: int) -> Params:
    groups, spg = _layout(cfg)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "self": {"k": jnp.zeros((groups, spg, batch, max_len, kvh, hd),
                                cfg.jax_dtype),
                 "v": jnp.zeros((groups, spg, batch, max_len, kvh, hd),
                                cfg.jax_dtype)},
        # cross KV is computed once from the image and reused every step
        "cross": {"k": jnp.zeros((groups, batch, cfg.num_image_tokens, kvh,
                                  hd), cfg.jax_dtype),
                  "v": jnp.zeros((groups, batch, cfg.num_image_tokens, kvh,
                                  hd), cfg.jax_dtype)},
    }


def prefill(p: Params, cfg, tokens: Array, image_embeds: Array,
            max_len: Optional[int] = None) -> Tuple[Array, Params]:
    b, s = tokens.shape
    t = max_len or s
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)
    pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]

    def self_scan(x, lp):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        k = L.apply_rope(L._split_heads(L.dense(lp["attn"]["wk"], h),
                                        cfg.num_kv_heads), positions,
                         cfg.rope_theta)
        v = L._split_heads(L.dense(lp["attn"]["wv"], h), cfg.num_kv_heads)
        kv = {"k": jnp.pad(k.astype(cfg.jax_dtype), pad),
              "v": jnp.pad(v.astype(cfg.jax_dtype), pad)}
        return T.block(lp, x, positions, cfg), kv

    def group_fn(x, gp):
        sp, cp = gp
        x, kv = L.xscan(self_scan, x, sp)
        ck, cv = L.memory_kv(cp["xattn"], image_embeds, cfg.num_kv_heads)
        x = cross_block(cp, x, image_embeds, cfg)
        return x, (kv, {"k": ck.astype(cfg.jax_dtype),
                        "v": cv.astype(cfg.jax_dtype)})

    x, (kv, ckv) = L.xscan(group_fn, x, (p["self"], p["cross"]))
    logits = T.logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, {"self": kv, "cross": ckv}


def decode_step(p: Params, cfg, token: Array, cache: Params, pos: Array
                ) -> Tuple[Array, Params]:
    x = p["embed"]["w"][token][:, None, :]

    def self_step(x, inp):
        lp, c = inp
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        a, c = L.decode_attention(lp["attn"], h, c, pos, cfg)
        x = x + a
        x = x + L.mlp(lp["mlp"], L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps),
                      cfg.activation)
        return x, c

    def group_fn(x, inp):
        sp, cp, kv, ckv = inp
        x, kv = L.xscan(self_step, x, (sp, kv))
        h = L.rmsnorm(cp["attn_norm"], x, cfg.norm_eps)
        a = L.cross_attention(cp["xattn"], h, (ckv["k"], ckv["v"]), cfg)
        x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * a
        m = L.mlp(cp["mlp"], L.rmsnorm(cp["mlp_norm"], x, cfg.norm_eps),
                  cfg.activation)
        x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * m
        return x, kv

    x, kv = L.xscan(group_fn, x, (p["self"], p["cross"],
                                       cache["self"], cache["cross"]))
    return T.logits_head(p, x, cfg)[:, 0], {"self": kv,
                                            "cross": cache["cross"]}
