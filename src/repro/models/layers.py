"""Shared neural building blocks (pure-JAX functional, param pytrees).

Conventions
-----------
* Params are nested dicts of arrays; per-layer params are STACKED on a
  leading L axis and consumed with ``jax.lax.scan`` (fast compile for
  61-layer models, uniform HLO for the dry-run).
* Activations carry layout [B, S, H]; attention internals [B, S, n, d].
* Weights init in fp32 then cast to ``dtype``; math in bf16 with fp32
  softmax/normalization (MXU-faithful numerics).
* Everything here is initializable under ``jax.eval_shape`` — the dry-run
  never allocates real parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]

# Query-chunk length for memory-bounded (flash-style) attention.
ATTN_CHUNK = 512

# Cost-calibration mode (set by launch.dryrun probes): fully unroll every
# scan so XLA cost_analysis sees each iteration.  XLA counts a while-loop
# BODY once regardless of trip count, so scanned-layer FLOPs/bytes/
# collective counts are ~L× under-reported; the dry-run lowers small-L
# unrolled probes and extrapolates linearly (see launch.dryrun.calibrate).
COST_EXACT = False

# Inference-path score dtype override (set by launch.dryrun --score-bf16):
# storing the [qc, T] scores bf16 halves the dominant prefill byte stream;
# softmax max-subtraction keeps bf16 exp stable (inference-quality knob,
# §Perf B3).
SCORE_DTYPE = None


def ckpt(fn, cfg, static_argnums=()):
    """jax.checkpoint honoring cfg.remat / cfg.remat_policy ("dots" saves
    matmul outputs so the backward recomputes only cheap elementwise ops —
    trades a little memory for a big cut in recompute bytes)."""
    if not cfg.remat:
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, static_argnums=static_argnums, policy=policy)


def xscan(f, init, xs, length=None):
    """lax.scan that fully unrolls under COST_EXACT (trace-time switch)."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if COST_EXACT else 1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype,
               use_bias: bool = False) -> Params:
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32)
    w = w * (in_dim ** -0.5)
    p = {"w": w.astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def embed_init(key, vocab: int, dim: int, dtype) -> Params:
    # std 1/√dim keeps tied-head logits O(1) (the √dim input multiplier in
    # tied models restores unit-scale embeddings).
    return {"w": (jax.random.normal(key, (vocab, dim), jnp.float32)
                  * dim ** -0.5).astype(dtype)}


def norm_init(dim: int, dtype, with_bias: bool = False) -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def dense(p: Params, x: Array) -> Array:
    y = jnp.einsum("...h,hn->...n", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu,
                                                           approximate=True),
            "geglu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x [..., S, n, d]; positions [..., S] (int).  Rotates pairs (even, odd)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [d/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., S, d/2]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, d/2]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, use_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype, use_bias),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype,
                         use_bias),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype,
                         use_bias),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype, use_bias),
    }


def _split_heads(x: Array, n: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, x.shape[-1] // n))


def _gqa_scores(q: Array, k: Array) -> Array:
    """q [B,S,nh,d], k [B,T,kvh,d] → scores [B,nh,S,T] (fp32 accum).

    Operands stay in their storage dtype (bf16) with fp32 MXU accumulation
    (preferred_element_type) — half the bytes of upcast-then-dot at the
    same numerics (§Perf iteration A4/B2)."""
    b, s, nh, d = q.shape
    kvh = k.shape[2]
    g = nh // kvh
    qg = q.reshape(b, s, kvh, g, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                    preferred_element_type=jnp.float32)
    return sc.reshape(b, nh, s, k.shape[1])


def _gqa_pv(p: Array, v: Array) -> Array:
    """p [B,nh,S,T] (bf16 probs ok), v [B,T,kvh,d] → out [B,S,nh,d] fp32."""
    b, nh, s, t = p.shape
    kvh = v.shape[2]
    g = nh // kvh
    pg = p.reshape(b, kvh, g, s, t)
    out = jnp.einsum("bkgst,btkd->bskgd", pg, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, nh, v.shape[-1])


def attend(q: Array, k: Array, v: Array, positions: Array, *,
           causal: bool = True, chunk: int = 0,
           out_dtype=None) -> Array:
    """Softmax attention over precomputed q [B,S,nh,d], k/v [B,T,kvh,d],
    scanned over query chunks so the [qc, T] score block is the only S²
    activation (flash-style memory).  Returns [B, S, nh·d]."""
    b, s, nh, hd = q.shape
    out_dtype = out_dtype or q.dtype
    scale = hd ** -0.5

    chunk = chunk or ATTN_CHUNK          # module global read at trace time
    qc = min(chunk, s)
    if s % qc != 0:                       # tiny smoke shapes
        qc = s
    n_chunks = s // qc

    def chunk_body(carry, qi):
        del carry
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        sc = _gqa_scores(q_blk, k) * scale            # [B, nh, qc, T]
        if SCORE_DTYPE is not None:
            sc = sc.astype(SCORE_DTYPE)
        if causal:
            pos_blk = jax.lax.dynamic_slice_in_dim(positions, qi * qc, qc,
                                                   axis=-1)
            mask = pos_blk[..., None] >= positions[..., None, :]  # [B, qc, T]
            sc = jnp.where(mask[:, None, :, :], sc,
                           jnp.asarray(-1e30, sc.dtype))
        pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)   # bf16 probs
        return None, _gqa_pv(pr, v).astype(out_dtype)  # [B, qc, nh, d]

    if n_chunks == 1:
        _, out = chunk_body(None, 0)
    else:
        # Remat each chunk: backward recomputes the [qc, T] probs instead of
        # saving n_chunks of them (flash-attention-style S² memory avoidance).
        _, outs = xscan(jax.checkpoint(chunk_body), None,
                        jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, nh, hd)
    return out.reshape(b, s, nh * hd)


def causal_attention(p: Params, x: Array, cfg, positions: Array,
                     chunk: int = 0, causal: bool = True) -> Array:
    """Standard self-attention block body (projections + attend + out-proj)."""
    nh, kvh = cfg.num_heads, cfg.num_kv_heads
    q = _split_heads(dense(p["wq"], x), nh)
    k = _split_heads(dense(p["wk"], x), kvh)
    v = _split_heads(dense(p["wv"], x), kvh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, positions, causal=causal, chunk=chunk,
                 out_dtype=x.dtype)
    return dense(p["wo"], out)


def cross_attention(p: Params, x: Array, memory_kv: Tuple[Array, Array],
                    cfg) -> Array:
    """Cross-attention against precomputed memory K/V [B, M, kvh, d].

    Uses the same query-chunked ``attend`` as self-attention — a dense
    [B, nh, S, M] score tensor at S = M = 4k would be tens of GB fp32.
    """
    nh = cfg.num_heads
    b, s, _ = x.shape
    q = _split_heads(dense(p["wq"], x), nh)
    k, v = memory_kv
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = attend(q, k, v, positions, causal=False, out_dtype=x.dtype)
    return dense(p["wo"], out)


def memory_kv(p: Params, memory: Array, kvh: int) -> Tuple[Array, Array]:
    return (_split_heads(dense(p["wk"], memory), kvh),
            _split_heads(dense(p["wv"], memory), kvh))


# -- KV-cache decode --------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, kvh: int, hd: int, dtype):
    shape = (batch, max_len, kvh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: Params, x: Array, cache: Params, pos: Array, cfg
                     ) -> Tuple[Array, Params]:
    """One-token attention: x [B, 1, H], cache k/v [B, T, kvh, d], pos [B]."""
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    t = cache["k"].shape[1]
    q = _split_heads(dense(p["wq"], x), nh)            # [B, 1, nh, d]
    k_new = _split_heads(dense(p["wk"], x), kvh)
    v_new = _split_heads(dense(p["wv"], x), kvh)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, pb: jax.lax.dynamic_update_slice_in_dim(
                cb, nb, pb, axis=0))(c, new, pos)
    k = upd(cache["k"], k_new.astype(cache["k"].dtype))
    v = upd(cache["v"], v_new.astype(cache["v"].dtype))

    sc = _gqa_scores(q, k) * (hd ** -0.5)              # [B, nh, 1, T]
    valid = jnp.arange(t)[None, :] <= pos[:, None]     # [B, T]
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    out = _gqa_pv(pr, v).astype(x.dtype).reshape(b, 1, nh * hd)
    return dense(p["wo"], out), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool,
             use_bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype, use_bias),
         "down": dense_init(ks[1], d_ff, d_model, dtype, use_bias)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype, use_bias)
    return p


def mlp(p: Params, x: Array, activation: str) -> Array:
    act = activation_fn(activation)
    if "gate" in p:
        h = act(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = act(dense(p["up"], x))
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: Array, labels: Array,
                  ignore_id: int = -1) -> Array:
    """Mean next-token CE; fp32 log-softmax; labels==ignore_id masked.

    The label logit is picked with a masked reduction (NOT take_along_axis):
    a gather on the vocab axis would force GSPMD to all-gather the
    vocab-sharded [B, S, V] logits; the where+sum fuses into a sharded
    reduction with a [B, S] all-reduce instead.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], lg, 0.0), axis=-1)
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
