"""Model zoo: pure-JAX functional models for all assigned architectures."""
from . import api, encdec, hybrid, layers, mamba2, moe, transformer, vlm
from .api import (ModelFns, abstract_params, active_param_count,
                  decode_input_specs, make_fake_batch, model_fns,
                  param_count, prefill_input_specs, train_batch_specs)
