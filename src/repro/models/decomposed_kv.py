"""Decomposed KV cache — the paper's activation decomposition applied to
serving memory (beyond-paper §Perf feature).

Decode is KV-bandwidth-bound: every step re-reads the whole [T, kvh·hd]
cache.  K and V are activations, so D-com's machinery applies directly:
after prefill, each layer's K/V is Lanczos-decomposed into
(U [B, T, r], Vᵀ [B, r, kvh·hd]); per decode step the attention contracts
THROUGH the factors —

  scores = (q · Vᵀ_kᵀ) · Uᵀ_k        (r·d + T·r  vs  T·d  per head-group)
  out    = ((p · U_v) · Vᵀ_v)

so cache bytes read per step shrink by ~d_kv/r (Eq. 10 applied to the KV
stream).  New tokens append to a small DENSE TAIL (exact attention over
recent context); the serving engine re-compresses the tail into the
low-rank prefix on a fixed cadence (rank-concat + retruncate, amortized) —
mirroring the paper's "decomposition once, consumed many times" economics.

Approximation surface: the low-rank prefix (rank r of the RoPE'd K/V rows).
``prefill_dkv`` at full rank reproduces dense attention exactly
(tests/test_decomposed_kv.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..engine import DecomposeEngine, EngineConfig
from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]

TAIL = 128                      # dense recent-token buffer length

# Module-default engine for callers that don't thread one (tests, one-shot
# scripts); serving constructs and reuses its own.
_DEFAULT_ENGINE = DecomposeEngine(EngineConfig())


def init_cache(cfg, batch: int, frozen_len: int, rank: int,
               tail: int = TAIL) -> Params:
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    nl, dt = cfg.num_layers, cfg.jax_dtype
    z = jnp.zeros
    return {
        "k_u": z((nl, batch, frozen_len, rank), dt),
        "k_vt": z((nl, batch, rank, kvw), dt),
        "v_u": z((nl, batch, frozen_len, rank), dt),
        "v_vt": z((nl, batch, rank, kvw), dt),
        "tail": {"k": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt),
                 "v": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt)},
    }


def prefill_dkv(p: Params, cfg, tokens: Array, rank: int,
                tail: int = TAIL, exact: bool = False,
                engine: Optional[DecomposeEngine] = None
                ) -> Tuple[Array, Params]:
    """Dense-family prefill that emits a decomposed KV cache.

    K/V factorization goes through :meth:`DecomposeEngine.decompose_kv`
    (Lanczos via the engine's backend; ``exact`` switches to direct SVD for
    r near full rank, where floating-point Lanczos loses trailing
    directions — §2.3: Lanczos is the small-rank algorithm).
    """
    if rank < 1:
        raise ValueError(f"prefill_dkv needs rank >= 1, got {rank} "
                         "(is the engine's kv_rank configured?)")
    engine = engine or _DEFAULT_ENGINE
    b, s = tokens.shape
    logits, dense_cache = T.prefill(p, cfg, tokens, s)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def one(kv):
        flat = kv.reshape(cfg.num_layers * b, s, kvh * hd)
        u, vt = engine.decompose_kv(flat, rank, exact=exact)
        return (u.reshape(cfg.num_layers, b, s, rank),
                vt.reshape(cfg.num_layers, b, rank, kvh * hd))

    k_u, k_vt = one(dense_cache["k"])
    v_u, v_vt = one(dense_cache["v"])
    z = jnp.zeros((cfg.num_layers, b, tail, kvh, hd), cfg.jax_dtype)
    return logits, {"k_u": k_u, "k_vt": k_vt, "v_u": v_u, "v_vt": v_vt,
                    "tail": {"k": z, "v": z}}


def _lowrank_attention(q: Array, c: Params, tail_kv: Params,
                       pos: Array, frozen_len: int, cfg) -> Array:
    """q [B, 1, nh, d]; low-rank prefix + dense tail → out [B, 1, nh·d]."""
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nh // kvh
    b = q.shape[0]
    scale = hd ** -0.5
    qg = q[:, 0].reshape(b, kvh, g, hd).astype(jnp.float32)

    # ---- prefix scores through the factors ------------------------------
    k_vt = c["k_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    inner = jnp.einsum("bkgd,brkd->bkgr", qg, k_vt)          # [B,kvh,g,r]
    sc_pre = jnp.einsum("bkgr,btr->bkgt", inner,
                        c["k_u"].astype(jnp.float32)) * scale

    # ---- tail scores (exact) ---------------------------------------------
    tk = tail_kv["k"].astype(jnp.float32)                     # [B,tl,kvh,hd]
    sc_tail = jnp.einsum("bkgd,btkd->bkgt", qg, tk) * scale
    tail_pos = frozen_len + jnp.arange(tk.shape[1])[None, :]
    valid = tail_pos <= pos[:, None]                          # [B, tl]
    sc_tail = jnp.where(valid[:, None, None, :], sc_tail, -1e30)

    # ---- joint softmax -----------------------------------------------------
    sc = jnp.concatenate([sc_pre, sc_tail], axis=-1)
    pr = jax.nn.softmax(sc, axis=-1)
    p_pre, p_tail = pr[..., :frozen_len], pr[..., frozen_len:]

    # ---- PV through the factors -------------------------------------------
    tmp = jnp.einsum("bkgt,btr->bkgr", p_pre,
                     c["v_u"].astype(jnp.float32))
    v_vt = c["v_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    out = jnp.einsum("bkgr,brkd->bkgd", tmp, v_vt)
    out = out + jnp.einsum("bkgt,btkd->bkgd", p_tail,
                           tail_kv["v"].astype(jnp.float32))
    return out.reshape(b, 1, nh * hd)


def decode_step_dkv(p: Params, cfg, token: Array, cache: Params,
                    pos: Array, frozen_len: int) -> Tuple[Array, Params]:
    """One-token decode over the decomposed cache (dense transformer)."""
    x = p["embed"]["w"][token][:, None, :] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    kvh = cfg.num_kv_heads

    def scan_fn(x, inp):
        lp, ku, kvt, vu, vvt, tail = inp
        h = T._norm(lp["attn_norm"], x, cfg)
        q = L._split_heads(L.dense(lp["attn"]["wq"], h), cfg.num_heads)
        k_new = L._split_heads(L.dense(lp["attn"]["wk"], h), kvh)
        v_new = L._split_heads(L.dense(lp["attn"]["wv"], h), kvh)
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.apply_rope(k_new, pos[:, None], cfg.rope_theta)

        slot = pos - frozen_len                       # tail write position
        upd = lambda buf, new: jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(
                bb, nn, ss, axis=0))(buf, new.astype(buf.dtype), slot)
        tail = {"k": upd(tail["k"], k_new), "v": upd(tail["v"], v_new)}

        layer_c = {"k_u": ku, "k_vt": kvt, "v_u": vu, "v_vt": vvt}
        a = _lowrank_attention(q, layer_c, tail, pos, frozen_len, cfg)
        x = x + L.dense(lp["attn"]["wo"], a.astype(x.dtype))
        x = x + L.mlp(lp["mlp"], T._norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x, tail

    x, tails = L.xscan(scan_fn, x,
                       (p["layers"], cache["k_u"], cache["k_vt"],
                        cache["v_u"], cache["v_vt"], cache["tail"]))
    new_cache = dict(cache)
    new_cache["tail"] = tails
    return T.logits_head(p, x, cfg)[:, 0], new_cache


def compress_tail(cache: Params, cfg, rank: int) -> Params:
    """Fold the dense tail into the low-rank prefix (rank-concat +
    retruncate) — the serving engine calls this every TAIL steps."""
    from ..core.lowrank import LowRank, retruncate
    nl, b, tl, kvh, hd = cache["tail"]["k"].shape
    kvw = kvh * hd

    def one(u, vt, tail):
        tail2 = tail.reshape(nl * b, tl, kvw).astype(jnp.float32)
        u2 = u.reshape(nl * b, -1, rank).astype(jnp.float32)
        vt2 = vt.reshape(nl * b, rank, kvw).astype(jnp.float32)
        # tail as exact rank-tl factors appended to the prefix row space:
        # [U | P_tail·tail] with Vt rows [Vt ; I-scatter] — here the tail
        # rows live at the END of the time axis, so U gains tl rows.
        t_frozen = u2.shape[1]
        u_cat = jnp.concatenate(
            [jnp.pad(u2, ((0, 0), (0, tl), (0, 0))),
             jnp.pad(jnp.eye(tl, dtype=u2.dtype)[None].repeat(nl * b, 0),
                     ((0, 0), (t_frozen, 0), (0, 0)))], axis=-1)
        vt_cat = jnp.concatenate([vt2, tail2], axis=-2)
        lr = retruncate(LowRank(u_cat,
                                jnp.ones(u_cat.shape[:-1][:-1]
                                         + (u_cat.shape[-1],), u_cat.dtype),
                                vt_cat), rank)
        return (lr.scaled_u().reshape(nl, b, t_frozen + tl, rank),
                lr.vt.reshape(nl, b, rank, kvw))

    k_u, k_vt = one(cache["k_u"], cache["k_vt"], cache["tail"]["k"])
    v_u, v_vt = one(cache["v_u"], cache["v_vt"], cache["tail"]["v"])
    z = jnp.zeros_like(cache["tail"]["k"])
    return {"k_u": k_u.astype(cache["k_u"].dtype),
            "k_vt": k_vt.astype(cache["k_vt"].dtype),
            "v_u": v_u.astype(cache["v_u"].dtype),
            "v_vt": v_vt.astype(cache["v_vt"].dtype),
            "tail": {"k": z, "v": z}}
