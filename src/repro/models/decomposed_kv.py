"""Decomposed KV cache — the paper's activation decomposition applied to
serving memory (beyond-paper §Perf feature).

Decode is KV-bandwidth-bound: every step re-reads the whole [T, kvh·hd]
cache.  K and V are activations, so D-com's machinery applies directly:
after prefill, each layer's K/V is Lanczos-decomposed into
(U [B, T, r], Vᵀ [B, r, kvh·hd]); per decode step the attention contracts
THROUGH the factors —

  scores = (q · Vᵀ_kᵀ) · Uᵀ_k        (r·d + T·r  vs  T·d  per head-group)
  out    = ((p · U_v) · Vᵀ_v)

so cache bytes read per step shrink by ~d_kv/r (Eq. 10 applied to the KV
stream).  New tokens append to a small DENSE TAIL (exact attention over
recent context); the serving engine re-compresses the tail into the
low-rank prefix on a fixed cadence (rank-concat + retruncate, amortized) —
mirroring the paper's "decomposition once, consumed many times" economics.

All tail state is PER SLOT: ``frozen_len`` may be a ``[B]`` vector (each
slot's low-rank prefix length), the prefix rows beyond a slot's
``frozen_len`` are masked out of the softmax, ``compress_tail`` accepts a
per-slot ``fold`` mask so each slot folds exactly when ITS tail fills, and
``splice_dkv`` scatters a freshly prefilled low-rank prefix + empty tail
into a live cache along the batch axis — the serving engine admits new
requests without touching live slots.

Approximation surface: the low-rank prefix (rank r of the RoPE'd K/V rows).
``prefill_dkv`` at full rank reproduces dense attention exactly
(tests/test_decomposed_kv.py).

Sharding invariants (mesh-parallel serving, DESIGN.md §9): every op in this
module is BATCH-LOCAL — the tail write is a vmapped
``dynamic_update_slice`` along each slot's own row, ``compress_tail``'s
scatter blocks are built per slot, and ``splice_dkv`` scatters along the
batch axis only — so a serving engine that DP-shards the slot axis (and
puts kvw on "model") never induces a cross-device gather on the decode hot
path.  ``k_u``/``v_u`` time axes stay model-replicated (the refuted §Perf
C3 experiment: sharded-softmax all-reduces over the [B,kvh,g,T] scores
cost 2× the saved U reads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..engine import DecomposeEngine, EngineConfig
from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]

TAIL = 128                      # dense recent-token buffer length

# Module-default engine for callers that don't thread one (tests, one-shot
# scripts); serving constructs and reuses its own.
_DEFAULT_ENGINE = DecomposeEngine(EngineConfig())


def init_cache(cfg, batch: int, frozen_len: int, rank: int,
               tail: int = TAIL) -> Params:
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    nl, dt = cfg.num_layers, cfg.jax_dtype
    z = jnp.zeros
    return {
        "k_u": z((nl, batch, frozen_len, rank), dt),
        "k_vt": z((nl, batch, rank, kvw), dt),
        "v_u": z((nl, batch, frozen_len, rank), dt),
        "v_vt": z((nl, batch, rank, kvw), dt),
        "tail": {"k": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt),
                 "v": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt)},
    }


def prefill_dkv(p: Params, cfg, tokens: Array, rank: int,
                tail: int = TAIL, exact: bool = False,
                engine: Optional[DecomposeEngine] = None
                ) -> Tuple[Array, Params]:
    """Dense-family prefill that emits a decomposed KV cache.

    K/V factorization goes through :meth:`DecomposeEngine.decompose_kv`
    (Lanczos via the engine's backend; ``exact`` switches to direct SVD for
    r near full rank, where floating-point Lanczos loses trailing
    directions — §2.3: Lanczos is the small-rank algorithm).
    """
    if rank < 1:
        raise ValueError(f"prefill_dkv needs rank >= 1, got {rank} "
                         "(is the engine's kv_rank configured?)")
    engine = engine or _DEFAULT_ENGINE
    b, s = tokens.shape
    logits, dense_cache = T.prefill(p, cfg, tokens, s)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def one(kv):
        flat = kv.reshape(cfg.num_layers * b, s, kvh * hd)
        u, vt = engine.decompose_kv(flat, rank, exact=exact)
        r_eff = u.shape[-1]          # rank caps at min(s, kvw) (exact SVD)
        return (u.reshape(cfg.num_layers, b, s, r_eff),
                vt.reshape(cfg.num_layers, b, r_eff, kvh * hd))

    k_u, k_vt = one(dense_cache["k"])
    v_u, v_vt = one(dense_cache["v"])
    z = jnp.zeros((cfg.num_layers, b, tail, kvh, hd), cfg.jax_dtype)
    return logits, {"k_u": k_u, "k_vt": k_vt, "v_u": v_u, "v_vt": v_vt,
                    "tail": {"k": z, "v": z}}


def _frozen_vec(frozen_len, pos: Array) -> Array:
    """Normalize frozen_len (int or per-slot [B] array) to int32 [B]."""
    return jnp.broadcast_to(jnp.asarray(frozen_len, jnp.int32), pos.shape)


def _lowrank_attention(q: Array, c: Params, tail_kv: Params,
                       pos: Array, frozen_len: Array, cfg) -> Array:
    """q [B, 1, nh, d]; low-rank prefix + dense tail → out [B, 1, nh·d].

    ``frozen_len`` is per-slot [B]: prefix rows at or beyond a slot's
    frozen_len are zero in U but still produce score 0 (not −inf) through
    the factors, so they are masked out of the softmax explicitly.
    """
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nh // kvh
    b = q.shape[0]
    scale = hd ** -0.5
    qg = q[:, 0].reshape(b, kvh, g, hd).astype(jnp.float32)
    t_pre = c["k_u"].shape[1]                     # static prefix row count

    # ---- prefix scores through the factors ------------------------------
    k_vt = c["k_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    inner = jnp.einsum("bkgd,brkd->bkgr", qg, k_vt)          # [B,kvh,g,r]
    sc_pre = jnp.einsum("bkgr,btr->bkgt", inner,
                        c["k_u"].astype(jnp.float32)) * scale
    pre_valid = jnp.arange(t_pre)[None, :] < frozen_len[:, None]   # [B,T]
    sc_pre = jnp.where(pre_valid[:, None, None, :], sc_pre, -1e30)

    # ---- tail scores (exact) ---------------------------------------------
    tk = tail_kv["k"].astype(jnp.float32)                     # [B,tl,kvh,hd]
    sc_tail = jnp.einsum("bkgd,btkd->bkgt", qg, tk) * scale
    tail_pos = frozen_len[:, None] + jnp.arange(tk.shape[1])[None, :]
    valid = tail_pos <= pos[:, None]                          # [B, tl]
    sc_tail = jnp.where(valid[:, None, None, :], sc_tail, -1e30)

    # ---- joint softmax -----------------------------------------------------
    sc = jnp.concatenate([sc_pre, sc_tail], axis=-1)
    pr = jax.nn.softmax(sc, axis=-1)
    p_pre, p_tail = pr[..., :t_pre], pr[..., t_pre:]

    # ---- PV through the factors -------------------------------------------
    tmp = jnp.einsum("bkgt,btr->bkgr", p_pre,
                     c["v_u"].astype(jnp.float32))
    v_vt = c["v_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    out = jnp.einsum("bkgr,brkd->bkgd", tmp, v_vt)
    out = out + jnp.einsum("bkgt,btkd->bkgd", p_tail,
                           tail_kv["v"].astype(jnp.float32))
    return out.reshape(b, 1, nh * hd)


def decode_step_dkv(p: Params, cfg, token: Array, cache: Params,
                    pos: Array, frozen_len) -> Tuple[Array, Params]:
    """One-token decode over the decomposed cache (dense transformer).

    ``frozen_len`` is an int (uniform) or a per-slot int32 [B] vector; each
    slot's tail write position is its own ``pos − frozen_len``.
    """
    frozen_len = _frozen_vec(frozen_len, pos)
    x = p["embed"]["w"][token][:, None, :] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    kvh = cfg.num_kv_heads

    def scan_fn(x, inp):
        lp, ku, kvt, vu, vvt, tail = inp
        h = T._norm(lp["attn_norm"], x, cfg)
        q = L._split_heads(L.dense(lp["attn"]["wq"], h), cfg.num_heads)
        k_new = L._split_heads(L.dense(lp["attn"]["wk"], h), kvh)
        v_new = L._split_heads(L.dense(lp["attn"]["wv"], h), kvh)
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.apply_rope(k_new, pos[:, None], cfg.rope_theta)

        slot = pos - frozen_len                       # tail write position
        upd = lambda buf, new: jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(
                bb, nn, ss, axis=0))(buf, new.astype(buf.dtype), slot)
        tail = {"k": upd(tail["k"], k_new), "v": upd(tail["v"], v_new)}

        layer_c = {"k_u": ku, "k_vt": kvt, "v_u": vu, "v_vt": vvt}
        a = _lowrank_attention(q, layer_c, tail, pos, frozen_len, cfg)
        x = x + L.dense(lp["attn"]["wo"], a.astype(x.dtype))
        x = x + L.mlp(lp["mlp"], T._norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x, tail

    x, tails = L.xscan(scan_fn, x,
                       (p["layers"], cache["k_u"], cache["k_vt"],
                        cache["v_u"], cache["v_vt"], cache["tail"]))
    new_cache = dict(cache)
    new_cache["tail"] = tails
    return T.logits_head(p, x, cfg)[:, 0], new_cache


def compress_tail(cache: Params, cfg, rank: int,
                  frozen_len=None, fold=None) -> Params:
    """Fold the dense tail into the low-rank prefix (rank-concat +
    retruncate).

    Uniform mode (``frozen_len is None``): every slot's tail occupies rows
    ``t_frozen … t_frozen+tl`` — the pre-per-slot behavior, kept for
    one-shot callers (tests, ``api.decomposed_fns``).

    Per-slot mode: ``frozen_len`` is an int32 [B] vector and ``fold`` a
    bool [B] mask — each folding slot's tail rows are scattered at ITS
    ``frozen_len`` offset in the row space, non-folding slots keep their
    prefix, factors, and tail untouched (time axis still grows by ``tl``
    so shapes stay static; the serving engine slices back to
    ``max(frozen_len)``).
    """
    from ..core.lowrank import LowRank, retruncate
    nl, b, tl, kvh, hd = cache["tail"]["k"].shape
    kvw = kvh * hd
    r_in = cache["k_u"].shape[-1]
    t_frozen = cache["k_u"].shape[2]
    # retruncate's output rank caps at both the concatenated factor width
    # and the row count; non-folding slots keep all r_in columns, so the
    # common output rank is the max of the two (zero-padded, never sliced)
    r_fold = min(rank, r_in + tl, t_frozen + tl)
    r_out = max(r_in, r_fold)

    if frozen_len is None:
        offsets = jnp.full((b,), t_frozen, jnp.int32)
        fold_m = jnp.ones((b,), bool)
    else:
        offsets = jnp.asarray(frozen_len, jnp.int32).reshape(b)
        fold_m = jnp.ones((b,), bool) if fold is None \
            else jnp.asarray(fold).reshape(b)

    # identity scatter block per slot: E[offset+i, i] = 1  → [B, T+tl, tl]
    eye = jnp.eye(tl, dtype=jnp.float32)
    scat = jax.vmap(lambda off: jax.lax.dynamic_update_slice(
        jnp.zeros((t_frozen + tl, tl), jnp.float32), eye, (off, 0)))(offsets)

    def one(u, vt, tail):
        tail2 = tail.reshape(nl, b, tl, kvw).astype(jnp.float32)
        u2 = u.astype(jnp.float32)                       # [nl, b, T, r]
        vt2 = vt.astype(jnp.float32)                     # [nl, b, r, kvw]
        u_pad = jnp.pad(u2, ((0, 0), (0, 0), (0, tl), (0, 0)))
        u_cat = jnp.concatenate(
            [u_pad, jnp.broadcast_to(scat[None], (nl,) + scat.shape)],
            axis=-1)                                     # [nl,b,T+tl,r+tl]
        vt_cat = jnp.concatenate([vt2, tail2], axis=-2)
        lr = retruncate(LowRank(u_cat,
                                jnp.ones(u_cat.shape[:-2]
                                         + (u_cat.shape[-1],), u_cat.dtype),
                                vt_cat), r_fold)
        pad_r = lambda a, ax: jnp.pad(
            a, [(0, 0)] * ax + [(0, r_out - a.shape[ax])]
            + [(0, 0)] * (a.ndim - ax - 1))
        u_new, vt_new = pad_r(lr.scaled_u(), 3), pad_r(lr.vt, 2)
        # non-folding slots keep their (time-padded, rank-padded) factors
        keep_u, keep_vt = pad_r(u_pad, 3), pad_r(vt2, 2)
        fm = fold_m[None, :, None, None]
        return (jnp.where(fm, u_new, keep_u),
                jnp.where(fm, vt_new, keep_vt))

    k_u, k_vt = one(cache["k_u"], cache["k_vt"], cache["tail"]["k"])
    v_u, v_vt = one(cache["v_u"], cache["v_vt"], cache["tail"]["v"])
    fm = fold_m[None, :, None, None, None]
    new_tail = {k: jnp.where(fm, jnp.zeros_like(v), v)
                for k, v in cache["tail"].items()}
    return {"k_u": k_u.astype(cache["k_u"].dtype),
            "k_vt": k_vt.astype(cache["k_vt"].dtype),
            "v_u": v_u.astype(cache["v_u"].dtype),
            "v_vt": v_vt.astype(cache["v_vt"].dtype),
            "tail": new_tail}


def splice_dkv(live: Params, fresh: Params, slot_indices,
               src_indices=None) -> Params:
    """Scatter freshly prefilled rows of ``fresh`` (batch rows
    ``src_indices``, default 0…n−1) into ``live`` at ``slot_indices`` along
    the batch axis — admission into a LIVE decomposed cache, no re-prefill
    of occupied slots.

    Time and rank axes are zero-padded to the pairwise max first (zero U
    rows/columns and zero Vᵀ rows are inert), so a fresh short prefix can
    join a cache whose prefix has grown through tail folds, and vice
    versa.
    """
    idx = jnp.asarray(slot_indices, jnp.int32)      # traced-input friendly
    src = jnp.arange(idx.shape[0], dtype=jnp.int32) \
        if src_indices is None else jnp.asarray(src_indices, jnp.int32)

    def pad_to(a, axis, size):
        if a.shape[axis] >= size:
            return a
        w = [(0, 0)] * a.ndim
        w[axis] = (0, size - a.shape[axis])
        return jnp.pad(a, w)

    t = max(live["k_u"].shape[2], fresh["k_u"].shape[2])
    r = max(live["k_u"].shape[-1], fresh["k_u"].shape[-1])
    out: Params = {}
    for key in ("k_u", "v_u"):
        old = pad_to(pad_to(live[key], 2, t), 3, r)
        new = pad_to(pad_to(fresh[key], 2, t), 3, r)
        out[key] = old.at[:, idx].set(new[:, src].astype(old.dtype))
    for key in ("k_vt", "v_vt"):
        old = pad_to(live[key], 2, r)
        new = pad_to(fresh[key], 2, r)
        out[key] = old.at[:, idx].set(new[:, src].astype(old.dtype))
    out["tail"] = {k: live["tail"][k].at[:, idx].set(
        fresh["tail"][k][:, src].astype(live["tail"][k].dtype))
        for k in live["tail"]}
    return out
