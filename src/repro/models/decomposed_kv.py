"""Decomposed KV cache — the paper's activation decomposition applied to
serving memory (beyond-paper §Perf feature).

Decode is KV-bandwidth-bound: every step re-reads the whole [T, kvh·hd]
cache.  K and V are activations, so D-com's machinery applies directly:
after prefill, each layer's K/V is Lanczos-decomposed into
(U [B, T, r], Vᵀ [B, r, kvh·hd]); per decode step the attention contracts
THROUGH the factors —

  scores = (q · Vᵀ_kᵀ) · Uᵀ_k        (r·d + T·r  vs  T·d  per head-group)
  out    = ((p · U_v) · Vᵀ_v)

so cache bytes read per step shrink by ~d_kv/r (Eq. 10 applied to the KV
stream).  New tokens append to a small DENSE TAIL (exact attention over
recent context); the serving engine re-compresses the tail into the
low-rank prefix on a fixed cadence (rank-concat + retruncate, amortized) —
mirroring the paper's "decomposition once, consumed many times" economics.

All tail state is PER SLOT: ``frozen_len`` may be a ``[B]`` vector (each
slot's low-rank prefix length), the prefix rows beyond a slot's
``frozen_len`` are masked out of the softmax, ``compress_tail`` accepts a
per-slot ``fold`` mask so each slot folds exactly when ITS tail fills, and
``splice_dkv`` scatters a freshly prefilled low-rank prefix + empty tail
into a live cache along the batch axis — the serving engine admits new
requests without touching live slots.

Approximation surface: the low-rank prefix (rank r of the RoPE'd K/V rows).
``prefill_dkv`` at full rank reproduces dense attention exactly
(tests/test_decomposed_kv.py).

A PAGED twin of the slab layout lives at the bottom of this module
(``init_paged_cache`` / ``gather_pages`` / ``decode_step_dkv_paged`` /
``compress_tail_paged`` / ``prefill_suffix_dkv``): prefix U rows and dense
tail rows sit in fixed-size page pools addressed by per-slot block tables,
enabling refcounted SHARING of frozen prefix pages across requests
(serving.paged) while replaying the slab arithmetic bit-for-bit.

Sharding invariants (mesh-parallel serving, DESIGN.md §9): every op in this
module is BATCH-LOCAL — the tail write is a vmapped
``dynamic_update_slice`` along each slot's own row, ``compress_tail``'s
scatter blocks are built per slot, and ``splice_dkv`` scatters along the
batch axis only — so a serving engine that DP-shards the slot axis (and
puts kvw on "model") never induces a cross-device gather on the decode hot
path.  ``k_u``/``v_u`` time axes stay model-replicated (the refuted §Perf
C3 experiment: sharded-softmax all-reduces over the [B,kvh,g,T] scores
cost 2× the saved U reads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..engine import DecomposeEngine, EngineConfig
from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]

TAIL = 128                      # dense recent-token buffer length

# Module-default engine for callers that don't thread one (tests, one-shot
# scripts); serving constructs and reuses its own.
_DEFAULT_ENGINE = DecomposeEngine(EngineConfig())


def init_cache(cfg, batch: int, frozen_len: int, rank: int,
               tail: int = TAIL) -> Params:
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    nl, dt = cfg.num_layers, cfg.jax_dtype
    z = jnp.zeros
    return {
        "k_u": z((nl, batch, frozen_len, rank), dt),
        "k_vt": z((nl, batch, rank, kvw), dt),
        "v_u": z((nl, batch, frozen_len, rank), dt),
        "v_vt": z((nl, batch, rank, kvw), dt),
        "tail": {"k": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt),
                 "v": z((nl, batch, tail, cfg.num_kv_heads,
                         cfg.resolved_head_dim), dt)},
    }


def prefill_dkv(p: Params, cfg, tokens: Array, rank: int,
                tail: int = TAIL, exact: bool = False,
                engine: Optional[DecomposeEngine] = None
                ) -> Tuple[Array, Params]:
    """Dense-family prefill that emits a decomposed KV cache.

    K/V factorization goes through :meth:`DecomposeEngine.decompose_kv`
    (Lanczos via the engine's backend; ``exact`` switches to direct SVD for
    r near full rank, where floating-point Lanczos loses trailing
    directions — §2.3: Lanczos is the small-rank algorithm).
    """
    if rank < 1:
        raise ValueError(f"prefill_dkv needs rank >= 1, got {rank} "
                         "(is the engine's kv_rank configured?)")
    engine = engine or _DEFAULT_ENGINE
    b, s = tokens.shape
    logits, dense_cache = T.prefill(p, cfg, tokens, s)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def one(kv):
        flat = kv.reshape(cfg.num_layers * b, s, kvh * hd)
        u, vt = engine.decompose_kv(flat, rank, exact=exact)
        r_eff = u.shape[-1]          # rank caps at min(s, kvw) (exact SVD)
        return (u.reshape(cfg.num_layers, b, s, r_eff),
                vt.reshape(cfg.num_layers, b, r_eff, kvh * hd))

    k_u, k_vt = one(dense_cache["k"])
    v_u, v_vt = one(dense_cache["v"])
    z = jnp.zeros((cfg.num_layers, b, tail, kvh, hd), cfg.jax_dtype)
    return logits, {"k_u": k_u, "k_vt": k_vt, "v_u": v_u, "v_vt": v_vt,
                    "tail": {"k": z, "v": z}}


def _frozen_vec(frozen_len, pos: Array) -> Array:
    """Normalize frozen_len (int or per-slot [B] array) to int32 [B]."""
    return jnp.broadcast_to(jnp.asarray(frozen_len, jnp.int32), pos.shape)


def _lowrank_attention(q: Array, c: Params, tail_kv: Params,
                       pos: Array, frozen_len: Array, cfg) -> Array:
    """q [B, 1, nh, d]; low-rank prefix + dense tail → out [B, 1, nh·d].

    ``frozen_len`` is per-slot [B]: prefix rows at or beyond a slot's
    frozen_len are zero in U but still produce score 0 (not −inf) through
    the factors, so they are masked out of the softmax explicitly.
    """
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nh // kvh
    b = q.shape[0]
    scale = hd ** -0.5
    qg = q[:, 0].reshape(b, kvh, g, hd).astype(jnp.float32)
    t_pre = c["k_u"].shape[1]                     # static prefix row count

    # ---- prefix scores through the factors ------------------------------
    k_vt = c["k_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    inner = jnp.einsum("bkgd,brkd->bkgr", qg, k_vt)          # [B,kvh,g,r]
    sc_pre = jnp.einsum("bkgr,btr->bkgt", inner,
                        c["k_u"].astype(jnp.float32)) * scale
    pre_valid = jnp.arange(t_pre)[None, :] < frozen_len[:, None]   # [B,T]
    sc_pre = jnp.where(pre_valid[:, None, None, :], sc_pre, -1e30)

    # ---- tail scores (exact) ---------------------------------------------
    tk = tail_kv["k"].astype(jnp.float32)                     # [B,tl,kvh,hd]
    sc_tail = jnp.einsum("bkgd,btkd->bkgt", qg, tk) * scale
    tail_pos = frozen_len[:, None] + jnp.arange(tk.shape[1])[None, :]
    valid = tail_pos <= pos[:, None]                          # [B, tl]
    sc_tail = jnp.where(valid[:, None, None, :], sc_tail, -1e30)

    # ---- joint softmax -----------------------------------------------------
    sc = jnp.concatenate([sc_pre, sc_tail], axis=-1)
    pr = jax.nn.softmax(sc, axis=-1)
    p_pre, p_tail = pr[..., :t_pre], pr[..., t_pre:]

    # ---- PV through the factors -------------------------------------------
    tmp = jnp.einsum("bkgt,btr->bkgr", p_pre,
                     c["v_u"].astype(jnp.float32))
    v_vt = c["v_vt"].astype(jnp.float32).reshape(b, -1, kvh, hd)
    out = jnp.einsum("bkgr,brkd->bkgd", tmp, v_vt)
    out = out + jnp.einsum("bkgt,btkd->bkgd", p_tail,
                           tail_kv["v"].astype(jnp.float32))
    return out.reshape(b, 1, nh * hd)


def decode_step_dkv(p: Params, cfg, token: Array, cache: Params,
                    pos: Array, frozen_len) -> Tuple[Array, Params]:
    """One-token decode over the decomposed cache (dense transformer).

    ``frozen_len`` is an int (uniform) or a per-slot int32 [B] vector; each
    slot's tail write position is its own ``pos − frozen_len``.
    """
    frozen_len = _frozen_vec(frozen_len, pos)
    x = p["embed"]["w"][token][:, None, :] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    kvh = cfg.num_kv_heads

    def scan_fn(x, inp):
        lp, ku, kvt, vu, vvt, tail = inp
        h = T._norm(lp["attn_norm"], x, cfg)
        q = L._split_heads(L.dense(lp["attn"]["wq"], h), cfg.num_heads)
        k_new = L._split_heads(L.dense(lp["attn"]["wk"], h), kvh)
        v_new = L._split_heads(L.dense(lp["attn"]["wv"], h), kvh)
        q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = L.apply_rope(k_new, pos[:, None], cfg.rope_theta)

        slot = pos - frozen_len                       # tail write position
        upd = lambda buf, new: jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(
                bb, nn, ss, axis=0))(buf, new.astype(buf.dtype), slot)
        tail = {"k": upd(tail["k"], k_new), "v": upd(tail["v"], v_new)}

        layer_c = {"k_u": ku, "k_vt": kvt, "v_u": vu, "v_vt": vvt}
        a = _lowrank_attention(q, layer_c, tail, pos, frozen_len, cfg)
        x = x + L.dense(lp["attn"]["wo"], a.astype(x.dtype))
        x = x + L.mlp(lp["mlp"], T._norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x, tail

    x, tails = L.xscan(scan_fn, x,
                       (p["layers"], cache["k_u"], cache["k_vt"],
                        cache["v_u"], cache["v_vt"], cache["tail"]))
    new_cache = dict(cache)
    new_cache["tail"] = tails
    return T.logits_head(p, x, cfg)[:, 0], new_cache


def decode_block_dkv(p: Params, cfg, token: Array, cache: Params, pos: Array,
                     frozen_len, n_steps, stop_table: Array, key, round0, *,
                     sampler, max_block: int):
    """Fused multi-step decode over the decomposed slab cache: up to
    ``n_steps`` (≤ the static ``max_block``) single-token steps inside one
    bounded on-device loop (:func:`api.run_decode_block`), sampling on
    device and exiting early on any stop-token emission.

    ``frozen_len`` is loop-invariant by construction — the serving engine
    caps ``n_steps`` at ``dkv_tail − max(occupancy)`` so every tail fold
    still happens at a block boundary, on the host, at exactly the
    occupancy the single-step engine would have folded at.

    Returns ``(token_buf [max_block, B], steps_done, done_mask, cache)``.
    """
    from . import api
    frozen = _frozen_vec(frozen_len, pos)
    step = lambda t, c, ps: decode_step_dkv(p, cfg, t, c, ps, frozen)
    return api.run_decode_block(step, sampler, max_block, token, cache,
                                pos, n_steps, stop_table, key, round0)


def fold_rank(rank: int, r_in: int, t_frozen: int, tl: int) -> int:
    """The rank a fold retruncates to — host-side mirror of the cap
    inside :func:`compress_tail` (configured rank, bounded by the
    concatenated factor width and the row count).  The serving engine uses
    it to track per-slot effective rank without touching device data."""
    return min(rank, r_in + tl, t_frozen + tl)


def compress_tail(cache: Params, cfg, rank: int,
                  frozen_len=None, fold=None, new_frozen=None) -> Params:
    """Fold the dense tail into the low-rank prefix (rank-concat +
    retruncate).

    Uniform mode (``frozen_len is None``): every slot's tail occupies rows
    ``t_frozen … t_frozen+tl`` — the pre-per-slot behavior, kept for
    one-shot callers (tests, ``api.decomposed_fns``).

    Per-slot mode: ``frozen_len`` is an int32 [B] vector and ``fold`` a
    bool [B] mask — each folding slot's tail rows are scattered at ITS
    ``frozen_len`` offset in the row space, non-folding slots keep their
    prefix, factors, and tail untouched (time axis still grows by ``tl``
    so shapes stay static; the serving engine slices back to
    ``max(frozen_len)``).

    ``new_frozen`` (per-slot mode, int32 [B]: each folding slot's
    post-fold prefix length, i.e. its ``pos``) zeroes the retruncated U
    rows at or beyond the new frozen length.  Those rows reconstruct to
    ~0 anyway (they fold zero tail rows), but the explicit zero enforces
    the module invariant "prefix rows beyond frozen_len are zero" BITWISE
    — which is what lets the paged engine store exactly
    ``ceil(frozen_len/page)`` pages per slot and still replay the slot
    engine's arithmetic identically.
    """
    from ..core.lowrank import LowRank, retruncate
    nl, b, tl, kvh, hd = cache["tail"]["k"].shape
    kvw = kvh * hd
    r_in = cache["k_u"].shape[-1]
    t_frozen = cache["k_u"].shape[2]
    # A fold RETRUNCATES BACK to the configured rank: r_fold caps at
    # ``rank`` (and at the concatenated factor width / row count, which
    # bound the content rank).  Uniform mode folds every slot, so the
    # output width is exactly r_fold — a cache whose factors were inflated
    # past ``rank`` by heterogeneous splices shrinks back on the next fold
    # instead of ratcheting (the old ``r_out = max(r_in, r_fold)``
    # permanently kept the widest rank any splice ever introduced).
    # Per-slot mode must keep the non-folding slots' r_in columns
    # bit-identical, so the ARRAY stays max-width there; folded slots'
    # columns beyond r_fold are zero and the serving engine slices the
    # rank axis down to the widest live slot (``rank_eff`` bookkeeping).
    r_fold = min(rank, r_in + tl, t_frozen + tl)
    r_out = r_fold if frozen_len is None else max(r_in, r_fold)

    if frozen_len is None:
        offsets = jnp.full((b,), t_frozen, jnp.int32)
        fold_m = jnp.ones((b,), bool)
    else:
        offsets = jnp.asarray(frozen_len, jnp.int32).reshape(b)
        fold_m = jnp.ones((b,), bool) if fold is None \
            else jnp.asarray(fold).reshape(b)

    # identity scatter block per slot: E[offset+i, i] = 1  → [B, T+tl, tl]
    eye = jnp.eye(tl, dtype=jnp.float32)
    scat = jax.vmap(lambda off: jax.lax.dynamic_update_slice(
        jnp.zeros((t_frozen + tl, tl), jnp.float32), eye, (off, 0)))(offsets)

    def one(u, vt, tail):
        tail2 = tail.reshape(nl, b, tl, kvw).astype(jnp.float32)
        u2 = u.astype(jnp.float32)                       # [nl, b, T, r]
        vt2 = vt.astype(jnp.float32)                     # [nl, b, r, kvw]
        u_pad = jnp.pad(u2, ((0, 0), (0, 0), (0, tl), (0, 0)))
        u_cat = jnp.concatenate(
            [u_pad, jnp.broadcast_to(scat[None], (nl,) + scat.shape)],
            axis=-1)                                     # [nl,b,T+tl,r+tl]
        vt_cat = jnp.concatenate([vt2, tail2], axis=-2)
        lr = retruncate(LowRank(u_cat,
                                jnp.ones(u_cat.shape[:-2]
                                         + (u_cat.shape[-1],), u_cat.dtype),
                                vt_cat), r_fold)
        pad_r = lambda a, ax: jnp.pad(
            a, [(0, 0)] * ax + [(0, r_out - a.shape[ax])]
            + [(0, 0)] * (a.ndim - ax - 1))
        u_new, vt_new = pad_r(lr.scaled_u(), 3), pad_r(lr.vt, 2)
        if new_frozen is not None:
            nf = jnp.asarray(new_frozen, jnp.int32).reshape(b)
            row_ok = jnp.arange(t_frozen + tl)[None, :] < nf[:, None]
            u_new = jnp.where(row_ok[None, :, :, None], u_new, 0.0)
        if frozen_len is None:
            # uniform mode: every slot folds, so the retruncated factors
            # ARE the output (width exactly r_fold <= rank — no keep
            # branch, which could be wider than r_out)
            return u_new, vt_new
        # non-folding slots keep their (time-padded, rank-padded) factors
        keep_u, keep_vt = pad_r(u_pad, 3), pad_r(vt2, 2)
        fm = fold_m[None, :, None, None]
        return (jnp.where(fm, u_new, keep_u),
                jnp.where(fm, vt_new, keep_vt))

    k_u, k_vt = one(cache["k_u"], cache["k_vt"], cache["tail"]["k"])
    v_u, v_vt = one(cache["v_u"], cache["v_vt"], cache["tail"]["v"])
    fm = fold_m[None, :, None, None, None]
    new_tail = {k: jnp.where(fm, jnp.zeros_like(v), v)
                for k, v in cache["tail"].items()}
    return {"k_u": k_u.astype(cache["k_u"].dtype),
            "k_vt": k_vt.astype(cache["k_vt"].dtype),
            "v_u": v_u.astype(cache["v_u"].dtype),
            "v_vt": v_vt.astype(cache["v_vt"].dtype),
            "tail": new_tail}


def splice_dkv(live: Params, fresh: Params, slot_indices,
               src_indices=None) -> Params:
    """Scatter freshly prefilled rows of ``fresh`` (batch rows
    ``src_indices``, default 0…n−1) into ``live`` at ``slot_indices`` along
    the batch axis — admission into a LIVE decomposed cache, no re-prefill
    of occupied slots.

    Time and rank axes are zero-padded to the pairwise max first (zero U
    rows/columns and zero Vᵀ rows are inert), so a fresh short prefix can
    join a cache whose prefix has grown through tail folds, and vice
    versa.
    """
    idx = jnp.asarray(slot_indices, jnp.int32)      # traced-input friendly
    src = jnp.arange(idx.shape[0], dtype=jnp.int32) \
        if src_indices is None else jnp.asarray(src_indices, jnp.int32)

    def pad_to(a, axis, size):
        if a.shape[axis] >= size:
            return a
        w = [(0, 0)] * a.ndim
        w[axis] = (0, size - a.shape[axis])
        return jnp.pad(a, w)

    t = max(live["k_u"].shape[2], fresh["k_u"].shape[2])
    r = max(live["k_u"].shape[-1], fresh["k_u"].shape[-1])
    out: Params = {}
    for key in ("k_u", "v_u"):
        old = pad_to(pad_to(live[key], 2, t), 3, r)
        new = pad_to(pad_to(fresh[key], 2, t), 3, r)
        out[key] = old.at[:, idx].set(new[:, src].astype(old.dtype))
    for key in ("k_vt", "v_vt"):
        old = pad_to(live[key], 2, r)
        new = pad_to(fresh[key], 2, r)
        out[key] = old.at[:, idx].set(new[:, src].astype(old.dtype))
    out["tail"] = {k: live["tail"][k].at[:, idx].set(
        fresh["tail"][k][:, src].astype(live["tail"][k].dtype))
        for k in live["tail"]}
    return out


# ---------------------------------------------------------------------------
# Paged layout (vLLM-style block tables over the decomposed cache)
# ---------------------------------------------------------------------------
#
# Instead of one [slots, max_len, …] slab, the low-rank prefix U rows and
# the dense tail live in fixed-size PAGE POOLS indexed by per-slot page
# lists (block tables, host-side):
#
#   k_u_pages / v_u_pages  [nl, P,  page, r]          prefix U row pool
#   k_vt / v_vt            [nl, B,  r,    kvw]        per-slot factors
#   tail.k_pages / v_pages [nl, TP, page, kvh, hd]    dense tail row pool
#
# Page id 0 is a reserved WRITE SINK: block-table padding and the scatter
# targets of non-folding slots point at it, so one jitted scatter serves
# every fold without masking.  The sink's content is kept ALL-ZERO by
# construction (fold scatters mask non-folding rows to zero), because
# gather_pages' block-table padding reads it as if it were zero rows.  A
# page holds the same row range for EVERY layer (one block table per
# slot, not per layer), so the layer scan consumes gathered pages exactly
# like slab rows.
#
# Token-exactness contract: ``gather_pages`` + row/rank slicing to the
# slot engine's slab geometry reproduces the slab ARRAYS bit-for-bit
# (rows beyond a slot's frozen_len are zero — see ``new_frozen`` in
# :func:`compress_tail`), so paged decode/fold arithmetic is the slot
# engine's arithmetic, and shared prefix pages are safe to alias across
# slots because folds scatter into FRESH pages (copy-on-write).


def init_paged_cache(cfg, batch: int, num_pages: int, page: int, rank: int,
                     num_tail_pages: int) -> Params:
    """Page pools + per-slot factor slots for the paged decomposed cache."""
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    nl, dt = cfg.num_layers, cfg.jax_dtype
    z = jnp.zeros
    return {
        "k_u_pages": z((nl, num_pages, page, rank), dt),
        "v_u_pages": z((nl, num_pages, page, rank), dt),
        "k_vt": z((nl, batch, rank, kvw), dt),
        "v_vt": z((nl, batch, rank, kvw), dt),
        "tail": {
            "k_pages": z((nl, num_tail_pages, page, cfg.num_kv_heads,
                          cfg.resolved_head_dim), dt),
            "v_pages": z((nl, num_tail_pages, page, cfg.num_kv_heads,
                          cfg.resolved_head_dim), dt),
        },
    }


def gather_pages(pool: Array, bt: Array, rows: Optional[int] = None
                 ) -> Array:
    """pool [nl, P, page, …], bt int32 [B, n] → rows [nl, B, t, …].

    Concatenates each slot's pages along the time axis; ``rows`` (static)
    pads with zeros or slices so the result matches a target slab length
    regardless of the block-table width.
    """
    g = pool[:, bt]                                  # [nl, B, n, page, ...]
    nl, b, n, pg = g.shape[:4]
    g = g.reshape(nl, b, n * pg, *g.shape[4:])
    if rows is not None:
        if rows <= n * pg:
            g = g[:, :, :rows]
        else:
            w = [(0, 0), (0, 0), (0, rows - n * pg)] \
                + [(0, 0)] * (g.ndim - 3)
            g = jnp.pad(g, w)
    return g


def scatter_pages(pool: Array, rows: Array, bt: Array) -> Array:
    """Write rows [nl, B, t, …] back into pool pages ``bt`` [B, n].

    ``t`` is zero-padded or sliced to ``n·page``; duplicate page ids (the
    id-0 write sink shared by padding and non-folding slots) are allowed —
    every sink write is zeros, so the sink stays all-zero regardless of
    scatter order.
    """
    nl, b, t = rows.shape[:3]
    n, page = bt.shape[1], pool.shape[2]
    want = n * page
    if t < want:
        w = [(0, 0), (0, 0), (0, want - t)] + [(0, 0)] * (rows.ndim - 3)
        rows = jnp.pad(rows, w)
    elif t > want:
        rows = rows[:, :, :want]
    rows = rows.reshape(nl, b, n, page, *rows.shape[3:])
    return pool.at[:, bt].set(rows.astype(pool.dtype))


def write_prefix_pages(pool: Array, u: Array, bt: Array, src: Array
                       ) -> Array:
    """Scatter freshly prefilled U factors (batch rows ``src`` of
    u [nl, nb, s, r_eff]) into pool pages ``bt`` [m, n]; the rank axis is
    zero-padded to the pool width (zero columns are inert)."""
    r = pool.shape[-1]
    u = u[:, src]
    if u.shape[-1] < r:
        u = jnp.pad(u, [(0, 0)] * (u.ndim - 1) + [(0, r - u.shape[-1])])
    return scatter_pages(pool, u, bt)


def _gathered_cache(cache: Params, bt_u: Array, bt_t: Array, t_need: int,
                    r_need: int, tail_len: int) -> Params:
    """Materialize the slot-engine slab view of a paged cache (sliced to
    the mirrored slab geometry, so downstream math is bit-identical)."""
    return {
        "k_u": gather_pages(cache["k_u_pages"], bt_u, t_need)[..., :r_need],
        "v_u": gather_pages(cache["v_u_pages"], bt_u, t_need)[..., :r_need],
        "k_vt": cache["k_vt"][:, :, :r_need],
        "v_vt": cache["v_vt"][:, :, :r_need],
        "tail": {
            "k": gather_pages(cache["tail"]["k_pages"], bt_t, tail_len),
            "v": gather_pages(cache["tail"]["v_pages"], bt_t, tail_len),
        },
    }


def decode_step_dkv_paged(p: Params, cfg, token: Array, cache: Params,
                          pos: Array, frozen_len, bt_u: Array, bt_t: Array,
                          t_need: int, r_need: int, tail_len: int
                          ) -> Tuple[Array, Params]:
    """One-token decode through the page tables: gather each slot's pages
    into the slab view, run the slot-engine step, scatter the updated tail
    rows back into the tail pool.  ``t_need``/``r_need``/``tail_len`` are
    the slot engine's (static) slab dims — the host mirrors them so the
    gathered arrays equal the slab bit-for-bit."""
    slab = _gathered_cache(cache, bt_u, bt_t, t_need, r_need, tail_len)
    logits, upd = decode_step_dkv(p, cfg, token, slab, pos, frozen_len)
    new = dict(cache)
    new["tail"] = {
        "k_pages": scatter_pages(cache["tail"]["k_pages"],
                                 upd["tail"]["k"], bt_t),
        "v_pages": scatter_pages(cache["tail"]["v_pages"],
                                 upd["tail"]["v"], bt_t),
    }
    return logits, new


def decode_block_dkv_paged(p: Params, cfg, token: Array, cache: Params,
                           pos: Array, frozen_len, bt_u: Array, bt_t: Array,
                           n_steps, stop_table: Array, key, round0,
                           t_need: int, r_need: int, tail_len: int, *,
                           sampler, max_block: int):
    """Fused multi-step paged decode: gather each slot's pages into the
    slab view ONCE, run the slab block loop, scatter the updated tail rows
    back at loop exit.

    The block tables and the low-rank prefix pool are loop-invariant —
    folds and admissions (the only writers of ``bt_u``/prefix pages) run
    at block boundaries on the host — so the per-step gather/scatter of
    :func:`decode_step_dkv_paged` collapses to one gather + one scatter
    per BLOCK while the in-loop arithmetic stays the slab engine's,
    bit-for-bit (the gathered slab equals the slot engine's arrays by the
    token-exactness contract above).
    """
    slab = _gathered_cache(cache, bt_u, bt_t, t_need, r_need, tail_len)
    buf, steps, done, upd = decode_block_dkv(
        p, cfg, token, slab, pos, frozen_len, n_steps, stop_table, key,
        round0, sampler=sampler, max_block=max_block)
    new = dict(cache)
    new["tail"] = {
        "k_pages": scatter_pages(cache["tail"]["k_pages"],
                                 upd["tail"]["k"], bt_t),
        "v_pages": scatter_pages(cache["tail"]["v_pages"],
                                 upd["tail"]["v"], bt_t),
    }
    return buf, steps, done, new


def compress_tail_paged(cache: Params, cfg, rank: int, frozen_len, fold,
                        new_frozen, bt_u: Array, bt_u_new: Array,
                        bt_t: Array, t_need: int, r_need: int,
                        tail_len: int) -> Params:
    """Paged tail fold: gather the slab view, run :func:`compress_tail`
    (identical arithmetic), then scatter the retruncated prefix rows into
    FRESH pages ``bt_u_new`` — old pages are never written, so prefix
    pages shared with other slots or the prefix cache stay intact
    (copy-on-write).  Non-folding slots' rows in ``bt_u_new`` point at the
    id-0 sink.  Returns the new pool cache; the caller updates block
    tables and releases the folded slots' old page refs."""
    slab = _gathered_cache(cache, bt_u, bt_t, t_need, r_need, tail_len)
    folded = compress_tail(slab, cfg, rank, frozen_len=frozen_len,
                           fold=fold, new_frozen=new_frozen)
    r_pool = cache["k_u_pages"].shape[-1]
    pad_r = lambda a, ax: a if a.shape[ax] >= r_pool else jnp.pad(
        a, [(0, 0)] * ax + [(0, r_pool - a.shape[ax])]
        + [(0, 0)] * (a.ndim - ax - 1))
    fm = jnp.asarray(fold).reshape(-1)[None, :, None, None]
    # Only FOLDING slots' rows are scattered (their rows at/beyond the new
    # frozen length are already zeroed via ``new_frozen``); non-folding
    # slots' rows — whose bt_u_new entries all point at the id-0 sink —
    # scatter as ZEROS.  This keeps the sink page all-zero FOREVER, which
    # gather_pages' block-table padding relies on: a sink read must
    # return exact zeros, not the residue of a previous fold.
    u_sc = lambda key: jnp.where(fm, pad_r(folded[key], 3), 0.0)
    vt_sel = lambda key: jnp.where(
        fm, pad_r(folded[key], 2).astype(cache[key].dtype),
        cache[key])
    return {
        "k_u_pages": scatter_pages(cache["k_u_pages"], u_sc("k_u"),
                                   bt_u_new),
        "v_u_pages": scatter_pages(cache["v_u_pages"], u_sc("v_u"),
                                   bt_u_new),
        "k_vt": vt_sel("k_vt"),
        "v_vt": vt_sel("v_vt"),
        "tail": {
            "k_pages": scatter_pages(cache["tail"]["k_pages"],
                                     folded["tail"]["k"], bt_t),
            "v_pages": scatter_pages(cache["tail"]["v_pages"],
                                     folded["tail"]["v"], bt_t),
        },
    }


def prefill_suffix_dkv(p: Params, cfg, tokens: Array, prefix: Params,
                       start: Array, slen: Array, tail_len: int
                       ) -> Tuple[Array, Params]:
    """Tail-only prefill for a prefix-cache hit (the paper's "decompose
    once, consume many times" economics applied across REQUESTS).

    ``tokens`` [B, S] is each slot's suffix beyond its matched frozen
    prefix, RIGHT-padded (rows at or beyond ``slen[b]`` are pad; causal
    masking keeps real rows from attending them).  ``prefix`` carries the
    gathered cached factors {k_u/v_u [nl, B, L, r], k_vt/v_vt
    [nl, B, r, kvw]}; ``start`` [B] (= the matched prefix length, the
    slot's frozen_len) sets absolute RoPE positions ``start + i``.

    Returns (logits at each slot's LAST real row [B, V], dense tails
    [nl, B, tail_len, kvh, hd] with rows >= slen zeroed) — exactly the
    per-slot state a full prefill of prefix+suffix would have produced,
    without re-running the prefix forward OR its Lanczos factorization.
    """
    b, s = tokens.shape
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nh // kvh
    scale = hd ** -0.5
    start = jnp.asarray(start, jnp.int32)
    slen = jnp.asarray(slen, jnp.int32)
    x = p["embed"]["w"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    positions = start[:, None] + jnp.arange(s)[None, :]
    row = jnp.arange(s)
    live_row = row[None, :] < slen[:, None]              # [B, S] real rows
    causal = row[:, None] >= row[None, :]                # [S, S]
    t_pre = prefix["k_u"].shape[2]
    pre_valid = jnp.arange(t_pre)[None, :] < start[:, None]

    def scan_fn(x, inp):
        lp, ku, kvt, vu, vvt = inp
        h = T._norm(lp["attn_norm"], x, cfg)
        q = L._split_heads(L.dense(lp["attn"]["wq"], h), nh)
        k = L._split_heads(L.dense(lp["attn"]["wk"], h), kvh)
        v = L._split_heads(L.dense(lp["attn"]["wv"], h), kvh)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        qg = q.reshape(b, s, kvh, g, hd).astype(jnp.float32)

        # prefix scores through the cached factors
        kvt4 = kvt.astype(jnp.float32).reshape(b, -1, kvh, hd)
        inner = jnp.einsum("bskgd,brkd->bskgr", qg, kvt4)
        sc_pre = jnp.einsum("bskgr,btr->bskgt", inner,
                            ku.astype(jnp.float32)) * scale
        sc_pre = jnp.where(pre_valid[:, None, None, None, :], sc_pre, -1e30)

        # within-suffix causal scores (exact)
        kf = k.astype(jnp.float32)
        sc_suf = jnp.einsum("bskgd,btkd->bskgt", qg, kf) * scale
        sc_suf = jnp.where(causal[None, :, None, None, :], sc_suf, -1e30)

        pr = jax.nn.softmax(
            jnp.concatenate([sc_pre, sc_suf], axis=-1), axis=-1)
        p_pre, p_suf = pr[..., :t_pre], pr[..., t_pre:]
        tmp = jnp.einsum("bskgt,btr->bskgr", p_pre,
                         vu.astype(jnp.float32))
        vvt4 = vvt.astype(jnp.float32).reshape(b, -1, kvh, hd)
        out = jnp.einsum("bskgr,brkd->bskgd", tmp, vvt4)
        out = out + jnp.einsum("bskgt,btkd->bskgd", p_suf,
                               v.astype(jnp.float32))
        out = out.reshape(b, s, nh * hd)
        x = x + L.dense(lp["attn"]["wo"], out.astype(x.dtype))
        x = x + L.mlp(lp["mlp"], T._norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)

        # suffix K/V become the slot's dense tail; pad rows zeroed so
        # later folds see exactly what a full prefill would have left
        zmask = live_row[:, :, None, None]
        tk = jnp.where(zmask, k, 0).astype(cfg.jax_dtype)
        tv = jnp.where(zmask, v, 0).astype(cfg.jax_dtype)
        pad = [(0, 0), (0, tail_len - s), (0, 0), (0, 0)]
        return x, {"k": jnp.pad(tk, pad), "v": jnp.pad(tv, pad)}

    x, tails = L.xscan(scan_fn, x,
                       (p["layers"], prefix["k_u"], prefix["k_vt"],
                        prefix["v_u"], prefix["v_vt"]))
    x_last = jnp.take_along_axis(
        x, jnp.maximum(slen - 1, 0)[:, None, None], axis=1)
    return T.logits_head(p, x_last, cfg)[:, 0], tails
