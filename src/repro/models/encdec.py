"""Seamless-M4T-medium backbone: transformer encoder over STUBBED audio
frame embeddings + autoregressive text decoder with cross-attention.

Adaptations recorded in DESIGN.md: the conformer audio frontend is replaced
by precomputed frame embeddings from ``input_specs`` (per the brief);
positions use RoPE in both stacks (the released model's relative-position
machinery is orthogonal to the paper's technique).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]


def init_enc_block(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    dt = cfg.jax_dtype
    return {
        "attn_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "attn": L.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                                 cfg.use_bias),
        "mlp_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.gated_mlp,
                          cfg.use_bias),
    }


def init_dec_block(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.jax_dtype
    p = init_enc_block(ks[0], cfg)
    p["xattn_norm"] = L.norm_init(cfg.d_model, dt, cfg.use_bias)
    p["xattn"] = L.attention_init(ks[1], cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                                  cfg.use_bias)
    return p


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    dt = cfg.jax_dtype
    return {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "enc": jax.vmap(lambda k: init_enc_block(k, cfg))(
            jax.random.split(ks[1], cfg.enc_layers)),
        "enc_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "dec": jax.vmap(lambda k: init_dec_block(k, cfg))(
            jax.random.split(ks[2], cfg.dec_layers)),
        "final_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "lm_head": L.dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt),
    }


def _norm(p, x, cfg):
    return L.layernorm(p, x, cfg.norm_eps) if cfg.use_bias \
        else L.rmsnorm(p, x, cfg.norm_eps)


def encode(p: Params, cfg, frames: Array) -> Array:
    """frames [B, M, H] (stub frontend output) → encoder memory [B, M, H]."""
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])

    def enc_block(lp, x):
        x = x + L.causal_attention(lp["attn"], _norm(lp["attn_norm"], x, cfg),
                                   cfg, positions, causal=False)
        x = x + L.mlp(lp["mlp"], _norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x

    body = L.ckpt(enc_block, cfg)
    x, _ = L.xscan(lambda x, lp: (body(lp, x), None), frames, p["enc"])
    return _norm(p["enc_norm"], x, cfg)


def dec_block(lp: Params, x: Array, memory: Array, positions: Array,
              cfg) -> Array:
    x = x + L.causal_attention(lp["attn"], _norm(lp["attn_norm"], x, cfg),
                               cfg, positions)
    kv = L.memory_kv(lp["xattn"], memory, cfg.num_kv_heads)
    x = x + L.cross_attention(lp["xattn"], _norm(lp["xattn_norm"], x, cfg),
                              kv, cfg)
    x = x + L.mlp(lp["mlp"], _norm(lp["mlp_norm"], x, cfg), cfg.activation)
    return x


def forward(p: Params, cfg, frames: Array, tokens: Array) -> Array:
    """frames [B, M, H]; decoder tokens [B, S] → logits [B, S, V]."""
    memory = encode(p, cfg, frames)
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    body = L.ckpt(dec_block, cfg, static_argnums=(4,))
    x, _ = L.xscan(
        lambda x, lp: (body(lp, x, memory, positions, cfg), None),
        x, p["dec"])
    return T.logits_head(p, x, cfg)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    logits = forward(p, cfg, batch["frames"], batch["tokens"])
    return L.cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill_inputs(cfg, tokens, make, mem_len=None):
    """``ModelFns.prefill_inputs``: encoder frames FIRST, then tokens.

    ``mem_len`` is the encoder memory length: training/dry-run specs pass
    the workload sequence length; ``None`` (the serving engine) resolves
    to ``cfg.num_audio_frames`` — the ``init_cache`` cross-KV contract —
    NOT the token prefix length."""
    m = cfg.num_audio_frames if mem_len is None else mem_len
    b = tokens.shape[0]
    return (make((b, m, cfg.d_model), cfg.jax_dtype), tokens)


def batch_extras(cfg, b, s, make):
    """``ModelFns.batch_extras``: training batches carry audio frames."""
    return {"frames": make((b, s, cfg.d_model), cfg.jax_dtype)}


def init_cache(cfg, batch: int, max_len: int) -> Params:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    nd, m = cfg.dec_layers, cfg.num_audio_frames
    return {
        "self": {"k": jnp.zeros((nd, batch, max_len, kvh, hd), cfg.jax_dtype),
                 "v": jnp.zeros((nd, batch, max_len, kvh, hd),
                                cfg.jax_dtype)},
        "cross": {"k": jnp.zeros((nd, batch, m, kvh, hd), cfg.jax_dtype),
                  "v": jnp.zeros((nd, batch, m, kvh, hd), cfg.jax_dtype)},
    }


def prefill(p: Params, cfg, frames: Array, tokens: Array,
            max_len: Optional[int] = None) -> Tuple[Array, Params]:
    """Encode audio + run the decoder over the token prefix, emitting caches."""
    b, s = tokens.shape
    t = max_len or s
    memory = encode(p, cfg, frames)
    x = p["embed"]["w"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)
    pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]

    def scan_fn(x, lp):
        h = _norm(lp["attn_norm"], x, cfg)
        k = L.apply_rope(L._split_heads(L.dense(lp["attn"]["wk"], h),
                                        cfg.num_kv_heads), positions,
                         cfg.rope_theta)
        v = L._split_heads(L.dense(lp["attn"]["wv"], h), cfg.num_kv_heads)
        ck, cv = L.memory_kv(lp["xattn"], memory, cfg.num_kv_heads)
        x = dec_block(lp, x, memory, positions, cfg)
        return x, ({"k": jnp.pad(k.astype(cfg.jax_dtype), pad),
                    "v": jnp.pad(v.astype(cfg.jax_dtype), pad)},
                   {"k": ck.astype(cfg.jax_dtype),
                    "v": cv.astype(cfg.jax_dtype)})

    x, (kv, ckv) = L.xscan(scan_fn, x, p["dec"])
    logits = T.logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, {"self": kv, "cross": ckv}


def decode_step(p: Params, cfg, token: Array, cache: Params, pos: Array
                ) -> Tuple[Array, Params]:
    x = p["embed"]["w"][token][:, None, :]

    def scan_fn(x, inp):
        lp, c, ckv = inp
        h = _norm(lp["attn_norm"], x, cfg)
        a, c = L.decode_attention(lp["attn"], h, c, pos, cfg)
        x = x + a
        h = _norm(lp["xattn_norm"], x, cfg)
        x = x + L.cross_attention(lp["xattn"], h, (ckv["k"], ckv["v"]), cfg)
        x = x + L.mlp(lp["mlp"], _norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x, c

    x, kv = L.xscan(scan_fn, x, (p["dec"], cache["self"],
                                      cache["cross"]))
    return T.logits_head(p, x, cfg)[:, 0], {"self": kv,
                                            "cross": cache["cross"]}
