"""Mamba2 SSD (state-space duality) — chunked dual form + O(1) decode state.

Faithful to the Mamba2 paper's chunked algorithm:
  * intra-chunk term: attention-like masked matmul M[t,s] = (C_t·B_s)
    ·exp(l_t−l_s)·dt_s for s ≤ t within a chunk,
  * inter-chunk term: per-chunk final states combined by a sequential scan
    over chunks, then broadcast back through C_t,
all in fp32.  The [B, nc, nh, Q, Q] decay tensor is the memory hot-spot; the
chunk length ``CHUNK`` trades it against scan length (a Pallas SSD kernel is
the obvious further step on hardware — recorded as future work).

Decode carries (conv_cache [B, w−1, ch], ssm_state [B, nh, hd, ds]) — O(1)
in sequence length, which is why the ssm/hybrid archs run ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]

CHUNK = 64


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def ssd_init(key, cfg) -> Params:
    dt = cfg.jax_dtype
    h, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], h, 2 * di + 2 * ds + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(0) = -1
        "d": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": L.norm_init(di, dt),
        "out_proj": L.dense_init(ks[2], di, h, dt),
    }


def init_block(key, cfg) -> Params:
    return {"norm": L.norm_init(cfg.d_model, cfg.jax_dtype),
            "ssd": ssd_init(key, cfg)}


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.jax_dtype
    return {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(
            jax.random.split(ks[1], cfg.num_layers)),
        "final_norm": L.norm_init(cfg.d_model, dt),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt),
    }


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------

def _conv_causal(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: x [B, S, C], w [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):            # width is 4 — unrolled taps
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(proj: Array, cfg):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * ds]
    dt_raw = proj[..., 2 * di + 2 * ds:]
    return z, xbc, dt_raw


def ssd_apply(p: Params, x_in: Array, cfg) -> Array:
    """Full-sequence SSD: x_in [B, S, H] → [B, S, H]."""
    b, s, _ = x_in.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = L.dense(p["in_proj"], x_in)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _conv_causal(xbc, p["conv_w"], p["conv_b"])
    xh = xbc[..., :di].reshape(b, s, nh, hd).astype(jnp.float32)
    bm = xbc[..., di:di + ds].astype(jnp.float32)            # [B, S, ds]
    cm = xbc[..., di + ds:].astype(jnp.float32)              # [B, S, ds]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    a = -jnp.exp(p["a_log"])                                  # [nh]
    da = dt * a                                               # [B, S, nh] < 0

    q = min(CHUNK, s)
    if s % q != 0:
        q = s
    nc = s // q

    def ch(t):  # chunked view
        return t.reshape((b, nc, q) + t.shape[2:])

    xh_c, bm_c, cm_c, dt_c, da_c = map(ch, (xh, bm, cm, dt, da))
    l = jnp.cumsum(da_c, axis=2)                              # [B,nc,Q,nh]

    # ---- intra-chunk (masked attention-like dual form) -------------------
    cb = jnp.einsum("bcqd,bcsd->bcqs", cm_c, bm_c)            # [B,nc,Q,Q]
    decay = jnp.exp(l[:, :, :, None, :] - l[:, :, None, :, :])  # [B,nc,Q,Q,nh]
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = cb[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0) \
        * dt_c[:, :, None, :, :]                              # [B,nc,Q,Q,nh]
    y_intra = jnp.einsum("bcqsn,bcsnp->bcqnp", m, xh_c)

    # ---- inter-chunk (recurrence over chunk states) -----------------------
    decay_to_end = jnp.exp(l[:, :, -1:, :] - l)               # [B,nc,Q,nh]
    states = jnp.einsum("bcsd,bcsn,bcsnp->bcnpd",
                        bm_c, dt_c * decay_to_end, xh_c)      # [B,nc,nh,hd,ds]
    g = jnp.exp(l[:, :, -1, :])                               # [B,nc,nh]

    def scan_fn(h_prev, inp):
        g_c, s_c = inp
        h_new = g_c[:, :, None, None] * h_prev + s_c
        return h_new, h_prev

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    # plain lax.scan (NOT xscan): the inter-chunk state recurrence carries
    # ~0.01% of layer FLOPs, and unrolling its S/Q iterations (512 at 32k)
    # explodes probe compile time for no cost-accuracy gain.
    _, h_prevs = jax.lax.scan(scan_fn, h0,
                              (jnp.moveaxis(g, 1, 0),
                               jnp.moveaxis(states, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # [B,nc,nh,hd,ds]
    y_inter = jnp.einsum("bcqd,bcqn,bcnpd->bcqnp",
                         cm_c, jnp.exp(l), h_prevs)

    y = (y_intra + y_inter).reshape(b, s, nh, hd) \
        + p["d"][None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm(p["norm"], y.astype(x_in.dtype), cfg.norm_eps)
    return L.dense(p["out_proj"], y)


def ssd_decode(p: Params, x_in: Array, state: Params, cfg
               ) -> Tuple[Array, Params]:
    """One-token SSD step: x_in [B, 1, H]; state = {conv [B,W-1,ch],
    ssm [B,nh,hd,ds]}."""
    b = x_in.shape[0]
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    width = cfg.ssm_conv_width

    proj = L.dense(p["in_proj"], x_in)
    z, xbc, dt_raw = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([state["conv"], xbc.astype(state["conv"].dtype)],
                              axis=1)                          # [B, W, ch]
    xbc_c = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
    xbc_c = jax.nn.silu(xbc_c + p["conv_b"].astype(jnp.float32))
    new_conv = conv_in[:, 1:, :]

    xh = xbc_c[:, :di].reshape(b, nh, hd).astype(jnp.float32)
    bm = xbc_c[:, di:di + ds].astype(jnp.float32)              # [B, ds]
    cm = xbc_c[:, di + ds:].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    g = jnp.exp(dt * a)                                        # [B, nh]

    h = state["ssm"].astype(jnp.float32)
    h = g[:, :, None, None] * h \
        + jnp.einsum("bn,bnp,bd->bnpd", dt, xh, bm)
    y = jnp.einsum("bd,bnpd->bnp", cm, h) + p["d"][None, :, None] * xh
    y = y.reshape(b, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    y = L.rmsnorm(p["norm"], y.astype(x_in.dtype), cfg.norm_eps)
    return L.dense(p["out_proj"], y), {"conv": new_conv,
                                       "ssm": h.astype(state["ssm"].dtype)}


# ---------------------------------------------------------------------------
# Model-level
# ---------------------------------------------------------------------------

def block(p: Params, x: Array, cfg) -> Array:
    return x + ssd_apply(p["ssd"], L.rmsnorm(p["norm"], x, cfg.norm_eps), cfg)


def forward(p: Params, cfg, tokens: Array) -> Array:
    x = p["embed"]["w"][tokens]
    body = L.ckpt(block, cfg, static_argnums=(2,))
    x, _ = L.xscan(lambda x, lp: (body(lp, x, cfg), None),
                        x, p["layers"])
    return T.logits_head(p, x, cfg)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    return L.cross_entropy(forward(p, cfg, batch["tokens"]), batch["labels"])


def init_state(cfg, batch: int, max_len: Optional[int] = None) -> Params:
    del max_len                      # state is O(1); no cache length needed
    nl = cfg.num_layers
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((nl, batch, cfg.ssm_conv_width - 1, conv_ch),
                          cfg.jax_dtype),
        "ssm": jnp.zeros((nl, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }


def prefill(p: Params, cfg, tokens: Array, max_len: Optional[int] = None
            ) -> Tuple[Array, Params]:
    """SSM prefill: full forward; final state assembled per layer."""
    del max_len                      # state is O(1); no cache length needed
    b, s = tokens.shape
    x = p["embed"]["w"][tokens]

    def scan_fn(x, lp):
        h_in = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        x = x + ssd_apply(lp["ssd"], h_in, cfg)
        # Rebuild the final (conv, ssm) state for decode continuation:
        proj = L.dense(lp["ssd"]["in_proj"], h_in)
        _, xbc, dt_raw = _split_proj(proj, cfg)
        conv_tail = xbc[:, -(cfg.ssm_conv_width - 1):, :].astype(cfg.jax_dtype)
        xbc_f = _conv_causal(xbc, lp["ssd"]["conv_w"], lp["ssd"]["conv_b"])
        di, ds, nh, hd = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                          cfg.ssm_head_dim)
        xh = xbc_f[..., :di].reshape(b, s, nh, hd).astype(jnp.float32)
        bm = xbc_f[..., di:di + ds].astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["ssd"]["dt_bias"])
        da = dt * (-jnp.exp(lp["ssd"]["a_log"]))
        l = jnp.cumsum(da, axis=1)                            # [B,S,nh]
        decay_to_end = jnp.exp(l[:, -1:, :] - l)
        ssm = jnp.einsum("bsd,bsn,bsnp->bnpd", bm, dt * decay_to_end, xh)
        return x, {"conv": conv_tail, "ssm": ssm}

    x, state = L.xscan(scan_fn, x, p["layers"])
    logits = T.logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, state


def decode_step(p: Params, cfg, token: Array, state: Params, pos: Array
                ) -> Tuple[Array, Params]:
    del pos                          # SSM state is position-free
    x = p["embed"]["w"][token][:, None, :]

    def scan_fn(x, inp):
        lp, st = inp
        y, st = ssd_decode(lp["ssd"], L.rmsnorm(lp["norm"], x, cfg.norm_eps),
                           st, cfg)
        return x + y, st

    x, state = L.xscan(scan_fn, x, (p["layers"], state))
    return T.logits_head(p, x, cfg)[:, 0], state
