"""Decomposed-execution integration layer — the paper's technique wired into
the model zoo (paper Figs. 1, 5, 6).

All decomposition flows through ONE :class:`~repro.engine.DecomposeEngine`
(carried by :class:`DecomposedRuntime`); this module only decides WHERE in
the block the engine is invoked.  For every layer the
:class:`~repro.core.policy.DecompositionPolicy` selects, the block input is
(a) outlier-extracted channel-wise (§4), (b) decomposed by the engine's
natively batched Lanczos bidiagonalization (§2.3), and (c) consumed by the
layer's GEMMs in decomposition-preserved form (§3.2):

* QKV projections: ``lowrank_matmul`` (Eq. 6) — or
  ``lowrank_x_lowrank_weight`` (Eq. 7) when the policy also decomposes the
  weights (Table 3 mode; weight factors are produced OFFLINE by
  :func:`decompose_layer_weights`).
* Attention scores / PV: two modes —
  - ``attn_mode="dense"`` (default): Q/K/V reconstructed per head, RoPE
    applied, chunked dense attention.  Exact numerics; savings come from the
    rank-k projections (this is what the quality benchmarks use).
  - ``attn_mode="preserved"``: QKᵀ and P·V contracted *through the factors*
    (S·S·k instead of S·S·dh) — the paper's "keep inputs decomposed for all
    matmuls within a layer".  RoPE cannot be folded into a
    position-independent Vᵀ factor, so this mode skips RoPE inside
    decomposed layers (NoPE approximation; the trade-off is measured in
    benchmarks, recorded in DESIGN.md §2).
* MLP: up/gate as preserved matmuls, reconstruct at the nonlinearity
  (non-GEMM boundary, paper Fig. 6), dense down-projection.

The residual stream stays dense at block boundaries (paper's best configs
decompose non-adjacent layers, so cross-layer preserved chains don't arise;
the pure matmul-chain path of Eq. 6/7 is exercised directly by
``benchmarks/fig11_layer_runtime``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.policy import DecompositionPolicy, LayerPolicy
from ..core.lowrank import LowRank
from ..engine import DecomposeEngine, EngineConfig
from . import layers as L
from . import transformer as T

Array = jax.Array
Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DecomposedRuntime:
    """Runtime configuration for decomposed execution.

    A thin, constructor-compatible shell around :class:`DecomposeEngine`:
    every decomposition (and every preserved-form consumption) goes through
    ``self.engine`` — the runtime only carries it plus the whole-model
    policy.  Pass ``engine=`` to share one engine across call sites, or let
    ``__post_init__`` build one from (policy, attn_mode, backend).
    """
    policy: Optional[DecompositionPolicy] = None
    attn_mode: str = "dense"             # "dense" | "preserved"
    backend: str = "reference"           # engine backend registry key
    engine: Optional[DecomposeEngine] = None

    def __post_init__(self):
        if self.engine is None:
            object.__setattr__(self, "engine", DecomposeEngine(EngineConfig(
                policy=self.policy, backend=self.backend,
                attn_mode=self.attn_mode)))
        else:
            # The engine is the source of truth; reject CONFLICTING explicit
            # settings rather than silently overriding them (leaving a field
            # at its default means "inherit from the engine").
            for field, mine, its in (
                    ("attn_mode", self.attn_mode, self.engine.attn_mode),
                    ("backend", self.backend, self.engine.backend.name)):
                if mine != type(self).__dataclass_fields__[field].default \
                        and mine != its:
                    raise ValueError(
                        f"DecomposedRuntime({field}={mine!r}) conflicts with "
                        f"engine's {field}={its!r}; configure the "
                        f"EngineConfig instead")
            if (self.policy is not None
                    and self.engine.config.policy is not None
                    and self.policy is not self.engine.config.policy):
                raise ValueError(
                    "DecomposedRuntime(policy=...) conflicts with the "
                    "engine's policy; configure the EngineConfig instead")
            if self.policy is None:
                object.__setattr__(self, "policy",
                                   self.engine.config.policy)
            object.__setattr__(self, "attn_mode", self.engine.attn_mode)
            object.__setattr__(self, "backend", self.engine.backend.name)
        if self.policy is None:
            raise ValueError("DecomposedRuntime needs a DecompositionPolicy "
                             "(directly or via the engine's EngineConfig)")

    def layer(self, i: int) -> LayerPolicy:
        return self.policy.layer(i)


# ---------------------------------------------------------------------------
# Activation decomposition (outliers + Lanczos), batched
# ---------------------------------------------------------------------------

def decompose_activation(x: Array, lp: LayerPolicy, threshold: float,
                         engine: Optional[DecomposeEngine] = None) -> LowRank:
    """x [B, S, H] → LowRank with dense outlier channel track.

    Thin compatibility wrapper: the pipeline (outlier extraction, batched
    Lanczos, track re-attachment) lives in
    :meth:`DecomposeEngine.decompose_activation`.
    """
    engine = engine or _DEFAULT_ENGINE
    return engine.decompose_activation(x, lp=lp, threshold=threshold)


_DEFAULT_ENGINE = DecomposeEngine(EngineConfig())


# ---------------------------------------------------------------------------
# Offline weight decomposition (Table 3 mode)
# ---------------------------------------------------------------------------

WEIGHT_KEYS = ("wq", "wk", "wv")        # attention in-projections
MLP_KEYS = ("up", "gate")


def decompose_layer_weights(params: Params, cfg,
                            policy: DecompositionPolicy) -> Dict[int, Params]:
    """Offline: per decomposed layer, factor the in-projection weights.

    Returns {layer_idx: {"attn": {wq/wk/wv: LowRank}, "mlp": {...}}}.
    Layer params are stacked [L, ...]; we slice per layer.
    """
    engine = DecomposeEngine(EngineConfig(policy=policy))
    out: Dict[int, Params] = {}
    for i in policy.decomposed_layers():
        lp = policy.layer(i)
        if not lp.decompose_weights:
            continue
        layer = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        fac: Params = {"attn": {}, "mlp": {}}
        for kname in WEIGHT_KEYS:
            fac["attn"][kname] = engine.decompose_weight(
                layer["attn"][kname]["w"], lp.weight_rank)
        for kname in MLP_KEYS:
            if kname in layer["mlp"]:
                fac["mlp"][kname] = engine.decompose_weight(
                    layer["mlp"][kname]["w"], lp.weight_rank)
        out[i] = fac
    return out


# ---------------------------------------------------------------------------
# Decomposed dense-transformer block
# ---------------------------------------------------------------------------

def decomposed_block(p: Params, x: Array, positions: Array, cfg,
                     lp: LayerPolicy, threshold: float,
                     wfac: Optional[Params] = None,
                     engine: Optional[DecomposeEngine] = None) -> Array:
    """One transformer block executed in decomposed form per ``lp``.

    All decomposition AND all preserved-form consumption go through the
    ``engine`` (backend/attn-mode were chosen once at its construction).
    """
    engine = engine or _DEFAULT_ENGINE
    nh, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape

    # ---- attention path -------------------------------------------------
    h1 = T._norm(p["attn_norm"], x, cfg)
    lr = engine.decompose_activation(h1, lp=lp, threshold=threshold)

    wf = (wfac or {}).get("attn", {})
    q_lr = engine.project(lr, p["attn"]["wq"], wf.get("wq"))
    k_lr = engine.project(lr, p["attn"]["wk"], wf.get("wk"))
    v_lr = engine.project(lr, p["attn"]["wv"], wf.get("wv"))

    if engine.attn_mode == "preserved":
        # Paper's preserved QKᵀ/PV contractions (NoPE inside the layer).
        sc = engine.qk_scores(q_lr, k_lr, nh, hd ** -0.5, kvh)
        mask = positions[..., None] >= positions[..., None, :]
        sc = jnp.where(mask[:, None, :, :], sc.astype(jnp.float32), -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        attn_out = engine.pv(pr, v_lr, nh, kvh).astype(x.dtype)
    else:
        q = L._split_heads(q_lr.reconstruct(), nh)
        k = L._split_heads(k_lr.reconstruct(), kvh)
        v = L._split_heads(v_lr.reconstruct(), kvh)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn_out = L.attend(q, k, v, positions, out_dtype=x.dtype)

    x = x + L.dense(p["attn"]["wo"], attn_out)

    # ---- MLP path --------------------------------------------------------
    h2 = T._norm(p["mlp_norm"], x, cfg)
    lr2 = engine.decompose_activation(h2, lp=lp, threshold=threshold)
    wfm = (wfac or {}).get("mlp", {})
    up = engine.project(lr2, p["mlp"]["up"], wfm.get("up")).reconstruct()
    act = L.activation_fn(cfg.activation)
    if "gate" in p["mlp"]:
        gate = engine.project(lr2, p["mlp"]["gate"],
                              wfm.get("gate")).reconstruct()
        hidden = act(gate) * up
    else:
        hidden = act(up)
    x = x + L.dense(p["mlp"]["down"], hidden.astype(x.dtype))
    return x


# ---------------------------------------------------------------------------
# Whole-model decomposed forward (dense family)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg, tokens: Array, runtime: DecomposedRuntime,
            wfactors: Optional[Dict[int, Params]] = None) -> Array:
    """Dense-LM forward with per-layer policy-selected decomposed execution.

    Python-level layer loop (policies differ per layer); decomposed layers
    run :func:`decomposed_block`, the rest the standard block.
    """
    x = params["embed"]["w"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    for i in range(cfg.num_layers):
        layer = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        pol = runtime.layer(i)
        if pol.decompose:
            thr = runtime.policy.thresholds.get(i)
            x = decomposed_block(layer, x, positions, cfg, pol, thr,
                                 (wfactors or {}).get(i),
                                 engine=runtime.engine)
        else:
            x = T.block(layer, x, positions, cfg)
    return T.logits_head(params, x, cfg)


def logit_kl(params: Params, cfg, tokens: Array,
             runtime: DecomposedRuntime,
             wfactors: Optional[Dict[int, Params]] = None) -> Array:
    """KL(base ‖ decomposed) over the vocab — the container-feasible stand-in
    for the paper's arc_easy/wikitext quality metrics (see DESIGN.md §7)."""
    base = jax.nn.log_softmax(
        T.forward(params, cfg, tokens).astype(jnp.float32), axis=-1)
    dec = jax.nn.log_softmax(
        forward(params, cfg, tokens, runtime, wfactors).astype(jnp.float32),
        axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(base) * (base - dec), axis=-1))
