"""Dense decoder-only transformer (gemma / starcoder2 / deepseek / granite /
llama2).  Layer params are stacked on a leading L axis and driven by
``jax.lax.scan``; KV caches are stacked the same way.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    return {
        "attn_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "attn": L.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dt, cfg.use_bias),
        "mlp_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, cfg.gated_mlp,
                          cfg.use_bias),
    }


def init(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    dt = cfg.jax_dtype
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    p = {
        "embed": L.embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dt),
        "layers": stacked,
        "final_norm": L.norm_init(cfg.d_model, dt, cfg.use_bias),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _norm(p, x, cfg):
    return L.layernorm(p, x, cfg.norm_eps) if cfg.use_bias \
        else L.rmsnorm(p, x, cfg.norm_eps)


def _sp(x: Array, cfg) -> Array:
    """Sequence-parallel residual constraint (Megatron-SP): the residual
    stream lives sequence-sharded over "model"; GSPMD then emits
    all-gather before the TP matmuls and reduce-scatter after them —
    halving activation-collective bytes vs two all-reduces."""
    if not cfg.seq_parallel:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(None, "model", None))


def block(p: Params, x: Array, positions: Array, cfg) -> Array:
    x = _sp(x, cfg)
    x = x + L.causal_attention(p["attn"], _norm(p["attn_norm"], x, cfg),
                               cfg, positions)
    x = _sp(x, cfg)
    x = x + L.mlp(p["mlp"], _norm(p["mlp_norm"], x, cfg), cfg.activation)
    return x


def logits_head(p: Params, x: Array, cfg) -> Array:
    x = _norm(p["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...h,vh->...v", x, p["embed"]["w"])
    else:
        logits = L.dense(p["lm_head"], x)
    if cfg.padded_vocab != cfg.vocab:      # mask the padding tail
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits


def forward(p: Params, cfg, tokens: Array) -> Array:
    """tokens [B, S] → logits [B, S, V]."""
    x = p["embed"]["w"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    body = L.ckpt(block, cfg, static_argnums=(3,))

    def scan_fn(x, lp):
        return body(lp, x, positions, cfg), None

    x, _ = L.xscan(scan_fn, x, p["layers"])
    return logits_head(p, x, cfg)


def loss_fn(p: Params, cfg, batch: Dict[str, Array]) -> Array:
    logits = forward(p, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> Params:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, kvh, hd)
    return {"k": jnp.zeros(shape, cfg.jax_dtype),
            "v": jnp.zeros(shape, cfg.jax_dtype)}


def prefill(p: Params, cfg, tokens: Array, max_len: Optional[int] = None
            ) -> Tuple[Array, Params]:
    """Full-sequence forward that also emits the KV cache.

    Returns (last-position logits [B, V], cache stacked [L, B, T, kvh, d]).
    """
    b, s = tokens.shape
    t = max_len or s
    x = p["embed"]["w"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)

    def scan_fn(x, lp):
        h = _norm(lp["attn_norm"], x, cfg)
        kvh = cfg.num_kv_heads
        k = L._split_heads(L.dense(lp["attn"]["wk"], h), kvh)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        v = L._split_heads(L.dense(lp["attn"]["wv"], h), kvh)
        x = block(lp, x, positions, cfg)
        pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
        return x, {"k": jnp.pad(k.astype(cfg.jax_dtype), pad),
                   "v": jnp.pad(v.astype(cfg.jax_dtype), pad)}

    x, cache = L.xscan(scan_fn, x, p["layers"])
    logits = logits_head(p, x[:, -1:, :], cfg)[:, 0]
    return logits, cache


def decode_step(p: Params, cfg, token: Array, cache: Params, pos: Array
                ) -> Tuple[Array, Params]:
    """One-token step: token [B], pos [B] → (logits [B, V], new cache)."""
    x = p["embed"]["w"][token][:, None, :] * jnp.asarray(
        cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0, cfg.jax_dtype)

    def scan_fn(x, inp):
        lp, c = inp
        h = _norm(lp["attn_norm"], x, cfg)
        a, c = L.decode_attention(lp["attn"], h, c, pos, cfg)
        x = x + a
        x = x + L.mlp(lp["mlp"], _norm(lp["mlp_norm"], x, cfg),
                      cfg.activation)
        return x, c

    x, cache = L.xscan(scan_fn, x, (p["layers"], cache))
    return logits_head(p, x, cfg)[:, 0], cache
