"""Paged decomposed-KV cache: page allocator, prefix cache, paged state.

The slot engine's ``[slots, max_len, …]`` slab wastes HBM on short
sequences and caps long ones; worse, it re-runs prefill AND the Lanczos
factorization for every admitted prompt even when millions of requests
share one system prompt.  This module supplies the vLLM-style fix on top
of ``models.decomposed_kv``'s page pools:

* :class:`PageAllocator` — refcounted free-list over page ids.  Id 0 is
  reserved as the WRITE SINK (block-table padding and non-folding slots'
  fold-scatter targets); real pages are 1..num_pages-1.
* :class:`PrefixCache` — hash-keyed store of frozen decomposed prefixes
  at page granularity.  One insertion registers every page-aligned
  boundary of the prompt as a match point (vLLM's per-block hash chain,
  flattened); lookup returns the LONGEST cached prefix of a new padded
  prompt whose remaining suffix fits in the dense tail.  Entries hold
  page refs, so slot lifecycle (folds free a slot's old pages) never
  invalidates cached pages — folds copy-on-write into fresh pages.
* :class:`PagedDKV` — per-engine paged state: pools, block tables, the
  two allocators, and the HOST MIRROR of the slot engine's slab geometry
  (``slab_t``/``slab_r``) that makes paged arithmetic bit-identical to
  the slab engine's (see models/decomposed_kv.py).

A prefix-cache hit admits with TAIL-ONLY work: the matched pages are
spliced by reference (refcount bump), the per-slot Vᵀ factors are copied
from the entry, and only the suffix tokens run a forward pass
(``prefill_suffix_dkv``) — no prefix forward, no Lanczos.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decomposed_kv as DK

SINK = 0                             # reserved write-sink page id


class PageAllocator:
    """Refcounted free-list allocator over page ids ``1..num_pages-1``.

    ``alloc`` returns None when the pool can't satisfy the request (the
    caller defers admission); ``release`` decrements and returns a page
    to the free list at refcount zero; releasing an unallocated page
    raises (double-free guard).
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one real page beside the sink"
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_refs(self) -> Dict[int, int]:
        return dict(self._ref)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def ref(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"ref of unallocated page {p}")
            self._ref[p] += 1

    def release(self, pages: List[int]) -> None:
        for p in pages:
            rc = self._ref.get(p)
            if rc is None:
                raise ValueError(f"double free of page {p}")
            if rc == 1:
                del self._ref[p]
                self._free.append(p)
            else:
                self._ref[p] = rc - 1


@dataclasses.dataclass
class PrefixEntry:
    tokens: np.ndarray               # the full padded prompt (int32)
    pages: List[int]                 # FULL pages: rows 0..len(pages)·page
    k_vt: jax.Array                  # [nl, r_eff, kvw]
    v_vt: jax.Array
    r_eff: int
    n_pad: int = 0                   # left-pad rows (bucket rounding)


class PrefixCache:
    """LRU cache of frozen decomposed prefixes, matched at page-aligned
    boundaries of the PADDED prompt.

    Matching operates on the padded token sequence (the serving engine
    left-pads prompts to the scheduler bucket, and the cached factors
    were computed over exactly those rows), so prompts share a prefix
    when their padded forms do — equal-length prompts behind a common
    system prompt, or identical prompts resubmitted.
    """

    def __init__(self, capacity: int, page: int, alloc: PageAllocator):
        self.capacity = max(1, capacity)
        self.page = page
        self.alloc = alloc
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self._by_prefix: Dict[Tuple[int, bytes], PrefixEntry] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _digest(tokens: np.ndarray) -> bytes:
        return hashlib.sha1(np.ascontiguousarray(
            tokens.astype(np.int32)).tobytes()).digest()

    def _boundaries(self, n_tokens: int, n_pad: int = 0):
        """Page-aligned match lengths: every full page, suffix non-empty,
        and the shared prefix must reach past the left-pad region — a
        boundary lying entirely inside the bucket padding would "match"
        unrelated prompts that merely share a pad count (their pad rows
        are identical tokens, but the entry's low-rank basis was fit to
        ITS real rows, not the query's)."""
        top = (n_tokens - 1) // self.page * self.page
        lo = n_pad // self.page * self.page + self.page
        return range(lo, top + 1, self.page)

    def lookup(self, padded: np.ndarray, max_suffix: int, n_pad: int = 0,
               record: bool = True) -> Optional[Tuple[PrefixEntry, int]]:
        """Longest cached prefix of ``padded`` whose suffix (the rest of
        the prompt) fits in ``max_suffix`` tail rows and which covers at
        least one of the query's REAL tokens (``n_pad`` = its left-pad
        row count).  ``record=False`` skips the hit/miss counters (the
        LRU touch still happens): the engine probes here at every
        admission attempt and counts once per ADMITTED request at
        dispatch, so page-pressure defer/retry cycles don't inflate the
        stats."""
        n = len(padded)
        for ln in reversed(self._boundaries(n, n_pad)):
            if n - ln > max_suffix:
                break                # shorter matches only lengthen it
            ent = self._by_prefix.get((ln, self._digest(padded[:ln])))
            if ent is not None and np.array_equal(ent.tokens[:ln],
                                                  padded[:ln]):
                self._entries.move_to_end(self._digest(ent.tokens))
                if record:
                    self.hits += 1
                return ent, ln
        if record:
            self.misses += 1
        return None

    def insert(self, padded: np.ndarray, pages: List[int], k_vt, v_vt,
               r_eff: int, n_pad: int = 0) -> None:
        """Register a freshly decomposed prompt.  Takes its own page refs
        on the full pages it covers; evicts LRU entries past capacity."""
        key = self._digest(padded)
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        bounds = list(self._boundaries(len(padded), n_pad))
        if not bounds:
            return                   # no boundary past padding + 1 page
        ent = PrefixEntry(tokens=np.array(padded, np.int32),
                          pages=list(pages[:bounds[-1] // self.page]),
                          k_vt=k_vt, v_vt=v_vt, r_eff=r_eff, n_pad=n_pad)
        self.alloc.ref(ent.pages)
        self._entries[key] = ent
        for ln in bounds:
            self._by_prefix[(ln, self._digest(padded[:ln]))] = ent
        while len(self._entries) > self.capacity:
            self._evict()

    def _evict(self) -> None:
        key, ent = self._entries.popitem(last=False)
        for ln in self._boundaries(len(ent.tokens), ent.n_pad):
            k = (ln, self._digest(ent.tokens[:ln]))
            if self._by_prefix.get(k) is ent:
                del self._by_prefix[k]
        self.alloc.release(ent.pages)
        self.evictions += 1
        # re-expose boundaries the evicted entry SHADOWED: an older live
        # entry sharing a prefix re-registers, so its pages don't sit
        # pinned-but-unreachable behind deleted keys
        for other in self._entries.values():
            for ln in self._boundaries(len(other.tokens), other.n_pad):
                k = (ln, self._digest(other.tokens[:ln]))
                self._by_prefix.setdefault(k, other)

    def drop_all(self) -> None:
        while self._entries:
            self._evict()


# ---------------------------------------------------------------------------
# Jitted paged step functions (lru-shared across engines, like serving's)
# ---------------------------------------------------------------------------

def _constrain(mesh):
    if mesh is None:
        return lambda c: c
    from ..distributed import sharding as sh
    return lambda c: sh.constrain_cache(c, mesh, seq_shard=False)


@functools.lru_cache(maxsize=None)
def _jitted_paged_decode(cfg, mesh=None):
    con = _constrain(mesh)

    def step(p, t, c, pos, fl, bt_u, bt_t, t_need, r_need, tail_len):
        lg, nc = DK.decode_step_dkv_paged(p, cfg, t, con(c), pos, fl,
                                          bt_u, bt_t, t_need, r_need,
                                          tail_len)
        return lg, con(nc)

    # the pools are rebound (pg.cache = …) at every call site, so the old
    # buffers can be donated into the update
    return jax.jit(step, static_argnums=(7, 8, 9), donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jitted_paged_decode_block(cfg, block: int, sampler, mesh=None):
    """Fused paged decode: gather the slot slab ONCE (block tables and the
    low-rank prefix are loop-invariant between folds), run up to ``block``
    sampled steps on the slab, scatter only the TAIL pages back at exit
    (the U pages / Vᵀ rows were read-only inside the loop)."""
    con = _constrain(mesh)

    def run(p, t, c, pos, fl, bt_u, bt_t, n, stops, key, r0,
            t_need, r_need, tail_len):
        buf, steps, done, nc = DK.decode_block_dkv_paged(
            p, cfg, t, con(c), pos, fl, bt_u, bt_t, n, stops, key, r0,
            t_need, r_need, tail_len, sampler=sampler, max_block=block)
        return buf, steps, done, con(nc)

    return jax.jit(run, static_argnums=(11, 12, 13), donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jitted_paged_fold(cfg, rank: int, mesh=None):
    con = _constrain(mesh)

    def fold(c, fl, fm, nf, bt_u, bt_new, bt_t, t_need, r_need, tail_len):
        return con(DK.compress_tail_paged(con(c), cfg, rank, fl, fm, nf,
                                          bt_u, bt_new, bt_t, t_need,
                                          r_need, tail_len))

    return jax.jit(fold, static_argnums=(7, 8, 9), donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jitted_paged_admit(mesh=None):
    """Write a fresh prefill into the pools: U rows into pages ``bt_u``,
    Vᵀ into the slot rows ``idx``, and ZERO the slots' tail pages (pages
    are recycled across requests; a fresh slot's tail must read zero)."""
    con = _constrain(mesh)

    def admit(c, k_u, v_u, k_vt, v_vt, bt_u, bt_t, idx, src):
        c = con(c)
        r = c["k_vt"].shape[2]
        pad = lambda a: a if a.shape[2] >= r else jnp.pad(
            a, ((0, 0), (0, 0), (0, r - a.shape[2]), (0, 0)))
        ztail = jnp.zeros((c["tail"]["k_pages"].shape[0], bt_t.shape[0],
                           bt_t.shape[1] * c["tail"]["k_pages"].shape[2])
                          + c["tail"]["k_pages"].shape[3:],
                          c["tail"]["k_pages"].dtype)
        return con({
            "k_u_pages": DK.write_prefix_pages(c["k_u_pages"], k_u, bt_u,
                                               src),
            "v_u_pages": DK.write_prefix_pages(c["v_u_pages"], v_u, bt_u,
                                               src),
            "k_vt": c["k_vt"].at[:, idx].set(
                pad(k_vt[:, src]).astype(c["k_vt"].dtype)),
            "v_vt": c["v_vt"].at[:, idx].set(
                pad(v_vt[:, src]).astype(c["v_vt"].dtype)),
            "tail": {
                "k_pages": DK.scatter_pages(c["tail"]["k_pages"], ztail,
                                            bt_t),
                "v_pages": DK.scatter_pages(c["tail"]["v_pages"], ztail,
                                            bt_t),
            },
        })

    return jax.jit(admit, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jitted_paged_suffix(cfg, mesh=None):
    """Prefix-cache hit admission: gather the entry's pages, run the
    tail-only suffix prefill, splice Vᵀ + tail rows into the pools."""
    con = _constrain(mesh)

    def hit(p, toks, c, ent_bt, k_vt, v_vt, start, slen, bt_t, idx, L,
            r_ent):
        c = con(c)
        prefix = {
            "k_u": DK.gather_pages(c["k_u_pages"], ent_bt, L)[..., :r_ent],
            "v_u": DK.gather_pages(c["v_u_pages"], ent_bt, L)[..., :r_ent],
            "k_vt": k_vt[:, :, :r_ent], "v_vt": v_vt[:, :, :r_ent],
        }
        tail_store = bt_t.shape[1] * c["tail"]["k_pages"].shape[2]
        logits, tails = DK.prefill_suffix_dkv(p, cfg, toks, prefix, start,
                                              slen, tail_store)
        r = c["k_vt"].shape[2]
        pad = lambda a: a if a.shape[2] >= r else jnp.pad(
            a, ((0, 0), (0, 0), (0, r - a.shape[2]), (0, 0)))
        return logits, con({
            "k_u_pages": c["k_u_pages"], "v_u_pages": c["v_u_pages"],
            "k_vt": c["k_vt"].at[:, idx].set(
                pad(k_vt).astype(c["k_vt"].dtype)),
            "v_vt": c["v_vt"].at[:, idx].set(
                pad(v_vt).astype(c["v_vt"].dtype)),
            "tail": {
                "k_pages": DK.scatter_pages(c["tail"]["k_pages"],
                                            tails["k"], bt_t),
                "v_pages": DK.scatter_pages(c["tail"]["v_pages"],
                                            tails["v"], bt_t),
            },
        })

    return jax.jit(hit, static_argnums=(10, 11), donate_argnums=(2,))


# ---------------------------------------------------------------------------
# Per-engine paged state
# ---------------------------------------------------------------------------

class PagedDKV:
    """Pools + block tables + allocators + slab-geometry mirror for one
    serving engine.  All bookkeeping is host-side python/numpy; device
    work happens only in the jitted functions above."""

    def __init__(self, cfg, *, slots: int, max_len: int, rank: int,
                 tail: int, page: int, pool_pages: int = 0,
                 prefix_capacity: int = 0, mesh=None):
        kvw = cfg.num_kv_heads * cfg.resolved_head_dim
        self.cfg, self.mesh = cfg, mesh
        self.page = max(1, page)
        self.rank = min(rank, kvw)
        self.tail = tail
        self.ntp = -(-tail // self.page)          # tail pages per slot
        per_slot = 2 * (-(-max_len // self.page))
        self.num_pages = pool_pages or slots * per_slot + 1
        self.num_tail_pages = slots * self.ntp + 1
        self.alloc = PageAllocator(self.num_pages)
        self.talloc = PageAllocator(self.num_tail_pages)
        self.cache = DK.init_paged_cache(cfg, slots, self.num_pages,
                                         self.page, self.rank,
                                         self.num_tail_pages)
        self.bt_u: List[List[int]] = [[] for _ in range(slots)]
        self.bt_t: List[List[int]] = [[] for _ in range(slots)]
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(prefix_capacity, self.page, self.alloc)
            if prefix_capacity else None)
        # host mirror of the slot engine's slab geometry — decode/fold
        # gathers slice to exactly these dims for bit-identical math
        self.slab_t = 0
        self.slab_r = 0
        self._decode = _jitted_paged_decode(cfg, mesh)
        self._fold = _jitted_paged_fold(cfg, self.rank, mesh)
        self._admit = _jitted_paged_admit(mesh)
        self._suffix = _jitted_paged_suffix(cfg, mesh)

    # -- block-table helpers ---------------------------------------------
    def pages_for(self, n_rows: int) -> int:
        return -(-max(0, n_rows) // self.page)

    def bt_array(self, lists: List[List[int]], width: int = 0) -> np.ndarray:
        width = width or max([len(p) for p in lists] + [1])
        a = np.full((len(lists), width), SINK, np.int32)
        for i, ps in enumerate(lists):
            a[i, :len(ps)] = ps
        return a

    def free_slot(self, slot: int) -> None:
        if self.bt_u[slot]:
            self.alloc.release(self.bt_u[slot])
            self.bt_u[slot] = []
        if self.bt_t[slot]:
            self.talloc.release(self.bt_t[slot])
            self.bt_t[slot] = []

    @property
    def pool_bytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.cache)
        return sum(x.size * x.dtype.itemsize for x in leaves)
