"""ServingFamily — the per-family protocol behind ``serving.Engine``.

PRs 2–8 built the production serving stack (per-slot continuous
batching, paged KV + prefix reuse, fused decode blocks, async prefill)
hardcoded to the transformer decomposed-KV family.  This module extracts
everything the engine used to special-case into one protocol, and the
engine dispatches EXCLUSIVELY through it (dcomlint rule F1 gates any
``cfg.family`` branch creeping back into ``serving/__init__.py``):

* cache lifecycle — :meth:`ServingFamily.alloc` (allocation + mesh
  placement + sharding specs), :meth:`~ServingFamily.free_slot`;
* admission — :meth:`~ServingFamily.reserve` (capacity check, paged
  prefix lookups), :meth:`~ServingFamily.dispatch` (per-slot splice
  admission as :class:`PrefillTicket`\\ s), :meth:`~ServingFamily.gang`
  (the legacy whole-batch policy), and the prefill-cost hook the
  :class:`~repro.serving.Scheduler` buckets on;
* decode — :meth:`~ServingFamily.decode` (single step) and
  :meth:`~ServingFamily.decode_block` (fused on-device loop);
* folds — :meth:`~ServingFamily.maybe_fold` / ``fold_horizon`` (no-ops
  for O(1)-state families: there is nothing to compress).

Registered families:

* ``transformer-dkv`` — the decomposed-KV path (slab or paged), byte-
  identical to the pre-protocol engine; selected whenever the engine is
  built with ``decompose_kv_rank``.
* ``dense`` — plain dense-KV transformer serving.  The only family whose
  gang admission may splice into a live cache (``gang_live_splice``).
* ``moe`` — dense KV serving with the expert-parallel ``moe_ffn`` path:
  routing/capacity live inside the model fns, and per-expert sharding
  comes from ``distributed.sharding``'s leaf rules under a mesh.  The
  serving engine never touches ``moe.SHARD_MAP_MESH`` — GSPMD partitions
  ``moe_ffn`` from the cache/param shardings alone.
* ``ssm`` (Mamba2) — conv_cache + ssm_state are fixed-size STATE SLOTS:
  no time axis, so folds are no-ops and a slot's memory never grows.
* ``hybrid`` (Zamba2-style) — composes per layer: attention layers carry
  sliced KV, mamba layers carry state slots; ``api.cache_batch_axes``
  probes each leaf's slot axis so one splice path serves the mixed tree.
* ``vlm`` / ``audio`` — dense-KV serving whose prefill carries extra
  modality inputs (``ModelFns.prefill_inputs``) and whose admission cost
  exceeds the token count (image tokens / encoder frames) — reflected in
  :meth:`~ServingFamily.prefill_cost` so scheduler bucketing tracks
  actual prefill work.

All mutable serving state (``cache``, ``pager``, ``pos``,
``frozen_len``, ``rank_eff``, ``live``) stays on the Engine — families
are stateless strategy objects holding only jitted callables, so tests
and benchmarks keep poking engine attributes directly.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..engine import DecomposeEngine, EngineConfig
from ..models import api
from ..obs import phase_scope

Array = jax.Array


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


@dataclasses.dataclass
class PrefillTicket:
    """One in-flight admission launch (the prefill side of the P/D split).

    Created at DISPATCH time: the prefill (forward + Lanczos, or a
    prefix-hit suffix pass) has been launched on device, the target slots
    are reserved, and — paged mode — the pages are already allocated and
    the prefix-hit refs held, so nothing the decode loop does during the
    async window can invalidate the launch.  ``probe`` is the result tree
    (``api.tree_ready`` gives a non-blocking done check); ``complete``
    materializes the results (splice + first-token sample — the only
    blocking point) and ``cancel`` unwinds the reservation (slots free,
    pages/refs release) without ever blocking on the device.
    """
    requests: List[Any]
    slots: List[int]
    plen: int
    probe: Any                       # pytree of in-flight jax arrays
    complete: Callable               # () -> (first_tokens, frozen_lens)
    cancel: Callable                 # () -> None (release pages/refs)
    t_dispatch: float = 0.0
    span: Any = None                 # obs.Span on the "tickets" track

    def ready(self) -> bool:
        return api.tree_ready(self.probe)


def _constrain(mesh):
    """Cache-tree ``with_sharding_constraint`` closure for the jitted step
    fns (identity when ``mesh`` is None — the single-device path traces the
    exact pre-mesh graph).  ``seq_shard=False``: the batch-1 time-axis
    ("flash-decoding") rule is for global-batch-1 long-context decode, not
    serving — a freshly prefilled single-request cache must stay replicated
    until spliced, not bounce through a sequence reshard per admission."""
    if mesh is None:
        return lambda c: c
    from ..distributed import sharding as sh
    return lambda c: sh.constrain_cache(c, mesh, seq_shard=False)


# ---------------------------------------------------------------------------
# Jitted step builders (lru-shared across Engine instances)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_steps(fns: api.ModelFns, cfg: ArchConfig, max_len: int,
                  mesh=None):
    """Jitted (decode, prefill) shared across Engine instances of the same
    (config, mesh) — XLA executables are reused instead of re-traced per
    engine.  Under a mesh both the incoming and outgoing cache trees are
    sharding-constrained to ``distributed.sharding.cache_pspec``, so GSPMD
    keeps every per-slot update device-local along the batch axis.  The
    decode cache is DONATED: the engine rebinds ``self.cache`` at the call
    site, so the update writes in place."""
    con = _constrain(mesh)

    def decode(p, t, c, pos):
        lg, nc = fns.decode_step(p, cfg, t, con(c), pos)
        return lg, con(nc)

    def prefill(p, *a):
        lg, c = fns.prefill(p, cfg, *a, max_len)
        return lg, con(c)

    return jax.jit(decode, donate_argnums=(2,)), jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _jitted_dkv_decode(cfg: ArchConfig, mesh=None):
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)

    def step(p, t, c, pos, fl):
        lg, nc = DK.decode_step_dkv(p, cfg, t, con(c), pos, frozen_len=fl)
        return lg, con(nc)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jitted_decode_block(fns: api.ModelFns, cfg: ArchConfig, block: int,
                         sampler, mesh=None):
    """Fused decode block for ANY family (dense path included): ``block``
    is the static loop bound, the actual step count per call is traced.
    lru-keyed on (fns, cfg, block, sampler, mesh) so equivalently
    configured engines share one executable; the cache carry is donated."""
    con = _constrain(mesh)

    def run(p, t, c, pos, n, stops, key, r0):
        step = lambda tk, cc, ps: fns.decode_step(p, cfg, tk, cc, ps)
        buf, steps, done, nc = api.run_decode_block(
            step, sampler, block, t, con(c), pos, n, stops, key, r0)
        return buf, steps, done, con(nc)

    return jax.jit(run, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jitted_dkv_decode_block(cfg: ArchConfig, block: int, sampler,
                             mesh=None):
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)

    def run(p, t, c, pos, fl, n, stops, key, r0):
        buf, steps, done, nc = DK.decode_block_dkv(
            p, cfg, t, con(c), pos, fl, n, stops, key, r0,
            sampler=sampler, max_block=block)
        return buf, steps, done, con(nc)

    return jax.jit(run, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _jitted_dkv_prefill(cfg: ArchConfig, backend: str, expansion: int,
                        rank: int, tail: int, iters_extra: int,
                        exact: bool, mesh=None):
    """Jitted decomposed-KV prefill (forward + Lanczos/SVD factorization in
    ONE compiled program — ~100× over the eager path on small configs).
    Keyed on the decomposition-relevant engine knobs so equivalently
    configured serving engines share executables.  With a mesh the inner
    DecomposeEngine runs the factorization DP-sharded over the
    layers×batch axis and the fresh cache comes out sharding-constrained."""
    from ..models import decomposed_kv as DK
    eng = DecomposeEngine(EngineConfig(
        backend=backend, expansion=expansion, kv_rank=rank, kv_tail=tail,
        kv_iters_extra=iters_extra, mesh=mesh))
    con = _constrain(mesh)

    def prefill(p, tk):
        lg, c = DK.prefill_dkv(p, cfg, tk, rank, tail=tail, exact=exact,
                               engine=eng)
        return lg, con(c)

    return jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _jitted_dkv_compress(cfg: ArchConfig, rank: int, mesh=None):
    # The incoming cache is donated: a fold GROWS the time axis, so only
    # the same-shaped leaves (tail, factors) alias — the rest is the
    # "not usable" warning filtered at serving import.
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)
    return jax.jit(lambda c, fl, fm, nf: con(DK.compress_tail(
        con(c), cfg, rank, frozen_len=fl, fold=fm, new_frozen=nf)),
        donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jitted_splices(mesh=None):
    """Jitted cache-splice kernels (slot/src index vectors are traced, so
    one executable serves every admission with the same shape profile).
    The LIVE side keeps its batch sharding — and is donated, since every
    call site rebinds the engine cache to the splice result; the fresh
    side is typically smaller than the slot batch and stays wherever
    prefill left it."""
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)
    dkv = jax.jit(lambda live, fresh, idx, src:
                  con(DK.splice_dkv(con(live), fresh, idx, src)),
                  donate_argnums=(0,))
    fam = jax.jit(lambda old, new, idx, src, cfg:
                  con(api.splice_cache(cfg, con(old), new, idx, src)),
                  static_argnums=(4,), donate_argnums=(0,))
    return dkv, fam


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, type] = {}


def register_family(*names):
    """Class decorator registering a ServingFamily under one or more
    ``cfg.family`` keys (plus the synthetic ``transformer-dkv`` key the
    engine selects when ``decompose_kv_rank`` is set)."""
    def deco(cls):
        for n in names:
            if n in _REGISTRY:
                raise ValueError(f"serving family {n!r} already registered")
            _REGISTRY[n] = cls
        cls.names = names
        return cls
    return deco


def family_names() -> List[str]:
    return sorted(_REGISTRY)


def serving_family(eng, paged: bool = False) -> "ServingFamily":
    """Resolve the engine's ServingFamily: ``decompose_kv_rank`` selects
    the transformer-dkv path, otherwise the model config's family key."""
    key = "transformer-dkv" if eng.dkv_rank else eng.cfg.family
    cls = _REGISTRY.get(key)
    if cls is None:
        raise ValueError(f"no ServingFamily registered for {key!r} "
                         f"(have {family_names()})")
    return cls(eng, paged=paged)


# ---------------------------------------------------------------------------
# Base protocol = generic dense-cache slab serving
# ---------------------------------------------------------------------------

class ServingFamily:
    """Per-family serving strategy.  The base class IS the generic
    dense-cache slab path: ``init_cache`` slab keyed on each leaf's probed
    batch axis, ``ModelFns``-driven prefill/decode/fused-block builders,
    ``api.splice_cache`` admission, no folds, no pager.  Families override
    only what differs; all mutable arrays live on ``self.eng``.
    """

    #: gang admission may splice into a cache with live slots.  True only
    #: for the plain dense-KV family (the legacy policy's one safe case);
    #: every other family gangs only on an all-free engine.
    gang_live_splice = False
    #: family supports ``Engine(paged=True)``
    paged_capable = False

    def __init__(self, eng, paged: bool = False):
        assert not paged or self.paged_capable, \
            "paged serving runs on the decomposed KV cache (set " \
            "decompose_kv_rank / kv_rank)"
        self.eng = eng
        self._decode, self._prefill = _jitted_steps(
            eng.fns, eng.cfg, eng.max_len, eng.mesh)
        _, self._splice_fam = _jitted_splices(eng.mesh)

    # -- cache lifecycle -------------------------------------------------
    def alloc(self):
        """Build (and mesh-place) the engine's slot cache; None defers
        allocation to the first prefill (shape depends on its result)."""
        eng = self.eng
        return eng._place(eng.fns.init_cache(eng.cfg, eng.slots,
                                             eng.max_len))

    def free_slot(self, slot: int) -> None:
        """Release per-slot resources (paged block tables) on finish."""

    def frozen_after_prefill(self, n: int, plen: int) -> np.ndarray:
        """Per-slot frozen_len right after a prefill of ``plen`` rows."""
        return np.zeros(n, np.int32)

    # -- scheduling ------------------------------------------------------
    def prefill_cost(self, req) -> int:
        """Admission cost the scheduler buckets on.  Token count by
        default; modality families add their fixed extra prefill work."""
        return len(req.prompt)

    def tune_horizon(self) -> int:
        """Decode horizon for the ``decode_block="auto"`` cost model."""
        return self.eng.max_len

    def block_cap(self) -> Optional[int]:
        """Hard upper bound on the fused block length (None = uncapped)."""
        return None

    def fold_horizon(self) -> Optional[int]:
        """Steps until some live slot must fold (None = never folds)."""
        return None

    # -- admission -------------------------------------------------------
    def reserve(self, batch: List[Any], plen: int):
        """Capacity check before dispatch.  Returns an opaque non-None
        context handed to :meth:`dispatch` on success, or None to defer
        the batch (engine requeues it and counts a stall)."""
        return True

    def capacity_msg(self, head) -> str:
        """Diagnostic for a deferral that can never unblock."""
        return (f"request uid={head.uid} (prompt {len(head.prompt)} "
                f"tokens) is blocked on serving capacity with no "
                f"in-flight work to free resources")

    def dispatch(self, batch: List[Any], slots_idx: List[int], plen: int,
                 ctx) -> List[PrefillTicket]:
        """Launch the prefill for one admission batch (batch padded to a
        power of two so compile count stays O(log slots × max_len/bucket))
        and return its tickets.  The prefill is in flight the moment this
        returns; the cache splice and first-token sample happen in
        ``complete()`` (ready-pool splice for async, immediately for
        sync)."""
        eng = self.eng
        nb = min(_pow2(len(batch)), max(eng.slots, 1))
        toks = eng._toks(batch, nb, plen, lambda j: j)
        args = eng.fns.prefill_inputs(eng.cfg, jnp.asarray(toks), jnp.zeros)
        logits, fresh = self._prefill(eng.params, *args)
        eng.stats.prefill_batches += 1

        def complete():
            idx = np.asarray(slots_idx, np.int32)
            src = np.arange(len(slots_idx), dtype=np.int32)
            eng.cache = self._splice_fam(eng.cache, fresh, idx, src,
                                         eng.cfg)
            nxt = eng._sample_host(logits, stream=1)[:len(batch)]
            return nxt, np.zeros(len(batch), np.int32)

        return [PrefillTicket(requests=list(batch), slots=list(slots_idx),
                              plen=plen, probe=(logits, fresh),
                              complete=complete, cancel=lambda: None,
                              t_dispatch=time.perf_counter())]

    def gang(self, batch: List[Any], slots_idx: List[int], plen: int,
             has_live: bool) -> Array:
        """Legacy admission: prefill the WHOLE slot batch (idle and live
        slots compute padding), splice rows into a live cache when the
        family supports it, replace the cache wholesale otherwise (all
        slots are free by the gang restriction)."""
        eng = self.eng
        toks = eng._toks(batch, eng.slots, plen, lambda j: slots_idx[j])
        args = eng.fns.prefill_inputs(eng.cfg, jnp.asarray(toks), jnp.zeros)
        logits, cache = self._prefill(eng.params, *args)
        if has_live:
            idx = np.asarray(slots_idx, np.int32)
            cache = self._splice_fam(eng.cache, cache, idx, idx, eng.cfg)
        eng.cache = cache
        return logits

    # -- decode ----------------------------------------------------------
    def decode(self, tok: np.ndarray) -> Array:
        """One single-token decode step over every slot; rebinds the
        engine cache and returns the logits (sampling stays host-side in
        ``Engine._sample_host`` — the one sanctioned sync)."""
        eng = self.eng
        logits, eng.cache = self._decode(eng.params, jnp.asarray(tok),
                                         eng.cache, jnp.asarray(eng.pos))
        return logits

    def decode_block(self, tok: np.ndarray, n, stops, key, r0):
        """Fused decode: up to ``eng.decode_block`` sampled steps in one
        jitted on-device loop.  Returns ``(token_buf, steps_done)``."""
        eng = self.eng
        fn = _jitted_decode_block(eng.fns, eng.cfg, eng.decode_block,
                                  eng.sampler, eng.mesh)
        buf, steps, _, eng.cache = fn(eng.params, jnp.asarray(tok),
                                      eng.cache, jnp.asarray(eng.pos),
                                      n, stops, key, r0)
        return buf, steps

    # -- folds -----------------------------------------------------------
    def maybe_fold(self) -> None:
        """Tail-fold check at a decode/block boundary (no-op unless the
        family compresses a growing cache)."""


# ---------------------------------------------------------------------------
# Concrete families
# ---------------------------------------------------------------------------

@register_family("dense")
class DenseKVServing(ServingFamily):
    """Plain dense-KV transformer serving — the base path unmodified,
    plus the one legacy privilege: gang admission may splice into a live
    cache (row-wise splice-merge has always existed for dense KV)."""
    gang_live_splice = True


@register_family("moe")
class MoEServing(ServingFamily):
    """Mixture-of-experts serving on the dense-KV slab.

    The KV cache is the transformer's (attention is dense); what differs
    is the FFN — ``moe.moe_ffn`` routes top-k per token with a capacity
    buffer.  Routing state is recomputed per step from the hidden states,
    so there is nothing extra to splice: admission, fused blocks, and
    async prefill all ride the base path.  Under a mesh, per-expert
    sharding comes from ``distributed.sharding``'s param rules; the
    engine deliberately leaves ``moe.SHARD_MAP_MESH`` alone so GSPMD
    partitions the expert einsums from the declared shardings (the
    shard_map path is the training/dryrun A/B, not serving).

    Caveat inherited from ``moe_ffn``: expert capacity
    (``ceil(tokens·top_k·cf / num_experts)``) makes token DROPS depend on
    the batch composition — dead-slot padding rows can steal capacity
    from live rows.  Serving conformance therefore pins configs where
    capacity never binds (see tests/test_serving_conformance.py); under
    a binding capacity factor, batched decode is a quality/throughput
    trade, not an exactness bug.
    """


@register_family("ssm")
class Mamba2Serving(ServingFamily):
    """Mamba2/SSM serving: the "cache" is O(1) per slot — conv window
    ``[nl, B, w−1, ch]`` + SSM state ``[nl, B, nh, hd, ds]`` — a STATE
    SLOT with no time axis.  ``pos`` still advances (budget bookkeeping)
    but never indexes device state; folds are no-ops (nothing grows);
    splice admission scatters whole state rows.  Decode cost is constant
    in sequence length, so the fused block is capped only by budget and
    admission horizons."""


@register_family("hybrid")
class HybridServing(ServingFamily):
    """Hybrid (Zamba2-style) serving composes per LAYER: attention
    layers carry sliced KV ``[g, mpg, B, T, kvh, hd]``, mamba layers
    carry state slots — one pytree, mixed leaf kinds.  The generic path
    already handles it: ``api.cache_batch_axes`` probes each leaf's slot
    axis for splicing, and ``distributed.sharding``'s suffix-relative
    leaf rules shard conv/ssm/KV leaves consistently under a mesh."""


@register_family("vlm")
class VLMServing(ServingFamily):
    """Vision-language serving: prefill consumes the image-embedding
    block alongside the tokens (``ModelFns.prefill_inputs``), and every
    admission pays ``num_image_tokens`` of extra attention work — so the
    scheduler buckets on tokens + image tokens, not prompt length."""

    def prefill_cost(self, req) -> int:
        return len(req.prompt) + self.eng.cfg.num_image_tokens


@register_family("audio")
class AudioServing(ServingFamily):
    """Audio encoder-decoder serving: prefill runs the encoder over
    ``num_audio_frames`` frames (the cross-KV cache contract) before the
    decoder touches a token, so admission cost is tokens + frames."""

    def prefill_cost(self, req) -> int:
        return len(req.prompt) + self.eng.cfg.num_audio_frames


@register_family("transformer-dkv")
class TransformerDKVServing(ServingFamily):
    """The paper's low-rank decomposed-KV serving path (dense family
    only): prefill decomposes K/V through the DecomposeEngine, decode
    contracts through the factors, per-slot dense tails fold back via
    ``compress_tail``, and ``paged=True`` swaps the slab for
    ``serving.paged``'s page pools + prefix cache.  Byte-identical to
    the pre-protocol engine — every method here is the old engine code
    moved behind the protocol."""
    paged_capable = True

    def __init__(self, eng, paged: bool = False):
        assert eng.cfg.family == "dense", "decomposed KV: dense family"
        self.eng = eng
        ec = eng.dengine.config
        self._decode_dkv = _jitted_dkv_decode(eng.cfg, eng.mesh)
        self._prefill_dkv = _jitted_dkv_prefill(
            eng.cfg, ec.backend, ec.expansion, eng.dkv_rank, eng.dkv_tail,
            ec.kv_iters_extra, eng.dkv_exact, eng.mesh)
        self._compress_dkv = _jitted_dkv_compress(eng.cfg, eng.dkv_rank,
                                                  eng.mesh)
        self._splice_dkv, _ = _jitted_splices(eng.mesh)
        if paged:
            assert eng.admission == "per_slot", "paged serving is per-slot"
            from .paged import PagedDKV
            eng.pager = PagedDKV(
                eng.cfg, slots=eng.slots, max_len=eng.max_len,
                rank=eng.dkv_rank, tail=eng.dkv_tail, page=ec.kv_page,
                pool_pages=ec.kv_pool_pages,
                prefix_capacity=ec.kv_prefix_cache, mesh=eng.mesh)
            if eng.mesh is not None:
                eng.pager.cache = eng._place(eng.pager.cache)

    # -- cache lifecycle -------------------------------------------------
    def alloc(self):
        return None                  # built at first prefill

    def free_slot(self, slot: int) -> None:
        if self.eng.pager is not None:
            self.eng.pager.free_slot(slot)

    def frozen_after_prefill(self, n: int, plen: int) -> np.ndarray:
        return np.full(n, plen, np.int32)

    # -- scheduling ------------------------------------------------------
    def tune_horizon(self) -> int:
        return self.eng.dkv_tail

    def block_cap(self) -> Optional[int]:
        # fold cadence bounds every block — don't trace a longer loop
        return self.eng.dkv_tail

    def fold_horizon(self) -> Optional[int]:
        eng = self.eng
        occ = max(int(eng.pos[i] - eng.frozen_len[i])
                  for i, r in enumerate(eng.live) if r is not None)
        return eng.dkv_tail - occ

    # -- admission -------------------------------------------------------
    def reserve(self, batch: List[Any], plen: int):
        eng = self.eng
        if eng.pager is None:
            return True
        # prefix lookups FIRST (page refs taken per hit), so the
        # reservation below only counts the MISSES' pages and its
        # evictions can never invalidate this batch's hits
        looks = self._lookup_prefixes(batch, plen)
        n_miss = sum(1 for g in looks if g is None)
        if not self._reserve_pages(n_miss, len(batch), plen):
            # page pool can't take this batch yet — release the hit refs
            # taken above (exactly once: they were never installed
            # anywhere) and let the engine requeue + stall
            for got in looks:
                if got is not None:
                    eng.pager.alloc.release(got[2])
            return None
        return looks

    def capacity_msg(self, head) -> str:
        pg = self.eng.pager
        return (f"request uid={head.uid} (prompt {len(head.prompt)} tokens)"
                f" is blocked on page capacity with no in-flight work to "
                f"free pages — raise kv_pool_pages (pool: "
                f"{pg.num_pages} U pages / "
                f"{pg.num_tail_pages} tail pages) or lower the "
                f"prompt length / admission batch")

    def _lookup_prefixes(self, batch: List[Any], plen: int) -> list:
        """Prefix-cache lookups for one admission batch.  Each hit's
        shared page refs are taken IMMEDIATELY — before any reservation
        eviction or same-batch miss insertion can release them — and
        handed to ``_dispatch_paged`` (or dropped on deferral).  Lookups
        run unrecorded (``record=False``): hit/miss stats are counted at
        DISPATCH, exactly once per admitted request, so defer/retry
        cycles can no longer inflate them (each retry used to re-count
        the same request)."""
        eng = self.eng
        pg = eng.pager
        out: list = []
        for req in batch:
            got = None
            if pg.prefix is not None:
                pad = plen - len(req.prompt)
                padded = np.zeros(plen, np.int32)
                padded[pad:] = req.prompt
                found = pg.prefix.lookup(padded, eng.dkv_tail, pad,
                                         record=False)
                if found is not None:
                    ent, match_len = found
                    share = ent.pages[:match_len // pg.page]
                    pg.alloc.ref(share)
                    got = (ent, match_len, share)
            out.append(got)
        return out

    def _reserve_pages(self, n_miss: int, n_req: int, plen: int) -> bool:
        """Can the pools take this batch (``n_miss`` full prefills plus a
        tail per request)?  Evicts prefix-cache entries LRU-first if that
        frees enough — hits are unaffected, they already hold refs."""
        pg = self.eng.pager
        need_u = n_miss * pg.pages_for(plen)
        need_t = n_req * pg.ntp
        while pg.alloc.free_pages < need_u and pg.prefix is not None \
                and len(pg.prefix):
            pg.prefix._evict()
        return pg.alloc.free_pages >= need_u \
            and pg.talloc.free_pages >= need_t

    def dispatch(self, batch: List[Any], slots_idx: List[int], plen: int,
                 ctx) -> List[PrefillTicket]:
        if self.eng.pager is not None:
            looks = ctx if isinstance(ctx, list) else None
            return self._dispatch_paged(batch, slots_idx, plen, looks)
        return [self._dispatch_slab(batch, slots_idx, plen)]

    def _dispatch_slab(self, batch: List[Any], slots_idx: List[int],
                       plen: int) -> PrefillTicket:
        """Launch the slab-path dkv prefill (Lanczos included) for one
        admission batch and return its ticket."""
        eng = self.eng
        nb = min(_pow2(len(batch)), max(eng.slots, 1))
        toks = eng._toks(batch, nb, plen, lambda j: j)
        logits, fresh = self._prefill_dkv(eng.params, jnp.asarray(toks))
        eng.stats.prefill_batches += 1

        def complete():
            from ..models import decomposed_kv as DK
            idx = np.asarray(slots_idx, np.int32)
            src = np.arange(len(slots_idx), dtype=np.int32)
            if eng.cache is None:
                eng.cache = eng._place(DK.init_cache(
                    eng.cfg, eng.slots, fresh["k_u"].shape[2],
                    fresh["k_u"].shape[-1], tail=eng.dkv_tail))
            eng.cache = self._splice_dkv(eng.cache, fresh, idx, src)
            eng.rank_eff[slots_idx] = fresh["k_u"].shape[-1]
            nxt = eng._sample_host(logits, stream=1)[:len(batch)]
            return nxt, np.full(len(batch), plen, np.int32)

        return PrefillTicket(requests=list(batch), slots=list(slots_idx),
                             plen=plen, probe=(logits, fresh),
                             complete=complete, cancel=lambda: None,
                             t_dispatch=time.perf_counter())

    def _dispatch_paged(self, batch: List[Any], slots_idx: List[int],
                        plen: int,
                        looks: Optional[list]) -> List[PrefillTicket]:
        """Paged admission dispatch: the precomputed prefix lookups
        (``looks``, from ``_lookup_prefixes`` — hit page refs already
        taken) split the batch into HITS (tail-only suffix prefill over
        refcounted shared pages — no prefix forward pass, no Lanczos) and
        MISSES (the slot engine's exact prefill path — same jitted fn,
        same pow2 batch padding, so the factors are bit-identical).  One
        ticket per hit group plus one for the misses; all pages are
        allocated and installed in the slot block tables HERE, at
        dispatch, so the reservation holds across the async window and
        ``free_slot`` on cancellation releases everything (shared prefix
        refs exactly once).  Device-side the launch order — suffix chains
        on the pool cache, then the miss scatter — is identical to the
        pre-split engine; only the host-side sample/bookkeeping moves
        into ``complete()``."""
        eng = self.eng
        pg = eng.pager
        n = len(batch)
        padded = eng._toks(batch, n, plen, lambda j: j)
        hits: dict = {}            # (L, r_eff) -> [(j, entry, share), ...]
        misses: List[int] = []
        for j in range(n):
            got = looks[j] if looks is not None else None
            if got is not None:
                ent, match_len, share = got
                hits.setdefault((match_len, ent.r_eff),
                                []).append((j, ent, share))
            else:
                misses.append(j)
        if pg.prefix is not None:
            # counted once per ADMITTED request, here at dispatch — the
            # lookups themselves ran record=False, so a defer/retry cycle
            # no longer double-counts (engine stats and cache counters)
            nh = n - len(misses)
            eng.stats.prefix_hits += nh
            eng.stats.prefix_misses += len(misses)
            pg.prefix.hits += nh
            pg.prefix.misses += len(misses)

        tickets: List[PrefillTicket] = []
        # hits first: they only consume tail pages, and their factor
        # pages already carry this batch's refs
        for (match_len, r_ent), group in sorted(hits.items()):
            tickets.append(self._dispatch_paged_hits(
                batch, slots_idx, plen, padded, match_len, r_ent, group))
        if misses:
            tickets.append(self._dispatch_paged_miss(
                batch, slots_idx, plen, padded, misses))
        return tickets

    def _dispatch_paged_hits(self, batch: List[Any],
                             slots_idx: List[int], plen: int,
                             padded: np.ndarray, match_len: int,
                             r_ent: int, group: list) -> PrefillTicket:
        eng = self.eng
        pg = eng.pager
        m = len(group)
        stoks = np.zeros((m, plen - match_len), np.int32)
        ent_bt, bt_t, idx = [], [], []
        reqs: List[Any] = []
        slots_l: List[int] = []
        shares: List[list] = []
        for gi, (j, ent, share) in enumerate(group):
            slot = slots_idx[j]
            stoks[gi] = padded[j][match_len:]
            tpages = pg.talloc.alloc(pg.ntp)
            assert tpages is not None, "tail pages after _reserve_pages"
            ent_bt.append(share)
            shares.append(list(share))
            bt_t.append(tpages)
            idx.append(slot)
            reqs.append(batch[j])
            slots_l.append(slot)
        k_vt = jnp.stack([ent.k_vt for _, ent, _ in group], axis=1)
        v_vt = jnp.stack([ent.v_vt for _, ent, _ in group], axis=1)
        start = np.full(m, match_len, np.int32)
        slen = np.full(m, plen - match_len, np.int32)
        logits, pg.cache = pg._suffix(
            eng.params, jnp.asarray(stoks), pg.cache,
            np.asarray(ent_bt, np.int32), k_vt, v_vt,
            jnp.asarray(start), jnp.asarray(slen),
            np.asarray(bt_t, np.int32), np.asarray(idx, np.int32),
            match_len, r_ent)
        eng.stats.prefill_batches += 1

        def complete():
            # install the block tables only NOW: while the ticket was in
            # flight the slot's bt rows stayed empty (SINK-padded in
            # bt_array), so intervening decode launches scattered their
            # dead-row writes into the sink page instead of the suffix
            # tail pages written at dispatch.  The shared-prefix ref from
            # _lookup_prefixes transfers to the slot here; free_slot
            # releases it exactly once.
            for gi, slot in enumerate(slots_l):
                pg.bt_u[slot], pg.bt_t[slot] = shares[gi], bt_t[gi]
                eng.rank_eff[slot] = r_ent
            nxt = eng._sample_host(logits, stream=1)[:m]
            pg.slab_t = max(pg.slab_t, match_len)
            pg.slab_r = max(pg.slab_r, r_ent)
            return nxt, np.full(m, match_len, np.int32)

        def cancel():
            # nothing was installed in the slot block tables yet, so the
            # lookup's shared ref and the fresh tail pages are released
            # directly (exactly once each)
            for gi in range(m):
                pg.alloc.release(shares[gi])
                pg.talloc.release(bt_t[gi])

        return PrefillTicket(requests=reqs, slots=slots_l, plen=plen,
                             probe=logits, complete=complete,
                             cancel=cancel,
                             t_dispatch=time.perf_counter())

    def _dispatch_paged_miss(self, batch: List[Any],
                             slots_idx: List[int], plen: int,
                             padded: np.ndarray,
                             misses: List[int]) -> PrefillTicket:
        eng = self.eng
        pg = eng.pager
        nb = min(_pow2(len(misses)), max(eng.slots, 1))
        mtoks = np.zeros((nb, plen), np.int32)
        for mi, j in enumerate(misses):
            mtoks[mi] = padded[j]
        logits, fresh = self._prefill_dkv(eng.params, jnp.asarray(mtoks))
        eng.stats.prefill_batches += 1
        npg = pg.pages_for(plen)
        bt_u, bt_t, idx = [], [], []
        reqs: List[Any] = []
        slots_l: List[int] = []
        for j in misses:
            slot = slots_idx[j]
            pages = pg.alloc.alloc(npg)
            tpages = pg.talloc.alloc(pg.ntp)
            assert pages is not None and tpages is not None, \
                "page reservation failed after _reserve_pages"
            bt_u.append(pages)
            bt_t.append(tpages)
            idx.append(slot)
            reqs.append(batch[j])
            slots_l.append(slot)
        pads = [plen - len(batch[j].prompt) for j in misses]
        rows = [padded[j].copy() for j in misses]

        def complete():
            # block tables are installed only now (see the hit-path note:
            # bt rows stay SINK during the async window so dead-row decode
            # writes can't touch the reserved pages); the _admit scatter
            # below chains device-side AFTER any intervening decode, so it
            # owns the final contents of every factor/tail page
            r_eff = fresh["k_u"].shape[-1]
            src = np.arange(len(misses), dtype=np.int32)
            pg.cache = pg._admit(pg.cache, fresh["k_u"], fresh["v_u"],
                                 fresh["k_vt"], fresh["v_vt"],
                                 np.asarray(bt_u, np.int32),
                                 np.asarray(bt_t, np.int32),
                                 np.asarray(idx, np.int32), src)
            for mi, slot in enumerate(slots_l):
                pg.bt_u[slot], pg.bt_t[slot] = bt_u[mi], bt_t[mi]
                eng.rank_eff[slot] = r_eff
            nxt = eng._sample_host(logits, stream=1)[:len(misses)]
            pg.slab_t = max(pg.slab_t, plen)
            pg.slab_r = max(pg.slab_r, r_eff)
            if pg.prefix is not None:
                for mi, slot in enumerate(slots_l):
                    pg.prefix.insert(rows[mi], pg.bt_u[slot],
                                     fresh["k_vt"][:, mi],
                                     fresh["v_vt"][:, mi], r_eff,
                                     n_pad=pads[mi])
            return nxt, np.full(len(misses), plen, np.int32)

        def cancel():
            for mi in range(len(misses)):
                pg.alloc.release(bt_u[mi])
                pg.talloc.release(bt_t[mi])

        return PrefillTicket(requests=reqs, slots=slots_l, plen=plen,
                             probe=(logits, fresh), complete=complete,
                             cancel=cancel,
                             t_dispatch=time.perf_counter())

    def gang(self, batch: List[Any], slots_idx: List[int], plen: int,
             has_live: bool) -> Array:
        eng = self.eng
        toks = eng._toks(batch, eng.slots, plen, lambda j: slots_idx[j])
        logits, eng.cache = self._prefill_dkv(eng.params,
                                              jnp.asarray(toks))
        eng.rank_eff[slots_idx] = eng.cache["k_u"].shape[-1]
        return logits

    # -- decode ----------------------------------------------------------
    def decode(self, tok: np.ndarray) -> Array:
        eng = self.eng
        if eng.pager is not None:
            pg = eng.pager
            logits, pg.cache = pg._decode(
                eng.params, jnp.asarray(tok), pg.cache,
                jnp.asarray(eng.pos),
                jnp.asarray(eng.frozen_len),
                jnp.asarray(pg.bt_array(pg.bt_u)),
                jnp.asarray(pg.bt_array(pg.bt_t, pg.ntp)),
                pg.slab_t, pg.slab_r, eng.dkv_tail)
            return logits
        logits, eng.cache = self._decode_dkv(
            eng.params, jnp.asarray(tok), eng.cache,
            jnp.asarray(eng.pos),
            jnp.asarray(eng.frozen_len))
        return logits

    def decode_block(self, tok: np.ndarray, n, stops, key, r0):
        eng = self.eng
        if eng.pager is not None:
            pg = eng.pager
            from .paged import _jitted_paged_decode_block
            fn = _jitted_paged_decode_block(eng.cfg, eng.decode_block,
                                            eng.sampler, eng.mesh)
            buf, steps, _, pg.cache = fn(
                eng.params, jnp.asarray(tok), pg.cache,
                jnp.asarray(eng.pos), jnp.asarray(eng.frozen_len),
                jnp.asarray(pg.bt_array(pg.bt_u)),
                jnp.asarray(pg.bt_array(pg.bt_t, pg.ntp)),
                n, stops, key, r0, pg.slab_t, pg.slab_r, eng.dkv_tail)
            return buf, steps
        fn = _jitted_dkv_decode_block(eng.cfg, eng.decode_block,
                                      eng.sampler, eng.mesh)
        buf, steps, _, eng.cache = fn(
            eng.params, jnp.asarray(tok), eng.cache,
            jnp.asarray(eng.pos), jnp.asarray(eng.frozen_len),
            n, stops, key, r0)
        return buf, steps

    # -- folds -----------------------------------------------------------
    def maybe_fold(self) -> None:
        """Tail-fold check at a decode/block boundary (decomposed KV)."""
        eng = self.eng
        live_m = np.array([r is not None for r in eng.live])
        occ = eng.pos - eng.frozen_len
        must = live_m & (occ >= eng.dkv_tail)
        if must.any():
            # a slot's tail is full — fold it, and opportunistically
            # co-fold every live slot at least half full: co-folded
            # slots restart at occupancy 0 together, re-synchronizing
            # fold cadence under staggered admissions (fold ≈ one
            # event per TAIL decode rounds instead of one per slot).
            # A co-folded slot's unused tail rows are zeros and fold
            # as zero rows — exactness is unaffected.
            fold = must | (live_m & (occ >= max(1, eng.dkv_tail // 2)))
            with eng.trace.span("fold", "engine",
                                {"slots": int(fold.sum())}), \
                    phase_scope("fold"):
                if eng.pager is not None:
                    self._fold_slots_paged(live_m, must, fold)
                else:
                    self._fold_slots(live_m, fold)

    def _fold_slots(self, live_m: np.ndarray, fold: np.ndarray) -> None:
        """Per-slot tail fold on the SLAB cache (non-paged path)."""
        from ..models import decomposed_kv as DK
        eng = self.eng
        r_in = int(eng.cache["k_u"].shape[-1])
        t_frozen = int(eng.cache["k_u"].shape[2])
        new_frozen = np.where(fold, eng.pos,
                              eng.frozen_len).astype(np.int32)
        eng.cache = self._compress_dkv(eng.cache,
                                       jnp.asarray(eng.frozen_len),
                                       jnp.asarray(fold),
                                       jnp.asarray(new_frozen))
        eng.frozen_len = new_frozen
        eng.rank_eff = np.where(
            fold, DK.fold_rank(eng.dkv_rank, r_in, t_frozen,
                               eng.dkv_tail),
            eng.rank_eff).astype(np.int32)
        eng.stats.tail_folds += int(fold.sum())
        # keep only the rows AND factor columns live slots reference — a
        # finished slot's stale frozen_len/rank must not pin memory, and
        # the rank axis shrinks back to the configured kv_rank once
        # wide-rank splices drain (the old behavior ratcheted forever)
        t_need = int(eng.frozen_len[live_m].max())
        r_need = int(eng.rank_eff[live_m].max())
        for key in ("k_u", "v_u"):
            eng.cache[key] = eng.cache[key][:, :, :t_need, :r_need]
        for key in ("k_vt", "v_vt"):
            eng.cache[key] = eng.cache[key][:, :, :r_need]

    def _fold_slots_paged(self, live_m: np.ndarray, must: np.ndarray,
                          fold: np.ndarray) -> np.ndarray:
        """Paged tail fold: retruncated prefixes land in FRESH pages
        (copy-on-write — shared/prefix-cache pages are never rewritten);
        the folded slots' old page refs are released after the scatter.
        Falls back to must-only folds when the pool can't take the
        opportunistic co-folds."""
        from ..models import decomposed_kv as DK
        eng = self.eng
        pg = eng.pager

        def grab(mask):
            idxs = [int(i) for i in np.where(mask)[0]]
            need = {i: pg.pages_for(int(eng.pos[i])) for i in idxs}
            if sum(need.values()) > pg.alloc.free_pages:
                return None
            return {i: pg.alloc.alloc(n) for i, n in need.items()}

        newp = grab(fold)
        if newp is None:
            fold = must
            newp = grab(fold)
        while newp is None and pg.prefix is not None and len(pg.prefix):
            pg.prefix._evict()
            newp = grab(fold)
        if newp is None:
            raise RuntimeError(
                "paged KV pool exhausted during a tail fold — raise "
                "kv_pool_pages (or lower slots/max_len)")
        npn = max(len(v) for v in newp.values())
        bt_new = pg.bt_array([newp.get(i, []) for i in range(eng.slots)],
                             npn)
        new_frozen = np.where(fold, eng.pos,
                              eng.frozen_len).astype(np.int32)
        pg.cache = pg._fold(
            pg.cache, jnp.asarray(eng.frozen_len), jnp.asarray(fold),
            jnp.asarray(new_frozen), jnp.asarray(pg.bt_array(pg.bt_u)),
            jnp.asarray(bt_new), jnp.asarray(pg.bt_array(pg.bt_t, pg.ntp)),
            pg.slab_t, pg.slab_r, eng.dkv_tail)
        r_fold = DK.fold_rank(eng.dkv_rank, pg.slab_r, pg.slab_t,
                              eng.dkv_tail)
        for i, pages in newp.items():
            pg.alloc.release(pg.bt_u[i])
            pg.bt_u[i] = pages
            eng.rank_eff[i] = r_fold
        eng.frozen_len = new_frozen
        eng.stats.tail_folds += int(fold.sum())
        pg.slab_t = int(eng.frozen_len[live_m].max())
        pg.slab_r = int(eng.rank_eff[live_m].max())
        return fold
