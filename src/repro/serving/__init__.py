"""Serving engine: batched prefill/decode with continuous batching.

A slot-based engine (vLLM-style, sized for the dry-run meshes): ``slots``
concurrent sequences share one static cache; finished sequences free their
slot; queued requests prefill into free slots.

The engine is FAMILY-GENERIC: everything model-family-specific — cache
allocation, splice admission, prefill/decode/fused-block builders, tail
folds, paged-layout adapters, scheduler admission cost — lives behind the
:class:`~repro.serving.families.ServingFamily` protocol, resolved once at
construction (``serving.families.serving_family``).  One engine serves
transformer (dense or decomposed-KV), Mamba2/SSM state slots, MoE,
hybrid, VLM, and audio encoder-decoder traffic; this module contains no
per-family branches (dcomlint rule F1 gates regressions), only the
family-agnostic machinery: slots, scheduler, tickets, stats, and the
step loop.

Admission is PER SLOT (``admission="per_slot"``, the default): only the
newly admitted requests are prefilled — batch and length rounded up to
scheduler buckets to bound re-jits — and the fresh cache rows are spliced
into the live cache along each leaf's batch axis (``api.splice_cache``,
every family; ``decomposed_kv.splice_dkv`` for the low-rank KV cache).
Live slots are never re-prefilled and admission never waits for them to
drain.  ``admission="gang"`` keeps the legacy policy (whole-slot-batch
prefill; decomposed-KV and non-dense families block until every slot is
free) for A/B comparison in ``benchmarks/serving_admission.py``.

``decompose_kv_rank`` serves the dense family on the paper's low-rank KV
cache (models.decomposed_kv): prefill decomposes K/V, decode contracts
through the factors, and each slot's dense tail is folded back
(``compress_tail`` with a per-slot fold mask) when THAT slot's tail
fills — plus opportunistic co-folding of half-full neighbors to
re-synchronize fold cadence.  ``frozen_len`` is a per-slot vector, not a
global scalar.

The :class:`Scheduler` dispatches FIFO with prefill-length bucketing (one
plen bucket per admission LAUNCH; ``_admit`` drains further buckets into
the remaining free slots, so mixed-length queues no longer idle slots
behind the head bucket); bucketing runs on the family's ADMISSION COST
(prompt tokens plus fixed modality work — image tokens, encoder frames),
not raw prompt length.  ``EngineStats`` tracks per-request first-token
and inter-token latency, and wall time accrues per ``step()``.  Requests
stop the moment they emit ``eos_id`` (or any of ``stop_tokens``) — the
slot frees immediately — with stopped-vs-budget finishes counted
separately.

``paged=True`` (decomposed-KV only) swaps the ``[slots, max_len, …]``
slab for the paged layout of ``serving.paged``: prefix U rows and dense
tail rows live in fixed-size page pools behind per-slot block tables, a
refcounted :class:`~repro.serving.paged.PageAllocator` recycles pages
across requests, and an optional hash-based prefix cache
(``EngineConfig.kv_prefix_cache``) admits a request whose padded prompt
extends a cached frozen prefix with TAIL-ONLY work — shared pages are
spliced by refcount, skipping both the prefix forward pass and its
Lanczos factorization.  With the prefix cache off, paged decode/fold
replays the slab engine's arithmetic bit-for-bit
(tests/test_serving_conformance.py).

Mesh-parallel serving: when the DecomposeEngine's config carries a
``mesh``, every cache (dense k/v AND the low-rank ``k_u``/``k_vt``
factors — and the SSM/hybrid state slots) is allocated on
``distributed.sharding.cache_sharding`` — slots over the DP super-axis,
KV heads / kv width over "model" — and every jitted step fn constrains
its cache inputs/outputs to the same specs, so splice admission,
per-slot ``frozen_len`` masking, and ``compress_tail`` folds all stay
device-local along the batch axis (no gather-to-host; the tail write is
a vmapped per-slot ``dynamic_update_slice``).  Greedy outputs are
byte-identical to the single-device engine
(tests/test_serving_conformance.py runs the 8-host-device twin).

``decode_block > 1`` fuses that many decode rounds into ONE jitted
on-device loop (``api.run_decode_block``): sampling runs on device, the
per-step host dispatch + sampler round-trip + python stop check are paid
once per BLOCK, and the host applies EOS/stop/budget bookkeeping in one
pass over the returned token buffer.  Tokens stay byte-identical to the
single-step engine by construction: the host computes every upcoming
boundary event deterministically (steps until the next tail fold from
``pos``/``frozen_len``/``dkv_tail``, the tightest budget horizon, the
next admission round) and caps the block there, and the loop exits early
the moment any slot emits a stop token — so folds, admissions, and
finishes all happen between blocks at exactly the rounds the single-step
engine would have run them (DESIGN.md §11).

``prefill_async=True`` disaggregates prefill from decode (vLLM-style
P/D split, DESIGN.md §12): ``_admit`` only DISPATCHES the prefill —
forward + Lanczos for misses, tail-only suffix prefill for prefix-cache
hits — as a :class:`~repro.serving.families.PrefillTicket` into the
engine's prefill pool, with the target slots reserved and (paged mode)
the pages/refs already held, then returns to the decode loop.  JAX
dispatch is asynchronous, so the Lanczos factorization runs device-side
while live slots keep decoding; the ticket's results are spliced into
the reserved slots at a later step boundary once ``api.tree_ready`` (a
non-blocking ``Array.is_ready`` probe over the result tree) reports them
done — decode never blocks on an in-flight decomposition.
``ready_order="ready"`` splices tickets as they complete (dispatch order
among the simultaneously-ready); ``ready_order="deterministic"``
completes every ticket inline at its dispatch round — the synchronous
engine's schedule driven through the identical dispatch/complete
machinery, which is the conformance mode: tokens are byte-identical to
``prefill_async=False`` (tests/test_serving_async.py, slot AND paged,
single AND fused decode, 1 and 8 devices).  ``cancel_pending`` unwinds
in-flight tickets: reserved slots free, page refs release, requests
requeue in arrival order.

All jitted decode/fold/splice fns DONATE their cache arguments
(``donate_argnums``): the engine rebinds ``self.cache`` (or the paged
pools) immediately at every call site, so XLA reuses the input buffers
in place instead of holding both generations live.  Shape-growing calls
(a fold extending the time axis, a widening splice) can't alias every
leaf — jax warns "Some donated buffers were not usable" there, which is
expected and filtered.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

# Expected consequence of best-effort donation: shape-growing folds and
# splices cannot reuse every donated leaf (see module docstring).
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")

from ..configs.base import ArchConfig
from ..engine import DecomposeEngine, EngineConfig
from ..models import api
from ..obs import (NULL_SPAN, LatencySeries, MetricsRegistry, Observability,
                   phase_scope)
from .families import (PrefillTicket, ServingFamily,  # noqa: F401
                       family_names, register_family, serving_family)

Array = jax.Array


def greedy_sampler(logits: Array, k: int) -> Array:
    """Default sampler: argmax over the vocab axis.  Module-level (not a
    per-engine lambda) so the fused decode-block executables, which are
    lru-keyed on the sampler, are shared across engines."""
    return jnp.argmax(logits, -1).astype(jnp.int32)


def categorical_sampler(temperature: float = 1.0) -> Callable:
    """Stochastic sampler for the on-device fused loop.  ``takes_key``
    marks it as keyed: both decode paths derive the per-round key as
    ``fold_in(stream_key, round_index)``, so any interleaving of block
    sizes samples the identical token sequence."""
    def sample(logits: Array, k: int, key) -> Array:
        lg = logits.astype(jnp.float32) / max(temperature, 1e-6)
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    sample.takes_key = True
    return sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None     # stop token (None = engine default)
    stop_tokens: Tuple[int, ...] = ()   # extra stop tokens
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    seq: int = -1                    # scheduler arrival stamp (FIFO key —
    #                                  deferral requeues merge on it)
    # -- latency accounting (monotonic perf_counter stamps, 0.0 = not yet)
    t_submit: float = 0.0
    t_dispatch: float = 0.0          # prefill launched (queue wait ends)
    t_first: float = 0.0             # first token emitted (prefill sample)
    t_last: float = 0.0              # most recent token
    t_done: float = 0.0


class EngineStats:
    """Per-engine serving counters + latency distributions, mounted on a
    ``repro.obs`` :class:`MetricsRegistry` (DESIGN.md §13).

    The attribute API is unchanged from the pre-obs dataclass — counters
    read/write as plain numbers (``stats.prefills += 1``), and the
    latency members (``ttft_s``/``ttft_queue_s``/``ttft_compute_s``/
    ``itl_s``) still ``append``/``extend``/iterate like lists — but the
    storage moved onto registry metrics: counters are ``Counter``s,
    latencies are O(1)-memory streaming histograms with a CAPPED
    recent-sample reservoir instead of the old unbounded per-request
    Python lists.  ``len(itl_s)`` reports the total observation count
    (the histogram counter), so the ``len(itl_s) == tokens_out``
    invariant survives the bound; iteration yields only the recent
    window.  ``mean_*`` come from the exact streaming sum/count, and
    p50/p95/p99 are available via ``.quantile(q)`` on any series.
    """

    _COUNTERS = (
        ("prefills", "admitted requests (one per request)"),
        ("prefill_batches", "admission batches (jit launches)"),
        ("decode_steps", "decode rounds (one token per live slot)"),
        ("blocks", "decode launches (== steps unless the fused loop "
                   "batches rounds per dispatch)"),
        ("tokens_out", "decode tokens emitted"),
        ("tail_folds", "per-slot compress_tail events"),
        ("stopped_eos", "requests finished on a stop token"),
        ("stopped_budget", "requests finished on max_new_tokens/max_len"),
        ("prefix_hits", "admissions served from the prefix cache"),
        ("prefix_misses", "prefix lookups that fell through to prefill"),
        ("stalls", "admissions deferred on page capacity"),
        ("wall_s", "wall seconds accrued per step()"),
    )
    _GAUGES = (
        ("prefill_inflight_peak",
         "max concurrently in-flight prefill tickets (async mode)"),
    )
    _HISTS = (
        ("ttft_s", "ttft_seconds", "submit to first token"),
        # TTFT split (aligned 1:1 with ttft_s): queue wait (submit →
        # prefill dispatch) vs prefill compute (dispatch → first token).
        # The async A/B compares queue wait — compute is the same device
        # work either way.
        ("ttft_queue_s", "ttft_queue_seconds",
         "queue wait: submit to prefill dispatch"),
        ("ttft_compute_s", "ttft_compute_seconds",
         "prefill compute: dispatch to first token"),
        ("itl_s", "itl_seconds", "inter-token latency"),
    )

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._m = {}
        for name, help_ in self._COUNTERS:
            metric = "serving_wall_seconds" if name == "wall_s" \
                else f"serving_{name}"
            self._m[name] = self.registry.counter(metric, help_)
        for name, help_ in self._GAUGES:
            self._m[name] = self.registry.gauge(f"serving_{name}", help_)
        for name, metric, help_ in self._HISTS:
            self._m[name] = LatencySeries(
                self.registry.histogram(f"serving_{metric}", help_))

    def __repr__(self) -> str:
        return (f"EngineStats(prefills={self.prefills}, "
                f"tokens_out={self.tokens_out}, "
                f"decode_steps={self.decode_steps})")

    @property
    def mean_ttft_s(self) -> float:
        return self.ttft_s.mean

    @property
    def mean_ttft_queue_s(self) -> float:
        return self.ttft_queue_s.mean

    @property
    def mean_ttft_compute_s(self) -> float:
        return self.ttft_compute_s.mean

    @property
    def mean_itl_s(self) -> float:
        return self.itl_s.mean

    def snapshot(self, wall_s: Optional[float] = None) -> dict:
        """The uniform ``repro.obs/v1`` metrics snapshot (benchmarks and
        the serve CLI embed this; see ``obs.snapshot``)."""
        from ..obs import stats_snapshot
        return stats_snapshot(self, wall_s=wall_s)


def _stat_counter(name: str) -> property:
    return property(lambda self: self._m[name].value,
                    lambda self, v: self._m[name].set(v))


for _name, _ in EngineStats._COUNTERS + EngineStats._GAUGES:
    setattr(EngineStats, _name, _stat_counter(_name))
for _name, _metric, _ in EngineStats._HISTS:
    setattr(EngineStats, _name,
            property(lambda self, _n=_name: self._m[_n]))
del _name, _metric


class Scheduler:
    """FIFO request queue with prefill-length bucketing.

    ``next_batch`` serves the HEAD of the queue plus any later requests
    falling in the same prefill-cost bucket (FIFO order within the
    bucket), so one admission batch compiles exactly one (batch, plen)
    shape.  Bucketing runs on ``cost(request)`` — the family's reported
    admission cost (prompt tokens by default; modality families add
    their fixed extra prefill work, e.g. image tokens or encoder
    frames), rounded up to multiples of ``bucket``; admitted batch size
    is capped at ``max_admit`` (0 = number of free slots).

    Every submission is stamped with a monotonically increasing arrival
    ``seq``; :meth:`requeue` merges a deferred batch back on that stamp,
    so a deferral can never leapfrog requests that arrived between the
    batch's members (the old front-insertion reordered cross-bucket:
    taking [a, c] out of [a(16), b(32), c(16)] and pushing the batch back
    to the front yielded [a, c, b] — c jumped b's place in line).
    """

    def __init__(self, bucket: int = 16, max_admit: int = 0,
                 cost: Optional[Callable[[Request], int]] = None):
        self.bucket = max(1, bucket)
        self.max_admit = max_admit
        self.cost = cost if cost is not None \
            else (lambda r: len(r.prompt))
        self._q: List[Request] = []
        self._seq = 0

    def submit(self, req: Request) -> None:
        if req.seq < 0:
            req.seq = self._seq
            self._seq += 1
        self._q.append(req)

    def requeue(self, batch: List[Request]) -> None:
        """Return a deferred (or cancelled) batch to the queue in ARRIVAL
        order — a stable merge on the submission stamp, not a front
        insertion."""
        self._q = sorted(self._q + list(batch), key=lambda r: r.seq)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> List[Request]:
        return list(self._q)

    def bucket_of(self, plen: int) -> int:
        return -(-max(int(plen), 1) // self.bucket) * self.bucket

    def next_batch(self, free_slots: int) -> List[Request]:
        if not self._q or free_slots < 1:
            return []
        cap = free_slots if self.max_admit < 1 \
            else min(free_slots, self.max_admit)
        want = self.bucket_of(self.cost(self._q[0]))
        take: List[Request] = []
        keep: List[Request] = []
        # Ride-along fairness: a later same-bucket request may join the
        # head's batch only while a slot remains for every OLDER skipped
        # bucket — each will want its own launch this admission round.
        # Without the reservation, a young ride-along could take the last
        # free slot from an older other-bucket request and push its first
        # token a full admission round out (head-bucket starvation).
        skipped = set()
        for r in self._q:
            bk = self.bucket_of(self.cost(r))
            if bk == want and len(take) + len(skipped) < cap:
                take.append(r)
            else:
                keep.append(r)
                if bk != want:
                    skipped.add(bk)
        self._q = keep
        return take


class Engine:
    """Continuous-batching engine over the unified model API.

    Decode advances every live slot one token per step; admission splices
    only the newly prefilled rows into the live cache (per-slot policy).
    Every family-specific operation dispatches through ``self.family``
    (a :class:`~repro.serving.families.ServingFamily`).
    """

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, sampler: Optional[Callable] = None,
                 decompose_kv_rank: Optional[int] = None,
                 dkv_tail: Optional[int] = None,
                 decompose_engine: Optional[DecomposeEngine] = None,
                 admission: str = "per_slot",
                 dkv_exact: Optional[bool] = None,
                 eos_id: Optional[int] = None,
                 paged: bool = False,
                 decode_block: Optional[Union[int, str]] = None,
                 prefill_async: Optional[bool] = None,
                 ready_order: str = "ready",
                 sample_seed: int = 0,
                 obs: Optional[Observability] = None):
        assert admission in ("per_slot", "gang"), admission
        assert ready_order in ("ready", "deterministic"), ready_order
        # Observability bundle (DESIGN.md §13): per-engine metrics
        # registry + tracer.  Purely host-side — spans and counters never
        # feed a jit or touch device state, so tokens are byte-identical
        # with tracing on or off (conformance-gated).
        self.obs = obs if obs is not None else Observability()
        self.trace = self.obs.tracer
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.admission = admission
        self.eos_id = eos_id             # default stop token for requests
        self.fns = api.model_fns(cfg)
        self.sampler = sampler or greedy_sampler
        # base PRNG key for keyed samplers (categorical_sampler): decode
        # rounds fold stream 0, admission rounds stream 1 — both indexed
        # by the engine's round counter, so the single-step and fused
        # paths draw identical samples
        self._key = jax.random.PRNGKey(sample_seed)
        # One DecomposeEngine per serving engine: backend/hook selection
        # happens here, once, and every prefill decomposition reuses it.
        # An explicitly passed knob always wins (0 DISABLES decomposed KV);
        # None knobs inherit from the engine config when one is supplied.
        if decompose_engine is not None:
            self.dengine = decompose_engine
            if decompose_kv_rank is None:
                decompose_kv_rank = decompose_engine.config.kv_rank
            if dkv_tail is None:
                dkv_tail = decompose_engine.config.kv_tail
        else:
            decompose_kv_rank = decompose_kv_rank or 0
            if dkv_tail is None:
                dkv_tail = 16
            self.dengine = DecomposeEngine(EngineConfig(
                kv_rank=decompose_kv_rank, kv_tail=dkv_tail))
        self.dkv_rank = decompose_kv_rank
        self.dkv_tail = dkv_tail
        self.dkv_exact = self.dengine.config.kv_exact \
            if dkv_exact is None else dkv_exact
        # Mesh-parallel serving: the engine config's mesh shards every
        # cache along the batch (slot) axis over the DP super-axis (and KV
        # heads / kv width over "model") per distributed.sharding's spec
        # tables; None keeps the single-device path bit-identical.
        self.mesh = self.dengine.config.mesh
        # per-slot state: pos is the next write position, frozen_len the
        # length of the slot's low-rank prefix, rank_eff its effective
        # factor rank (dkv path only — lets the engine slice the rank
        # axis back down when wide-rank occupants leave or fold)
        self.pos = np.zeros((slots,), np.int32)
        self.frozen_len = np.zeros((slots,), np.int32)
        self.rank_eff = np.zeros((slots,), np.int32)
        self.live: List[Optional[Request]] = [None] * slots
        # the per-family strategy: cache layout, splice admission, jitted
        # step builders, folds, and (transformer-dkv) the paged adapter —
        # resolving it also constructs self.pager when paged
        self.pager = None
        self.family = serving_family(self, paged=paged)
        self.cache = self.family.alloc()
        ecfg = self.dengine.config
        self.sched = Scheduler(bucket=ecfg.sched_bucket,
                               max_admit=ecfg.sched_max_admit,
                               cost=self.family.prefill_cost)
        self.admit_every = max(1, ecfg.sched_admit_every)
        # fused decode-block length: explicit arg wins, else the engine
        # config; "auto" resolves through the repro.tune cost model for
        # this (slots, decode horizon, kv width) bucket.  1 = the
        # single-step path, bit-identical to the pre-fusion engine.
        blk = ecfg.decode_block if decode_block is None else decode_block
        if blk == "auto":
            from .. import tune
            horizon = self.family.tune_horizon()
            kvw = cfg.num_kv_heads * cfg.resolved_head_dim
            blk = tune.tuned_decode_block((slots, horizon, kvw))
        self.decode_block = max(1, int(blk))
        cap = self.family.block_cap()
        if cap is not None:
            self.decode_block = min(self.decode_block, cap)
        # -- async prefill/decode disaggregation (DESIGN.md §12) --------
        # prefill_async: explicit arg wins, else the engine config.
        # ready_order="ready" splices tickets as their device results
        # come ready (the true async mode — decode never blocks on an
        # in-flight Lanczos); "deterministic" completes each ticket at
        # its dispatch round, replaying the synchronous schedule through
        # the identical ticket machinery (the byte-identity conformance
        # mode).  Sync admission and deterministic mode share one code
        # path; only "ready" populates the pool across steps.
        if prefill_async is None:
            prefill_async = ecfg.prefill_async
        self.prefill_async = bool(prefill_async)
        self.ready_order = ready_order
        assert not (self.prefill_async and admission == "gang"), \
            "async prefill requires per-slot admission (gang replaces " \
            "the whole cache — there is nothing to overlap)"
        self._pool: List[PrefillTicket] = []     # in-flight admissions
        self._reserved = np.zeros(slots, bool)   # dispatched, not spliced
        self.admit_log: List[int] = []           # uids in dispatch order
        self.stats = EngineStats(registry=self.obs.registry)
        # open request-lifecycle spans: uid -> {"request"/"queue"/
        # "prefill"/"decode": Span} (NULL_SPANs when tracing is off)
        self._req_spans: dict = {}
        # _round counts COMPLETED decode rounds (a fused block advances it
        # by its step count); admission due-ness and sampler keys both
        # index it, which is what keeps any interleaving of block sizes
        # byte-identical to the single-step engine
        self._round = 0

    def _place(self, cache):
        """device_put a freshly built cache onto its mesh shardings."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, api.cache_shardings(
            self.cfg, cache, self.mesh, seq_shard=False))

    # -- public API ------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched._q

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens leaves no decode room "
                f"in a max_len={self.max_len} cache")
        if not req.t_submit:
            req.t_submit = time.perf_counter()
        if self.trace.enabled:
            track = f"req/{req.uid}"
            self._req_spans[req.uid] = {
                "request": self.trace.begin(
                    "request", track,
                    {"uid": req.uid, "prompt_tokens": len(req.prompt)}),
                "queue": self.trace.begin("queue", track),
            }
        self.sched.submit(req)

    def step(self) -> List[Request]:
        """One scheduling iteration: admit if due (per the interleaving
        policy), then decode — one token per live slot, or up to
        ``decode_block`` tokens in one fused on-device loop.  Returns the
        requests that finished this step.  Wall time accrues HERE, so
        ``step()``-driven callers (benchmarks, the serve CLI loop) get the
        same tok/s accounting as ``run()``."""
        t0 = time.perf_counter()
        step_span = self.trace.begin("step", "engine",
                                     {"round": self._round})
        finished: List[Request] = []
        try:
            if self._pool:
                # splice any in-flight admissions whose results came
                # ready since the last boundary; when nothing is live
                # decode can't make progress, so block on the pool head
                # instead of spinning
                finished.extend(self._drain_pool(
                    block=not any(r is not None for r in self.live)))
            if self._round % self.admit_every == 0 or not self._occupied():
                with self.trace.span("admit", "engine"):
                    finished.extend(self._admit())
            if any(self.live):
                finished.extend(self._decode_rounds())
            else:
                self._round += 1     # idle step still advances the clock
            return finished
        finally:
            step_span.end(finished=len(finished))
            self.stats.wall_s += time.perf_counter() - t0

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self._occupied() and not len(self.sched):
                # drained: no live slot, no in-flight ticket, empty
                # queue — admission on an all-free engine always takes
                # at least the queue head, so this means done.  (A
                # non-empty queue that can NEVER admit raises inside
                # _admit instead of spinning to max_steps — see the
                # capacity-stall check there.)
                break
        return finished

    def _occupied(self) -> bool:
        """Any slot live OR reserved by an in-flight admission ticket."""
        return any(r is not None for r in self.live) \
            or bool(self._reserved.any()) or bool(self._pool)

    # -- internals ---------------------------------------------------------
    def _sample_host(self, logits: Array, stream: int = 0) -> np.ndarray:
        """Host-side sampling (admission first tokens, single-step decode).
        Keyed samplers get ``fold_in(fold_in(key, stream), round)`` —
        stream 0 is the decode stream the fused loop folds on device,
        stream 1 the admission stream — so both decode paths and every
        block interleaving draw the same tokens."""
        # the ONE sanctioned device→host sync in the engine: emitted
        # tokens must land in host lists, so the readback is the point
        if getattr(self.sampler, "takes_key", False):
            k = jax.random.fold_in(jax.random.fold_in(self._key, stream),
                                   self._round)
            return np.asarray(self.sampler(logits, 1, k))  # dcomlint: disable=J2
        return np.asarray(self.sampler(logits, 1))  # dcomlint: disable=J2

    def _stops(self, req: Request) -> frozenset:
        eos = req.eos_id if req.eos_id is not None else self.eos_id
        toks = set(req.stop_tokens)
        if eos is not None:
            toks.add(eos)
        return frozenset(toks)

    def _finish(self, slot: int, req: Request, now: float, *,
                eos: bool) -> None:
        """Free a slot the moment its request stops (token or budget)."""
        req.done = True
        req.t_done = now
        self.live[slot] = None
        self.family.free_slot(slot)
        if eos:
            self.stats.stopped_eos += 1
        else:
            self.stats.stopped_budget += 1
        spans = self._req_spans.pop(req.uid, None)
        if spans:
            # Span.end is idempotent: queue/prefill already ended at their
            # own boundaries; this closes whatever is still open
            for name in ("queue", "prefill", "decode"):
                if name in spans:
                    spans[name].end()
            spans["request"].end(tokens=len(req.out_tokens), eos=eos)

    def _check_stop(self, slot: int, req: Request, now: float) -> bool:
        """Stop-token / budget check after a token was appended."""
        if req.out_tokens and req.out_tokens[-1] in self._stops(req):
            self._finish(slot, req, now, eos=True)
            return True
        if (len(req.out_tokens) >= req.max_new_tokens
                or self.pos[slot] >= self.max_len - 1):
            self._finish(slot, req, now, eos=False)
            return True
        return False

    def _admit(self) -> List[Request]:
        """Admission: drain the queue into the free slots, ONE prefill
        launch per length bucket, so other-bucket requests no longer wait
        behind the head bucket while slots sit idle.  Async mode only
        DISPATCHES here (tickets into the pool); sync/deterministic mode
        completes each ticket inline at its dispatch round."""
        finished: List[Request] = []
        blocked = False
        while True:
            free = [i for i, r in enumerate(self.live)
                    if r is None and not self._reserved[i]]
            if not free or not len(self.sched):
                break
            has_live = any(r is not None for r in self.live)
            if self.admission == "gang" and has_live \
                    and not self.family.gang_live_splice:
                # legacy gang restriction, kept only for the A/B benchmark:
                # splice-merge used to exist for the dense-cache path only
                break
            batch = self.sched.next_batch(len(free))
            if not batch:
                break
            maxp = max(len(r.prompt) for r in batch)
            plen = self.sched.bucket_of(maxp)
            if plen >= self.max_len:
                # bucket rounds past the cache: fall back to the exact
                # length (one extra jit shape near the cap beats losing
                # decode room)
                plen = maxp
            # family capacity check (paged: prefix lookups + page
            # reservation — hit refs already held inside ctx); None
            # defers the batch until in-flight work frees resources
            ctx = self.family.reserve(batch, plen)
            if ctx is None:
                self.sched.requeue(batch)
                self.stats.stalls += 1
                blocked = True
                break
            finished.extend(self._admit_batch(batch, free, plen, has_live,
                                              ctx))
            if self.admission == "gang":
                break                # legacy: one gang per admission
        if blocked and not self._occupied():
            # Deferred on capacity with NO live slot and NO in-flight
            # ticket: nothing can ever free resources (a paged
            # reservation already evicted every evictable prefix entry),
            # so retrying would livelock run() until max_steps and
            # silently drop the request.  Fail loudly instead.
            raise RuntimeError(self.family.capacity_msg(self.sched._q[0]))
        return finished

    def _admit_batch(self, batch: List[Request], free: List[int],
                     plen: int, has_live: bool,
                     ctx: Any = None) -> List[Request]:
        """One admission batch: stamp dispatch times, launch the prefill
        (ticket dispatch), then either complete inline (sync and
        deterministic modes — identical device-side program order to the
        pre-split engine) or park the tickets in the ready pool (async
        ``ready`` mode) for ``_drain_pool`` to splice at step edges."""
        slots_idx = free[:len(batch)]
        now = time.perf_counter()
        for req in batch:
            req.t_dispatch = now
            spans = self._req_spans.get(req.uid)
            if spans:
                spans["queue"].end()
                spans["prefill"] = self.trace.begin(
                    "prefill", f"req/{req.uid}", {"plen": plen})
        self.admit_log.extend(r.uid for r in batch)
        self.stats.prefills += len(batch)
        if self.admission == "gang":
            with phase_scope("prefill"):
                logits = self.family.gang(batch, slots_idx, plen, has_live)
            nxt = self._sample_host(logits, stream=1)[slots_idx]
            fls = self.family.frozen_after_prefill(len(batch), plen)
            self.stats.prefill_batches += 1
            return self._activate(batch, slots_idx, plen, nxt, fls)
        for slot in slots_idx:
            self._reserved[slot] = True
        with phase_scope("prefill"):
            tickets = self.family.dispatch(batch, slots_idx, plen, ctx)
        if self.trace.enabled:
            for t in tickets:
                t.span = self.trace.begin(
                    "ticket", "tickets",
                    {"requests": len(t.requests), "plen": t.plen,
                     "uids": [r.uid for r in t.requests]})
        if self.prefill_async and self.ready_order == "ready":
            self._pool.extend(tickets)
            self.stats.prefill_inflight_peak = max(
                self.stats.prefill_inflight_peak, len(self._pool))
            return []
        finished: List[Request] = []
        for t in tickets:
            finished.extend(self._finish_ticket(t))
        return finished

    def _activate(self, batch: List[Request], slots_idx: List[int],
                  plen: int, nxt: np.ndarray,
                  fls: np.ndarray) -> List[Request]:
        """Completion tail shared by every admission path: occupy the
        slots, stamp the TTFT split (queue wait vs prefill compute), and
        apply first-token stop checks."""
        now = time.perf_counter()
        finished: List[Request] = []
        for j, (slot, req) in enumerate(zip(slots_idx, batch)):
            self._reserved[slot] = False
            self.live[slot] = req
            self.pos[slot] = plen
            self.frozen_len[slot] = fls[j]
            req.out_tokens.append(int(nxt[j]))
            req.t_first = req.t_last = now
            spans = self._req_spans.get(req.uid)
            if spans:
                spans["prefill"].end(slot=slot)
                spans["decode"] = self.trace.begin("decode",
                                                   f"req/{req.uid}")
            self.stats.ttft_s.append(now - req.t_submit)
            self.stats.ttft_queue_s.append(req.t_dispatch - req.t_submit)
            self.stats.ttft_compute_s.append(now - req.t_dispatch)
            # the FIRST token can already be a stop token (or the whole
            # budget): finish and free the slot immediately
            if self._check_stop(slot, req, now):
                finished.append(req)
        return finished

    def _finish_ticket(self, t: PrefillTicket) -> List[Request]:
        with self.trace.span("splice", "engine",
                             {"requests": len(t.requests)}), \
                phase_scope("splice"):
            nxt, fls = t.complete()
        if t.span is not None:
            t.span.end()
        return self._activate(t.requests, t.slots, t.plen, nxt, fls)

    def _drain_pool(self, *, block: bool) -> List[Request]:
        """Splice finished prefill tickets into their reserved slots.

        Tickets are visited in dispatch order; a ticket is spliced when
        its done-probe reports ready (never blocking decode on an
        in-flight Lanczos).  With ``block=True`` (nothing live to decode,
        so there is no useful work to overlap) the pool HEAD is completed
        even if not yet ready — ``complete()`` then blocks on the device
        result, which is exactly the sync engine's behaviour."""
        finished: List[Request] = []
        rest: List[PrefillTicket] = []
        spliced = 0
        with self.trace.span("drain-pool", "engine",
                             {"pool": len(self._pool)}) as dspan:
            for t in self._pool:
                if (block and not spliced and not rest) or t.ready():
                    finished.extend(self._finish_ticket(t))
                    spliced += 1
                else:
                    rest.append(t)
            dspan.annotate(spliced=spliced)
        self._pool = rest
        return finished

    def cancel_pending(self, requeue: bool = True) -> int:
        """Cancel every in-flight admission ticket.

        Reserved slots are freed, paged tickets release their page refs
        (prefix-hit shared refs exactly once — the ref taken at lookup
        was installed as the slot's block table at dispatch, and
        ``free_slot`` releases it), and the requests re-enter the queue
        in arrival order (``requeue=False`` drops them).  Dispatch-side
        stats are unwound so a cancelled request is not double-counted
        when re-admitted.  The device computation itself is not
        interrupted — its results are simply never spliced.  Returns the
        number of cancelled requests."""
        n = 0
        for t in self._pool:
            t.cancel()
            if t.span is not None:
                t.span.end(cancelled=True)
            for slot in t.slots:
                self._reserved[slot] = False
            self.stats.prefills -= len(t.requests)
            for req in t.requests:
                req.t_dispatch = 0.0
                spans = self._req_spans.get(req.uid)
                if spans:
                    spans.pop("prefill", NULL_SPAN).end(cancelled=True)
                    if requeue:      # back in the queue: reopen its wait
                        spans["queue"] = self.trace.begin(
                            "queue", f"req/{req.uid}", {"requeued": True})
                    else:
                        spans["request"].end(dropped=True)
                        del self._req_spans[req.uid]
                n += 1
                for k in range(len(self.admit_log) - 1, -1, -1):
                    if self.admit_log[k] == req.uid:
                        del self.admit_log[k]
                        break
            if requeue:
                self.sched.requeue(t.requests)
        self._pool = []
        return n

    def _toks(self, batch: List[Request], rows: int, plen: int,
              row_of: Callable[[int], int]) -> np.ndarray:
        toks = np.zeros((rows, plen), np.int32)
        for j, req in enumerate(batch):
            toks[row_of(j), plen - len(req.prompt):] = req.prompt  # left-pad
        return toks

    def _last_tokens(self) -> np.ndarray:
        tok = np.zeros((self.slots,), np.int32)
        for i, req in enumerate(self.live):
            if req is not None and req.out_tokens:
                tok[i] = req.out_tokens[-1]
        return tok

    def _decode_rounds(self) -> List[Request]:
        """One decode LAUNCH: the single-step round (decode_block == 1,
        bit-identical to the pre-fusion engine) or a fused block of up to
        ``decode_block`` rounds.  Fold checks run here, at the boundary —
        identical cadence either way (a no-op for families whose state
        never grows)."""
        self.family.maybe_fold()
        if self.decode_block <= 1:
            done = self._decode_round()
            self._round += 1
            return done
        return self._decode_block_round()

    def _decode_round(self) -> List[Request]:
        tok = self._last_tokens()
        with self.trace.span("decode-step", "engine"), \
                phase_scope("decode"):
            logits = self.family.decode(tok)
            nxt = self._sample_host(logits)
        self.stats.decode_steps += 1
        self.stats.blocks += 1
        now = time.perf_counter()
        done: List[Request] = []
        for i, req in enumerate(self.live):
            if req is None:
                continue
            self.pos[i] += 1
            req.out_tokens.append(int(nxt[i]))
            self.stats.tokens_out += 1
            self.stats.itl_s.append(now - req.t_last)
            req.t_last = now
            # EOS / stop tokens end a request the moment they are emitted
            # (the old loop only stopped on budget or cache exhaustion,
            # so every request burned its full max_new_tokens)
            if self._check_stop(i, req, now):
                done.append(req)
        return done

    # -- fused block decode ------------------------------------------------
    def _block_len(self) -> int:
        """Steps the next fused block may run before a host-side event is
        due.  Every horizon is DETERMINISTIC from engine state, which is
        the fold/admission half of the token-exactness argument (stop
        tokens — the non-deterministic half — end the block early on
        device instead):

        * budget: no live slot may decode past ``max_new_tokens`` or the
          cache end (the single-step engine would have finished it);
        * fold: the family's ``fold_horizon()`` — steps until some tail
          fills (folds only happen at boundaries, at the exact same
          occupancy); None for families whose state never grows;
        * admission: with ``admit_every > 1`` and a non-empty queue, stop
          at the next due round.  With ``admit_every == 1`` no cap is
          needed — a queued request that admission just deferred (no free
          slot, bucket mismatch, page pressure) can only be unblocked by
          a slot freeing or a fold, which are boundary events themselves.
        """
        blk = self.decode_block
        for i, req in enumerate(self.live):
            if req is None:
                continue
            blk = min(blk,
                      req.max_new_tokens - len(req.out_tokens),
                      (self.max_len - 1) - int(self.pos[i]))
        fh = self.family.fold_horizon()
        if fh is not None:
            blk = min(blk, fh)
        if len(self.sched) and self.admit_every > 1:
            due = (self._round // self.admit_every + 1) * self.admit_every
            blk = min(blk, due - self._round)
        return max(1, blk)

    def _stop_table(self) -> np.ndarray:
        """Per-slot stop-token table for the on-device early-exit check:
        int32 [slots, W], −1-padded (dead slots are all −1, matching no
        sampled token).  W is the widest live stop set, so the jit shape
        only changes when a request carries more stop tokens than any
        before it."""
        sets = [sorted(self._stops(r)) if r is not None else []
                for r in self.live]
        w = max([len(s) for s in sets] + [1])
        tbl = np.full((self.slots, w), -1, np.int32)
        for i, s in enumerate(sets):
            tbl[i, :len(s)] = s
        return tbl

    def _decode_block_round(self) -> List[Request]:
        blk = self._block_len()
        tok = self._last_tokens()
        stops = jnp.asarray(self._stop_table())
        key = jax.random.fold_in(self._key, 0)      # decode sample stream
        n, r0 = jnp.int32(blk), jnp.int32(self._round)
        t0 = time.perf_counter()
        bspan = self.trace.begin("decode-block", "engine", {"max_steps": blk})
        with phase_scope("decode"):
            buf, steps = self.family.decode_block(tok, n, stops, key, r0)
            steps = int(steps)
            toks = np.asarray(buf)[:steps]          # [steps, slots], syncs
        bspan.end(steps=steps)
        now = time.perf_counter()
        # ITL under block decode: one wall measurement per LAUNCH,
        # attributed wall/steps per token (the per-round "now − t_last"
        # stamp would collapse to ~0 for all but the first token of a
        # block and overstate the first)
        per_tok = (now - t0) / max(steps, 1)
        self.stats.decode_steps += steps
        self.stats.blocks += 1
        self._round += steps
        done: List[Request] = []
        for i, req in enumerate(self.live):
            if req is None:
                continue
            req.out_tokens.extend(int(t) for t in toks[:, i])
            self.pos[i] += steps
            self.stats.tokens_out += steps
            self.stats.itl_s.extend([per_tok] * steps)
            req.t_last = now
            # stops can only sit on the block's LAST step (early exit),
            # so the boundary check sees exactly what the single-step
            # engine's per-round check would have
            if self._check_stop(i, req, now):
                done.append(req)
        return done
