"""Serving engine: batched prefill/decode with continuous batching.

A slot-based engine (vLLM-style, sized for the dry-run meshes): ``slots``
concurrent sequences share one static cache; finished sequences free their
slot; queued requests prefill into free slots.

Admission is PER SLOT (``admission="per_slot"``, the default): only the
newly admitted requests are prefilled — batch and length rounded up to
scheduler buckets to bound re-jits — and the fresh cache rows are spliced
into the live cache along each leaf's batch axis (``api.splice_cache``,
every family; ``decomposed_kv.splice_dkv`` for the low-rank KV cache).
Live slots are never re-prefilled and admission never waits for them to
drain.  ``admission="gang"`` keeps the legacy policy (whole-slot-batch
prefill; decomposed-KV and non-dense families block until every slot is
free) for A/B comparison in ``benchmarks/serving_admission.py``.

``decompose_kv_rank`` serves the dense family on the paper's low-rank KV
cache (models.decomposed_kv): prefill decomposes K/V, decode contracts
through the factors, and each slot's dense tail is folded back
(``compress_tail`` with a per-slot fold mask) when THAT slot's tail
fills — plus opportunistic co-folding of half-full neighbors to
re-synchronize fold cadence.  ``frozen_len`` is a per-slot vector, not a
global scalar.

The :class:`Scheduler` dispatches FIFO with prefill-length bucketing (one
plen bucket per admission batch); ``EngineStats`` tracks per-request
first-token and inter-token latency.

Mesh-parallel serving: when the DecomposeEngine's config carries a
``mesh``, every cache (dense k/v AND the low-rank ``k_u``/``k_vt``
factors) is allocated on ``distributed.sharding.cache_sharding`` — slots
over the DP super-axis, KV heads / kv width over "model" — and every
jitted step fn constrains its cache inputs/outputs to the same specs, so
splice admission, per-slot ``frozen_len`` masking, and ``compress_tail``
folds all stay device-local along the batch axis (no gather-to-host; the
tail write is a vmapped per-slot ``dynamic_update_slice``).  Greedy
outputs are byte-identical to the single-device engine
(tests/test_serving_conformance.py runs the 8-host-device twin).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..engine import DecomposeEngine, EngineConfig
from ..models import api

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # -- latency accounting (monotonic perf_counter stamps, 0.0 = not yet)
    t_submit: float = 0.0
    t_first: float = 0.0             # first token emitted (prefill sample)
    t_last: float = 0.0              # most recent token
    t_done: float = 0.0


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0                # admitted REQUESTS (one per request)
    prefill_batches: int = 0         # admission batches (jit launches)
    decode_steps: int = 0
    tokens_out: int = 0
    tail_folds: int = 0              # per-slot compress_tail events
    wall_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    itl_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def mean_ttft_s(self) -> float:
        return sum(self.ttft_s) / len(self.ttft_s) if self.ttft_s else 0.0

    @property
    def mean_itl_s(self) -> float:
        return sum(self.itl_s) / len(self.itl_s) if self.itl_s else 0.0


class Scheduler:
    """FIFO request queue with prefill-length bucketing.

    ``next_batch`` serves the HEAD of the queue plus any later requests
    falling in the same prefill-length bucket (FIFO order within the
    bucket), so one admission batch compiles exactly one (batch, plen)
    shape.  Prompt lengths round up to multiples of ``bucket``; admitted
    batch size is capped at ``max_admit`` (0 = number of free slots).
    """

    def __init__(self, bucket: int = 16, max_admit: int = 0):
        self.bucket = max(1, bucket)
        self.max_admit = max_admit
        self._q: List[Request] = []

    def submit(self, req: Request) -> None:
        self._q.append(req)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> List[Request]:
        return list(self._q)

    def bucket_of(self, plen: int) -> int:
        return -(-max(int(plen), 1) // self.bucket) * self.bucket

    def next_batch(self, free_slots: int) -> List[Request]:
        if not self._q or free_slots < 1:
            return []
        cap = free_slots if self.max_admit < 1 \
            else min(free_slots, self.max_admit)
        want = self.bucket_of(len(self._q[0].prompt))
        take: List[Request] = []
        keep: List[Request] = []
        for r in self._q:
            if len(take) < cap and self.bucket_of(len(r.prompt)) == want:
                take.append(r)
            else:
                keep.append(r)
        self._q = keep
        return take


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


def _constrain(mesh):
    """Cache-tree ``with_sharding_constraint`` closure for the jitted step
    fns (identity when ``mesh`` is None — the single-device path traces the
    exact pre-mesh graph).  ``seq_shard=False``: the batch-1 time-axis
    ("flash-decoding") rule is for global-batch-1 long-context decode, not
    serving — a freshly prefilled single-request cache must stay replicated
    until spliced, not bounce through a sequence reshard per admission."""
    if mesh is None:
        return lambda c: c
    from ..distributed import sharding as sh
    return lambda c: sh.constrain_cache(c, mesh, seq_shard=False)


@functools.lru_cache(maxsize=None)
def _jitted_steps(fns: api.ModelFns, cfg: ArchConfig, max_len: int,
                  mesh=None):
    """Jitted (decode, prefill) shared across Engine instances of the same
    (config, mesh) — XLA executables are reused instead of re-traced per
    engine.  Under a mesh both the incoming and outgoing cache trees are
    sharding-constrained to ``distributed.sharding.cache_pspec``, so GSPMD
    keeps every per-slot update device-local along the batch axis."""
    con = _constrain(mesh)

    def decode(p, t, c, pos):
        lg, nc = fns.decode_step(p, cfg, t, con(c), pos)
        return lg, con(nc)

    def prefill(p, *a):
        lg, c = fns.prefill(p, cfg, *a, max_len)
        return lg, con(c)

    return jax.jit(decode), jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _jitted_dkv_decode(cfg: ArchConfig, mesh=None):
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)

    def step(p, t, c, pos, fl):
        lg, nc = DK.decode_step_dkv(p, cfg, t, con(c), pos, frozen_len=fl)
        return lg, con(nc)

    return jax.jit(step)


@functools.lru_cache(maxsize=None)
def _jitted_dkv_prefill(cfg: ArchConfig, backend: str, expansion: int,
                        rank: int, tail: int, iters_extra: int,
                        exact: bool, mesh=None):
    """Jitted decomposed-KV prefill (forward + Lanczos/SVD factorization in
    ONE compiled program — ~100× over the eager path on small configs).
    Keyed on the decomposition-relevant engine knobs so equivalently
    configured serving engines share executables.  With a mesh the inner
    DecomposeEngine runs the factorization DP-sharded over the
    layers×batch axis and the fresh cache comes out sharding-constrained."""
    from ..models import decomposed_kv as DK
    eng = DecomposeEngine(EngineConfig(
        backend=backend, expansion=expansion, kv_rank=rank, kv_tail=tail,
        kv_iters_extra=iters_extra, mesh=mesh))
    con = _constrain(mesh)

    def prefill(p, tk):
        lg, c = DK.prefill_dkv(p, cfg, tk, rank, tail=tail, exact=exact,
                               engine=eng)
        return lg, con(c)

    return jax.jit(prefill)


@functools.lru_cache(maxsize=None)
def _jitted_dkv_compress(cfg: ArchConfig, rank: int, mesh=None):
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)
    return jax.jit(lambda c, fl, fm: con(DK.compress_tail(
        con(c), cfg, rank, frozen_len=fl, fold=fm)))


@functools.lru_cache(maxsize=None)
def _jitted_splices(mesh=None):
    """Jitted cache-splice kernels (slot/src index vectors are traced, so
    one executable serves every admission with the same shape profile).
    The LIVE side keeps its batch sharding; the fresh side is typically
    smaller than the slot batch and stays wherever prefill left it."""
    from ..models import decomposed_kv as DK
    con = _constrain(mesh)
    dkv = jax.jit(lambda live, fresh, idx, src:
                  con(DK.splice_dkv(con(live), fresh, idx, src)))
    fam = jax.jit(lambda old, new, idx, src, cfg:
                  con(api.splice_cache(cfg, con(old), new, idx, src)),
                  static_argnums=(4,))
    return dkv, fam


class Engine:
    """Continuous-batching engine over the unified model API.

    Decode advances every live slot one token per step; admission splices
    only the newly prefilled rows into the live cache (per-slot policy).
    """

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, sampler: Optional[Callable] = None,
                 decompose_kv_rank: Optional[int] = None,
                 dkv_tail: Optional[int] = None,
                 decompose_engine: Optional[DecomposeEngine] = None,
                 admission: str = "per_slot",
                 dkv_exact: Optional[bool] = None):
        assert admission in ("per_slot", "gang"), admission
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.admission = admission
        self.fns = api.model_fns(cfg)
        self.sampler = sampler or (lambda lg, k: jnp.argmax(lg, -1)
                                   .astype(jnp.int32))
        # One DecomposeEngine per serving engine: backend/hook selection
        # happens here, once, and every prefill decomposition reuses it.
        # An explicitly passed knob always wins (0 DISABLES decomposed KV);
        # None knobs inherit from the engine config when one is supplied.
        if decompose_engine is not None:
            self.dengine = decompose_engine
            if decompose_kv_rank is None:
                decompose_kv_rank = decompose_engine.config.kv_rank
            if dkv_tail is None:
                dkv_tail = decompose_engine.config.kv_tail
        else:
            decompose_kv_rank = decompose_kv_rank or 0
            if dkv_tail is None:
                dkv_tail = 16
            self.dengine = DecomposeEngine(EngineConfig(
                kv_rank=decompose_kv_rank, kv_tail=dkv_tail))
        self.dkv_rank = decompose_kv_rank
        self.dkv_tail = dkv_tail
        self.dkv_exact = self.dengine.config.kv_exact \
            if dkv_exact is None else dkv_exact
        # Mesh-parallel serving: the engine config's mesh shards every
        # cache along the batch (slot) axis over the DP super-axis (and KV
        # heads / kv width over "model") per distributed.sharding's spec
        # tables; None keeps the single-device path bit-identical.
        self.mesh = self.dengine.config.mesh
        if self.dkv_rank:
            assert cfg.family == "dense", "decomposed KV: dense family"
            self.cache = None            # built at first prefill
        else:
            self.cache = self._place(self.fns.init_cache(cfg, slots,
                                                         max_len))
        # per-slot state: pos is the next write position, frozen_len the
        # length of the slot's low-rank prefix (dkv path only)
        self.pos = np.zeros((slots,), np.int32)
        self.frozen_len = np.zeros((slots,), np.int32)
        self.live: List[Optional[Request]] = [None] * slots
        ecfg = self.dengine.config
        self.sched = Scheduler(bucket=ecfg.sched_bucket,
                               max_admit=ecfg.sched_max_admit)
        self.admit_every = max(1, ecfg.sched_admit_every)
        self.stats = EngineStats()
        self._round = 0

        self._decode, self._prefill = _jitted_steps(self.fns, cfg, max_len,
                                                    self.mesh)
        self._splice_dkv, self._splice_fam = _jitted_splices(self.mesh)
        # frozen_len is a traced [B] vector now, so the dkv step jits
        # cleanly (no retrace per tail fold)
        if self.dkv_rank:
            ec = self.dengine.config
            self._decode_dkv = _jitted_dkv_decode(cfg, self.mesh)
            self._prefill_dkv = _jitted_dkv_prefill(
                cfg, ec.backend, ec.expansion, self.dkv_rank, self.dkv_tail,
                ec.kv_iters_extra, self.dkv_exact, self.mesh)
            self._compress_dkv = _jitted_dkv_compress(cfg, self.dkv_rank,
                                                      self.mesh)

    def _place(self, cache):
        """device_put a freshly built cache onto its mesh shardings."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, api.cache_shardings(
            self.cfg, cache, self.mesh, seq_shard=False))

    # -- public API ------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched._q

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens leaves no decode room "
                f"in a max_len={self.max_len} cache")
        if not req.t_submit:
            req.t_submit = time.perf_counter()
        self.sched.submit(req)

    def step(self) -> List[Request]:
        """One scheduling iteration: admit if due (per the interleaving
        policy), then decode one token on every live slot.  Returns the
        requests that finished this step."""
        if self._round % self.admit_every == 0 or not any(self.live):
            self._admit()
        self._round += 1
        if not any(self.live):
            return []
        return self._decode_round()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        t0 = time.perf_counter()
        finished: List[Request] = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not any(self.live) and not len(self.sched):
                # drained: admission on an all-free engine always takes at
                # least the queue head, so an empty queue means done
                break
        self.stats.wall_s += time.perf_counter() - t0
        return finished

    # -- internals ---------------------------------------------------------
    def _admit(self) -> int:
        free = [i for i, r in enumerate(self.live) if r is None]
        if not free or not len(self.sched):
            return 0
        has_live = any(r is not None for r in self.live)
        if self.admission == "gang" and has_live and \
                (self.dkv_rank or self.cfg.family != "dense"):
            # legacy gang restriction, kept only for the A/B benchmark:
            # splice-merge used to exist for the dense dense-cache path only
            return 0
        batch = self.sched.next_batch(len(free))
        if not batch:
            return 0
        slots_idx = free[:len(batch)]
        maxp = max(len(r.prompt) for r in batch)
        plen = self.sched.bucket_of(maxp)
        if plen >= self.max_len:
            # bucket rounds past the cache: fall back to the exact length
            # (one extra jit shape near the cap beats losing decode room)
            plen = maxp

        if self.admission == "gang":
            logits = self._admit_gang(batch, slots_idx, plen, has_live)
            rows = slots_idx
        else:
            logits = self._admit_per_slot(batch, slots_idx, plen)
            rows = list(range(len(batch)))

        now = time.perf_counter()
        nxt = np.asarray(self.sampler(logits, 1))
        for row, slot, req in zip(rows, slots_idx, batch):
            self.live[slot] = req
            self.pos[slot] = plen
            self.frozen_len[slot] = plen if self.dkv_rank else 0
            req.out_tokens.append(int(nxt[row]))
            req.t_first = req.t_last = now
            self.stats.ttft_s.append(now - req.t_submit)
        self.stats.prefills += len(batch)
        self.stats.prefill_batches += 1
        return len(batch)

    def _toks(self, batch: List[Request], rows: int, plen: int,
              row_of: Callable[[int], int]) -> np.ndarray:
        toks = np.zeros((rows, plen), np.int32)
        for j, req in enumerate(batch):
            toks[row_of(j), plen - len(req.prompt):] = req.prompt  # left-pad
        return toks

    def _admit_per_slot(self, batch: List[Request], slots_idx: List[int],
                        plen: int) -> Array:
        """Prefill ONLY the admitted requests (batch padded to a power of
        two so compile count stays O(log slots × max_len/bucket)) and
        splice the fresh rows into the live cache."""
        nb = min(_pow2(len(batch)), max(self.slots, 1))
        toks = self._toks(batch, nb, plen, lambda j: j)
        if self.dkv_rank:
            from ..models import decomposed_kv as DK
            logits, fresh = self._prefill_dkv(self.params, jnp.asarray(toks))
            if self.cache is None:
                self.cache = self._place(DK.init_cache(
                    self.cfg, self.slots, fresh["k_u"].shape[2],
                    fresh["k_u"].shape[-1], tail=self.dkv_tail))
            idx = np.asarray(slots_idx, np.int32)
            src = np.arange(len(slots_idx), dtype=np.int32)
            self.cache = self._splice_dkv(self.cache, fresh, idx, src)
        else:
            args = self._prefill_args(jnp.asarray(toks))
            logits, fresh = self._prefill(self.params, *args)
            idx = np.asarray(slots_idx, np.int32)
            src = np.arange(len(slots_idx), dtype=np.int32)
            self.cache = self._splice_fam(self.cache, fresh, idx, src,
                                          self.cfg)
        return logits

    def _admit_gang(self, batch: List[Request], slots_idx: List[int],
                    plen: int, has_live: bool) -> Array:
        """Legacy admission: prefill the WHOLE slot batch (idle and live
        slots compute padding), splice rows for the dense family, replace
        the cache wholesale otherwise (all slots are free by the gang
        restriction)."""
        toks = self._toks(batch, self.slots, plen,
                          lambda j: slots_idx[j])
        if self.dkv_rank:
            logits, self.cache = self._prefill_dkv(self.params,
                                                   jnp.asarray(toks))
        else:
            args = self._prefill_args(jnp.asarray(toks))
            logits, cache = self._prefill(self.params, *args)
            if has_live:
                idx = np.asarray(slots_idx, np.int32)
                cache = self._splice_fam(self.cache, cache, idx, idx,
                                         self.cfg)
            self.cache = cache
        return logits

    def _prefill_args(self, toks: Array):
        b, s = toks.shape
        if self.cfg.family == "vlm":
            img = jnp.zeros((b, self.cfg.num_image_tokens, self.cfg.d_model),
                            self.cfg.jax_dtype)
            return (toks, img)
        if self.cfg.family == "audio":
            # encoder memory length is cfg.num_audio_frames (the init_cache
            # cross-KV contract) — NOT the token prefix length
            frames = jnp.zeros((b, self.cfg.num_audio_frames,
                                self.cfg.d_model), self.cfg.jax_dtype)
            return (frames, toks)
        return (toks,)

    def _decode_round(self) -> List[Request]:
        tok = np.zeros((self.slots,), np.int32)
        for i, req in enumerate(self.live):
            if req is not None and req.out_tokens:
                tok[i] = req.out_tokens[-1]
        if self.dkv_rank:
            live_m = np.array([r is not None for r in self.live])
            occ = self.pos - self.frozen_len
            must = live_m & (occ >= self.dkv_tail)
            if must.any():
                # a slot's tail is full — fold it, and opportunistically
                # co-fold every live slot at least half full: co-folded
                # slots restart at occupancy 0 together, re-synchronizing
                # fold cadence under staggered admissions (fold ≈ one
                # event per TAIL decode rounds instead of one per slot).
                # A co-folded slot's unused tail rows are zeros and fold
                # as zero rows — exactness is unaffected.
                fold = must | (live_m & (occ >= max(1, self.dkv_tail // 2)))
                self.cache = self._compress_dkv(self.cache,
                                                jnp.asarray(self.frozen_len),
                                                jnp.asarray(fold))
                self.frozen_len = np.where(fold, self.pos,
                                           self.frozen_len).astype(np.int32)
                self.stats.tail_folds += int(fold.sum())
                # keep only the rows live slots reference (a finished
                # slot's stale frozen_len must not pin prefix memory)
                t_need = int(self.frozen_len[live_m].max())
                for key in ("k_u", "v_u"):
                    self.cache[key] = self.cache[key][:, :, :t_need]
            logits, self.cache = self._decode_dkv(
                self.params, jnp.asarray(tok), self.cache,
                jnp.asarray(self.pos), jnp.asarray(self.frozen_len))
        else:
            logits, self.cache = self._decode(self.params, jnp.asarray(tok),
                                              self.cache,
                                              jnp.asarray(self.pos))
        nxt = np.asarray(self.sampler(logits, 1))
        self.stats.decode_steps += 1
        now = time.perf_counter()
        done: List[Request] = []
        for i, req in enumerate(self.live):
            if req is None:
                continue
            self.pos[i] += 1
            req.out_tokens.append(int(nxt[i]))
            self.stats.tokens_out += 1
            self.stats.itl_s.append(now - req.t_last)
            req.t_last = now
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                req.t_done = now
                done.append(req)
                self.live[i] = None
        return done
