"""Serving engine: batched prefill/decode with continuous batching.

A slot-based engine (vLLM-style, sized for the dry-run meshes): ``slots``
concurrent sequences share one static KV cache; finished sequences free
their slot; queued requests prefill into free slots.

Admission with LIVE sequences present re-prefills the slot batch, so the
fresh cache rows are SPLICED into the live cache along the batch axis
(dense family; other families gang-admit when all slots are free —
documented limitation).  ``decompose_kv_rank`` serves the dense family on
the paper's low-rank KV cache (models.decomposed_kv): prefill decomposes
K/V, decode contracts through the factors, and the dense tail is folded
back (compress_tail) whenever it fills.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..engine import DecomposeEngine, EngineConfig
from ..models import api

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0


class Engine:
    """Continuous-batching engine over the unified model API.

    All sequences in a batch prefill together (same padded length); decode
    advances every live slot one token per step.
    """

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, sampler: Optional[Callable] = None,
                 decompose_kv_rank: Optional[int] = None,
                 dkv_tail: Optional[int] = None,
                 decompose_engine: Optional[DecomposeEngine] = None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.fns = api.model_fns(cfg)
        self.sampler = sampler or (lambda lg, k: jnp.argmax(lg, -1)
                                   .astype(jnp.int32))
        # One DecomposeEngine per serving engine: backend/hook selection
        # happens here, once, and every prefill decomposition reuses it.
        # An explicitly passed knob always wins (0 DISABLES decomposed KV);
        # None knobs inherit from the engine config when one is supplied.
        if decompose_engine is not None:
            self.dengine = decompose_engine
            if decompose_kv_rank is None:
                decompose_kv_rank = decompose_engine.config.kv_rank
            if dkv_tail is None:
                dkv_tail = decompose_engine.config.kv_tail
        else:
            decompose_kv_rank = decompose_kv_rank or 0
            if dkv_tail is None:
                dkv_tail = 16
            self.dengine = DecomposeEngine(EngineConfig(
                kv_rank=decompose_kv_rank, kv_tail=dkv_tail))
        self.dkv_rank = decompose_kv_rank
        self.dkv_tail = dkv_tail
        self.frozen_len = 0
        if self.dkv_rank:
            assert cfg.family == "dense", "decomposed KV: dense family"
            self.cache = None            # built at first prefill
        else:
            self.cache = self.fns.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.stats = EngineStats()

        self._decode = jax.jit(
            lambda p, t, c, pos: self.fns.decode_step(p, cfg, t, c, pos))

    # -- public API ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        t0 = time.time()
        finished: List[Request] = []
        for _ in range(max_steps):
            self._admit()
            if not any(self.live):
                if not self.queue:
                    break
                continue
            finished.extend(self._decode_round())
        self.stats.wall_s += time.time() - t0
        return finished

    # -- internals ---------------------------------------------------------
    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.live) if r is None]
        if not free or not self.queue:
            return
        has_live = any(r is not None for r in self.live)
        if has_live and (self.dkv_rank or self.cfg.family != "dense"):
            # gang admission: splice-merge is implemented for the dense
            # dense-cache path only (documented limitation)
            return
        batch = [self.queue.pop(0) for _ in free[:len(self.queue)]]
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((self.slots, plen), np.int32)
        new_mask = np.zeros((self.slots,), bool)
        for slot, req in zip(free, batch):
            toks[slot, plen - len(req.prompt):] = req.prompt   # left-pad
            self.live[slot] = req
            new_mask[slot] = True
        # Prefill the WHOLE slot batch (idle slots compute padding — the
        # static-shape trade; per-slot prefill would re-jit per length).
        if self.dkv_rank:
            from ..models import decomposed_kv as DK
            logits, cache = DK.prefill_dkv(self.params, self.cfg,
                                           jnp.asarray(toks), self.dkv_rank,
                                           tail=self.dkv_tail,
                                           engine=self.dengine)
            self.frozen_len = plen
            self.cache = cache
        else:
            args = self._prefill_args(jnp.asarray(toks))
            logits, cache = jax.jit(
                lambda p, *a: self.fns.prefill(p, self.cfg, *a,
                                               self.max_len))(self.params,
                                                              *args)
            if has_live:
                # splice fresh rows into the live cache (batch axis = 1 on
                # every dense-cache leaf [L, B, T, kvh, hd])
                m = jnp.asarray(new_mask)

                def splice(old, new):
                    mm = m.reshape((1, -1) + (1,) * (old.ndim - 2))
                    return jnp.where(mm, new, old)
                cache = jax.tree_util.tree_map(splice, self.cache, cache)
            self.cache = cache
        self.stats.prefills += 1
        for slot, req in zip(free, batch):
            self.pos[slot] = plen
            nxt = int(np.asarray(self.sampler(logits, 1))[slot])
            req.out_tokens.append(nxt)

    def _prefill_args(self, toks: Array):
        b, s = toks.shape
        if self.cfg.family == "vlm":
            img = jnp.zeros((b, self.cfg.num_image_tokens, self.cfg.d_model),
                            self.cfg.jax_dtype)
            return (toks, img)
        if self.cfg.family == "audio":
            frames = jnp.zeros((b, s, self.cfg.d_model), self.cfg.jax_dtype)
            return (frames, toks)
        return (toks,)

    def _decode_round(self) -> List[Request]:
        tok = np.zeros((self.slots,), np.int32)
        for i, req in enumerate(self.live):
            if req is not None and req.out_tokens:
                tok[i] = req.out_tokens[-1]
        if self.dkv_rank:
            from ..models import decomposed_kv as DK
            if int(self.pos.max()) - self.frozen_len >= self.dkv_tail:
                # tail full: fold into the low-rank prefix (amortized)
                self.cache = DK.compress_tail(self.cache, self.cfg,
                                              self.dkv_rank)
                self.frozen_len += self.dkv_tail
            logits, self.cache = DK.decode_step_dkv(
                self.params, self.cfg, jnp.asarray(tok), self.cache,
                jnp.asarray(self.pos), frozen_len=self.frozen_len)
        else:
            logits, self.cache = self._decode(self.params, jnp.asarray(tok),
                                              self.cache,
                                              jnp.asarray(self.pos))
        nxt = np.asarray(self.sampler(logits, 1))
        self.stats.decode_steps += 1
        done: List[Request] = []
        for i, req in enumerate(self.live):
            if req is None:
                continue
            self.pos[i] += 1
            req.out_tokens.append(int(nxt[i]))
            self.stats.tokens_out += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                done.append(req)
                self.live[i] = None
        return done
