"""Training CLI: fault-tolerant loop on reduced configs (CPU container) or
full configs (real TPU deployment — same code path, bigger mesh).

  PYTHONPATH=src python -m repro.launch.train --arch llama2-7b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os

from ..configs.base import ShapeSpec, get_arch
from ..runtime.driver import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    os.makedirs(args.ckpt_dir, exist_ok=True)
    res = train_loop(cfg, shape, total_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     seed=args.seed)
    print(f"done: step={res.step} final_loss={res.losses[-1]:.4f} "
          f"restarts={res.restarts} stragglers={res.straggler_flags}")


if __name__ == "__main__":
    main()
