"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init;
smoke tests see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16×16 ("data","model") or 2-pod 2×16×16 ("pod","data",
    "model").  512 placeholder devices are required for multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1×1 mesh on the real local device — smoke tests / examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
