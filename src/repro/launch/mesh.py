"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init;
smoke tests see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16×16 ("data","model") or 2-pod 2×16×16 ("pod","data",
    "model").  512 placeholder devices are required for multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """``(data, model)`` mesh on the local devices — smoke tests, examples,
    and mesh-parallel serving on forced host devices.  The no-arg form is
    the historical 1×1 mesh.  ``data×model`` must not exceed the local
    device count (force more with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE jax
    initializes — the CI distributed job and
    ``benchmarks/serving_sharded.py`` both do)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec):
    """CLI ``--mesh`` wiring → Mesh or None.

    * ``"none"``/``""``/None — no mesh (single-device serving),
    * ``"host"``             — every local device on the "data" axis
                               (DP serving; 1 device ⇒ a 1×1 mesh),
    * ``"DxM"`` (e.g. ``8x1``, ``4x2``) — explicit (data, model) shape.
    """
    if spec is None or spec in ("", "none", "off"):
        return None
    if spec == "host":
        return make_host_mesh(len(jax.devices()), 1)
    try:
        data, model = (int(n) for n in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"--mesh must be 'none', 'host', or 'DxM' (got {spec!r})")
    n_dev = len(jax.devices())
    if data * model > n_dev:
        raise ValueError(
            f"--mesh {spec} needs {data * model} devices but only {n_dev} "
            f"are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model} before "
            f"launching (the CI/benchmark harnesses force 8)")
    return make_host_mesh(data, model)
