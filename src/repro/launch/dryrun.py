"""Multi-pod dry-run (deliverable e) + roofline term extraction (deliverable g).

For every (architecture × shape × mesh) cell: build ShapeDtypeStruct inputs,
jit the step function with explicit in/out shardings, ``.lower().compile()``,
then record ``memory_analysis()`` / ``cost_analysis()`` and the collective
bytes parsed from the optimized HLO into ``results/dryrun/*.json``.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]
"""
# The VERY FIRST lines — before ANY other import — jax locks the device
# count at first init (see brief).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, all_archs, cells, get_arch
from ..distributed import sharding as sh
from ..ioutil import atomic_write_json
from ..models import api
from ..runtime import steps
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

from .roofline import (_COLLECTIVES, collective_stats, probe_plan,
                       roofline_terms)

# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(arch_name: str, shape_name: str, mesh,
               *, decomposed_kv: int = 0, remat: Optional[bool] = None,
               zero1: bool = True, microbatches: int = 1,
               seq_parallel: bool = False, moe_shard_map: bool = False,
               remat_policy: Optional[str] = None,
               capacity_factor: float = 0.0):
    """(step_fn, abstract_args, in_shardings, out_shardings) for one cell.

    ``decomposed_kv`` > 0 switches decode cells to the low-rank KV cache at
    that rank (models.decomposed_kv) — the paper's technique as a serving
    feature; ``seq_parallel`` turns on Megatron-SP residual sharding.
    """
    cfg = get_arch(arch_name)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if seq_parallel:
        cfg = cfg.replace(seq_parallel=True)
    if remat_policy is not None:
        cfg = cfg.replace(remat_policy=remat_policy)
    if capacity_factor:
        cfg = cfg.replace(capacity_factor=capacity_factor)
    from ..models import moe as moe_mod
    moe_mod.SHARD_MAP_MESH = mesh if moe_shard_map else None
    shape = SHAPES[shape_name]
    dp = sh.dp_axes(mesh)
    dp_name = dp if len(dp) > 1 else dp[0]

    params_abs = api.abstract_params(cfg)
    params_shd = sh.params_sharding(params_abs, mesh, cfg)

    if shape.kind == "train":
        step = steps.make_train_step(cfg, microbatches=microbatches)
        _, opt_abs = steps.abstract_train_state(cfg)
        opt_shd = sh.opt_state_sharding(opt_abs, mesh, cfg, zero1=zero1)
        batch_abs = api.train_batch_specs(cfg, shape)
        batch_shd = sh.batch_sharding(batch_abs, mesh)
        metrics_shd = {"loss": sh.replicated(mesh),
                       "grad_norm": sh.replicated(mesh)}
        return (step, (params_abs, opt_abs, batch_abs),
                (params_shd, opt_shd, batch_shd),
                (params_shd, opt_shd, metrics_shd))

    if shape.kind == "prefill":
        step = steps.make_prefill_step(cfg)
        inputs_abs = api.prefill_input_specs(cfg, shape)
        inputs_shd = sh.batch_sharding(inputs_abs, mesh)
        cache_abs = jax.eval_shape(
            lambda: api.model_fns(cfg).init_cache(cfg, shape.global_batch,
                                                  shape.seq_len))
        cache_shd = sh.cache_sharding(cache_abs, mesh, cfg)
        logits_shd = NamedSharding(
            mesh, P(dp_name if shape.global_batch % sh.axis_size(mesh, dp)
                    == 0 else None))
        return (step, (params_abs,) + tuple(inputs_abs),
                (params_shd,) + tuple(inputs_shd),
                (logits_shd, cache_shd))

    # decode
    if decomposed_kv:
        from ..models import decomposed_kv as DK
        shape_obj = shape
        frozen = shape_obj.seq_len - DK.TAIL

        def step(params, token, cache, pos):
            return DK.decode_step_dkv(params, cfg, token, cache, pos,
                                      frozen_len=frozen)
        tok_abs = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos_abs = tok_abs
        cache_abs = jax.eval_shape(
            lambda: DK.init_cache(cfg, shape.global_batch, frozen,
                                  decomposed_kv))
        cache_shd = sh.cache_sharding(cache_abs, mesh, cfg)
        tok_shd = sh.token_sharding(mesh, shape.global_batch)
        logits_shd = NamedSharding(
            mesh, P(dp_name if shape.global_batch % sh.axis_size(mesh, dp)
                    == 0 and shape.global_batch > 1 else None))
        return (step, (params_abs, tok_abs, cache_abs, pos_abs),
                (params_shd, tok_shd, cache_shd, tok_shd),
                (logits_shd, cache_shd))

    step = steps.make_decode_step(cfg)
    tok_abs, cache_abs, pos_abs = api.decode_input_specs(cfg, shape)
    cache_shd = sh.cache_sharding(cache_abs, mesh, cfg)
    tok_shd = sh.token_sharding(mesh, shape.global_batch)
    logits_shd = NamedSharding(
        mesh, P(dp_name if shape.global_batch % sh.axis_size(mesh, dp) == 0
                and shape.global_batch > 1 else None))
    return (step, (params_abs, tok_abs, cache_abs, pos_abs),
            (params_shd, tok_shd, cache_shd, tok_shd),
            (logits_shd, cache_shd))


# ---------------------------------------------------------------------------
# Cost calibration: XLA's cost_analysis counts a while-loop body ONCE, so
# scanned layers/chunks under-report FLOPs / bytes / collectives by ~L×.
# We lower two SMALL fully-unrolled probes (layers.COST_EXACT) and
# extrapolate the per-repeating-unit cost linearly to the full depth.
# ---------------------------------------------------------------------------

def _cell_costs(arch_cfg, shape_name: str, mesh, kw,
                donate: bool = False) -> Dict[str, Any]:
    """Lower+compile one config; return flops/bytes/collective stats."""
    from ..configs import base as cfgbase
    # temporarily register the probe config under a unique name
    name = arch_cfg.name
    cfgbase._REGISTRY[name] = arch_cfg
    step, args, in_shd, out_shd = build_cell(name, shape_name, mesh, **kw)
    donate_argnums = _donation(SHAPES[shape_name].kind, donate)
    with mesh:
        compiled = jax.jit(step, in_shardings=in_shd,
                           out_shardings=out_shd,
                           donate_argnums=donate_argnums).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def calibrate(arch_name: str, shape_name: str, mesh, kw,
              donate: bool = False) -> Dict[str, Any]:
    """Unrolled small-L probes → extrapolated full-depth costs."""
    from ..models import layers as Lmod
    cfg = get_arch(arch_name)
    plan, n_full = probe_plan(cfg)
    (p1, n1), (p2, n2) = plan
    Lmod.COST_EXACT = True
    try:
        c1 = _cell_costs(p1.replace(name=arch_name + "@probe1"),
                         shape_name, mesh, kw, donate)
        c2 = _cell_costs(p2.replace(name=arch_name + "@probe2"),
                         shape_name, mesh, kw, donate)
    finally:
        Lmod.COST_EXACT = False

    def lin(a, b):
        per = (b - a) / (n2 - n1)
        return a + per * (n_full - n1)

    coll = {}
    for k in _COLLECTIVES:
        coll[k] = {"bytes": max(0.0, lin(c1["coll"][k]["bytes"],
                                         c2["coll"][k]["bytes"])),
                   "count": max(0.0, lin(c1["coll"][k]["count"],
                                         c2["coll"][k]["count"]))}
    return {"flops": max(0.0, lin(c1["flops"], c2["flops"])),
            "bytes": max(0.0, lin(c1["bytes"], c2["bytes"])),
            "coll": coll,
            "probes": {"n1": n1, "n2": n2, "n_full": n_full,
                       "c1": c1, "c2": c2}}


def _donation(shape_kind: str, donate: bool):
    if not donate:
        return ()
    return {"train": (0, 1), "prefill": (), "decode": (2,)}[shape_kind]


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             calibrated: bool = True, donate: bool = False,
             **kw) -> Dict[str, Any]:
    """Lower + compile one cell; extract roofline inputs."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_arch(arch_name)
    t0 = time.perf_counter()
    step, args, in_shd, out_shd = build_cell(arch_name, shape_name, mesh,
                                             **kw)
    donate_argnums = _donation(SHAPES[shape_name].kind, donate)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_shd,
                          out_shardings=out_shd,
                          donate_argnums=donate_argnums).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    hbm_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    calib = None
    if calibrated:
        calib = calibrate(arch_name, shape_name, mesh, kw, donate)
        flops, hbm_bytes, coll = calib["flops"], calib["bytes"], calib["coll"]
    terms = roofline_terms(flops, hbm_bytes, coll)

    n_chips = mesh.devices.size
    shape = SHAPES[shape_name]
    n_active = api.active_param_count(cfg)
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * shape.global_batch        # one token

    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": int(n_chips),
        "kind": shape.kind,
        "options": {k: str(v) for k, v in kw.items()},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "calibrated": bool(calib),
        "calibration": (calib or {}).get("probes"),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": float(model_flops),
        "model_flops_per_device": float(model_flops) / n_chips,
        "useful_flops_ratio": (float(model_flops) / n_chips / flops)
        if flops else None,
        "memory_analysis": {},
    }
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec["memory_analysis"][attr] = int(v)
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    rec["dominant_term"] = dom
    rec["roofline_fraction"] = (
        terms["compute_s"] / max(terms["compute_s"], terms["memory_s"],
                                 terms["collective_s"], 1e-30))
    return rec


def save_record(rec: Dict[str, Any], tag: str = "") -> str:
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    path = os.path.join(RESULTS_DIR, name)
    atomic_write_json(path, rec, indent=2)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--decomposed-kv", type=int, default=0)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--score-bf16", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--ssd-chunk", type=int, default=0)
    args = ap.parse_args()

    todo = []
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        for name, cfg in sorted(all_archs().items()):
            if name == "llama2-7b":
                continue               # paper model: benchmarks, not a cell
            for shp in cells(cfg):
                for mp in meshes:
                    todo.append((name, shp, mp))
    else:
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    kw = {}
    if args.no_calibrate:
        kw["calibrated"] = False
    if args.no_remat:
        kw["remat"] = False
    if args.no_zero1:
        kw["zero1"] = False
    if args.microbatches != 1:
        kw["microbatches"] = args.microbatches
    if args.decomposed_kv:
        kw["decomposed_kv"] = args.decomposed_kv
    if args.seq_parallel:
        kw["seq_parallel"] = True
    if args.moe_shard_map:
        kw["moe_shard_map"] = True
    if args.remat_policy:
        kw["remat_policy"] = args.remat_policy

    donate = args.donate
    if args.score_bf16:
        from ..models import layers as Lmod
        import jax.numpy as _jnp
        Lmod.SCORE_DTYPE = _jnp.bfloat16
    if args.attn_chunk:
        from ..models import layers as Lmod
        Lmod.ATTN_CHUNK = args.attn_chunk
    if args.ssd_chunk:
        from ..models import mamba2 as M2mod
        M2mod.CHUNK = args.ssd_chunk
    if args.capacity_factor:
        kw["capacity_factor"] = args.capacity_factor
    failures = []
    for arch, shp, mp in todo:
        mtag = "multi" if mp else "single"
        out = os.path.join(RESULTS_DIR, f"{arch}_{shp}_{mtag}{args.tag}.json")
        if args.skip_existing and os.path.exists(out):
            print(f"[skip] {arch} × {shp} × {mtag}")
            continue
        print(f"[cell] {arch} × {shp} × {mtag} ...", flush=True)
        try:
            rec = run_cell(arch, shp, mp, donate=donate, **kw)
            path = save_record(rec, args.tag)
            t = rec["roofline"]
            print(f"  ok  compile={rec['compile_s']}s "
                  f"compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s"
                  f" coll={t['collective_s']:.3e}s dom={rec['dominant_term']}"
                  f" -> {os.path.basename(path)}", flush=True)
        except Exception:
            failures.append((arch, shp, mtag))
            print(f"  FAIL {arch} × {shp} × {mtag}\n{traceback.format_exc()}",
                  flush=True)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all cells green")


if __name__ == "__main__":
    main()
