"""Serving CLI: continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_arch
from ..models import api
from ..serving import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    fns = api.model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, cfg.vocab, args.prompt_len,
                                              dtype=np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.out_tokens}")
    s = eng.stats
    print(f"stats: prefills={s.prefills} decode_steps={s.decode_steps} "
          f"tokens={s.tokens_out} wall={s.wall_s:.2f}s "
          f"tok/s={s.tokens_out / max(s.wall_s, 1e-9):.1f}")


if __name__ == "__main__":
    main()
