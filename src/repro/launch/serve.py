"""Serving CLI: continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8

Decomposed-KV serving (the paper's activation decomposition applied to the
KV stream) rides one DecomposeEngine, constructed here from the CLI flags
and handed to the serving engine:

  ... --decompose-kv-rank 8 --dkv-tail 16 --backend pallas_interpret
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_arch
from ..engine import DecomposeEngine, EngineConfig, available_backends
from ..models import api
from ..serving import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--decompose-kv-rank", type=int, default=0,
                    help="serve the low-rank KV cache at this rank (0=off)")
    ap.add_argument("--dkv-tail", type=int, default=16,
                    help="dense recent-token tail length")
    ap.add_argument("--backend", default="reference",
                    choices=available_backends(),
                    help="decomposition backend for the engine")
    ap.add_argument("--expansion", type=int, default=8,
                    help="D-com compute-expansion factor f")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    fns = api.model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    dengine = DecomposeEngine(EngineConfig(
        backend=args.backend, expansion=args.expansion,
        kv_rank=args.decompose_kv_rank, kv_tail=args.dkv_tail))
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 decompose_kv_rank=args.decompose_kv_rank,
                 dkv_tail=args.dkv_tail, decompose_engine=dengine)

    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, cfg.vocab, args.prompt_len,
                                              dtype=np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.out_tokens}")
    s = eng.stats
    print(f"engine: {dengine}")
    print(f"stats: prefills={s.prefills} decode_steps={s.decode_steps} "
          f"tokens={s.tokens_out} wall={s.wall_s:.2f}s "
          f"tok/s={s.tokens_out / max(s.wall_s, 1e-9):.1f}")


if __name__ == "__main__":
    main()
