"""Serving CLI: continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8

The engine is family-generic (``repro.serving.families``): ``--family
ssm|moe|hybrid|dense`` serves that family's default reduced arch on the
same slot/fused/async machinery, e.g.

  PYTHONPATH=src python -m repro.launch.serve --family ssm --requests 8

Decomposed-KV serving (the paper's activation decomposition applied to the
KV stream) rides one DecomposeEngine, constructed here from the CLI flags
and handed to the serving engine:

  ... --decompose-kv-rank 8 --dkv-tail 16 --backend pallas_interpret

``--backend auto`` / ``--expansion auto`` resolve through the ``repro.tune``
autotuner; with ``--expansion auto`` warmup PRE-TUNES the prefill
decomposition shape this serving config will actually launch (the bucketed
prompt length through the lanczos_reorth kernel family), so the first
request pays no tuning cost and the resolved operating point is printed
before traffic starts.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_arch
from ..engine import DecomposeEngine, EngineConfig, available_backends
from ..models import api
from ..obs import (GLOBAL, Observability, compile_stats, write_json_snapshot,
                   write_prometheus)
from ..serving import Engine, Request
from .mesh import parse_mesh


# default arch per serving family for `--family NAME` without `--arch`
_FAMILY_DEFAULT_ARCH = {
    "dense": "llama2-7b",
    "ssm": "mamba2-780m",
    "moe": "olmoe-1b-7b",
    "hybrid": "zamba2-1.2b",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture name (required unless --family "
                         "picks its default arch)")
    ap.add_argument("--family", default=None,
                    choices=sorted(_FAMILY_DEFAULT_ARCH),
                    help="serve this family's default arch (ssm = "
                         "mamba2-780m, moe = olmoe-1b-7b, hybrid = "
                         "zamba2-1.2b, dense = llama2-7b); --arch "
                         "overrides the arch, and the engine checks it "
                         "really is that family")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--decompose-kv-rank", type=int, default=0,
                    help="serve the low-rank KV cache at this rank (0=off)")
    ap.add_argument("--dkv-tail", type=int, default=16,
                    help="dense recent-token tail length")
    ap.add_argument("--dkv-exact", action="store_true",
                    help="direct-SVD KV factorization (near-full rank)")
    ap.add_argument("--paged", action="store_true",
                    help="paged decomposed-KV cache (block tables over "
                         "fixed-size page pools instead of a static slab)")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool size in pages (0 = auto-sized from "
                         "slots x max-len with fold headroom)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per page (prefix U rows / dense tail rows)")
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="shared-prefix cache capacity in entries (0 = "
                         "off; hits admit with tail-only work, skipping "
                         "the prefix forward pass AND its Lanczos)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id: requests finish (and free their "
                         "slot) the moment they emit it")
    ap.add_argument("--backend", default="reference",
                    choices=available_backends() + ["auto"],
                    help="decomposition backend for the engine "
                         "(auto = tuner-resolved)")
    ap.add_argument("--expansion", default="8",
                    help="D-com compute-expansion factor f, or 'auto' "
                         "(tuner-resolved per shape-bucket)")
    ap.add_argument("--no-pretune", action="store_true",
                    help="skip the warmup pre-tuning pass")
    ap.add_argument("--admission", default="per_slot",
                    choices=("per_slot", "gang"),
                    help="admission policy (gang = legacy, for A/B)")
    ap.add_argument("--sched-bucket", type=int, default=16,
                    help="prefill length bucket (bounds re-jits)")
    ap.add_argument("--admit-every", type=int, default=1,
                    help="decode rounds between admission checks")
    ap.add_argument("--max-admit", type=int, default=0,
                    help="max requests per admission batch (0=free slots)")
    ap.add_argument("--mesh", default="none",
                    help="serving mesh: 'none' (default), 'host' (all "
                         "local devices on the data axis), or 'DxM' (e.g. "
                         "8x1; force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--decode-block", default="1",
                    help="fused decode steps per device launch: N, or "
                         "'auto' (tuner-resolved).  1 = classic per-token "
                         "dispatch; N>1 runs up to N steps in one jitted "
                         "on-device loop, token-identical output")
    ap.add_argument("--prefill-async", action="store_true",
                    help="disaggregated prefill/decode: admissions "
                         "(forward prefill + Lanczos) dispatch "
                         "asynchronously and splice into slots when "
                         "ready — decode never blocks on an in-flight "
                         "decomposition")
    ap.add_argument("--ready-order", default="ready",
                    choices=("ready", "deterministic"),
                    help="async splice order: 'ready' (as results "
                         "complete) or 'deterministic' (inline at the "
                         "dispatch round — byte-identical tokens to the "
                         "synchronous engine, for conformance A/Bs)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition of every "
                         "metric (engine stats + decomposition/tuner/"
                         "compile telemetry) here at exit; '-.json' "
                         "suffix writes the JSON snapshot instead")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request-lifecycle spans and write "
                         "Chrome trace-event JSON (Perfetto-loadable) "
                         "here at exit")
    ap.add_argument("--stats-every", type=int, default=0, metavar="N",
                    help="print a p50/p95/p99 stats snapshot every N "
                         "engine steps (0 = only the final summary)")
    args = ap.parse_args()

    if args.arch is None:
        if args.family is None:
            ap.error("one of --arch / --family is required")
        args.arch = _FAMILY_DEFAULT_ARCH[args.family]
    mesh = parse_mesh(args.mesh)
    cfg = get_arch(args.arch).reduced()
    if args.family is not None and cfg.family != args.family:
        ap.error(f"--arch {args.arch} is family {cfg.family!r}, "
                 f"not {args.family!r}")
    fns = api.model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    expansion = args.expansion if args.expansion == "auto" \
        else int(args.expansion)
    decode_block = args.decode_block if args.decode_block == "auto" \
        else int(args.decode_block)
    dengine = DecomposeEngine(EngineConfig(
        backend=args.backend, expansion=expansion,
        kv_rank=args.decompose_kv_rank, kv_tail=args.dkv_tail,
        kv_exact=args.dkv_exact, kv_page=args.page_size,
        kv_pool_pages=args.pages, kv_prefix_cache=args.prefix_cache,
        sched_bucket=args.sched_bucket,
        sched_admit_every=args.admit_every, sched_max_admit=args.max_admit,
        decode_block=decode_block, mesh=mesh))

    if expansion == "auto" and not args.no_pretune:
        # Serving warmup: resolve the tuned operating points for the
        # shapes this config will actually launch — per-slot admission
        # prefills pow2(len(admitted)) ≤ slots requests, and the flat
        # prefill decomposition engine.decompose_kv runs through the
        # lanczos_reorth family is [num_layers·nb, plen_bucket, kvw] —
        # so every pow2 admission batch gets its bucket warmed before
        # traffic starts.  (Pointless for a fixed --expansion: resolution
        # never consults the tuner then.)
        from .. import tune
        plen = -(-args.prompt_len // max(1, args.sched_bucket)) \
            * max(1, args.sched_bucket)
        kvw = cfg.num_kv_heads * cfg.resolved_head_dim
        slots = max(1, args.slots)
        nbs, nb = {slots}, 1             # nb = min(pow2(admitted), slots)
        while nb < slots:
            nbs.add(nb)
            nb *= 2
        pre = tune.pretune(
            {"lanczos_reorth": [(cfg.num_layers * n, plen, kvw)
                                for n in sorted(nbs)]},
            fix={"backend": dengine.resolved_backend})
        for key, res in pre.items():
            print(f"pretune[{res.kernel}]: f={res.best['expansion']} "
                  f"({res.source}, {key})")

    obs = Observability(trace=args.trace_out is not None)
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 decompose_kv_rank=args.decompose_kv_rank,
                 dkv_tail=args.dkv_tail, decompose_engine=dengine,
                 admission=args.admission, paged=args.paged,
                 eos_id=args.eos_id, prefill_async=args.prefill_async,
                 ready_order=args.ready_order, obs=obs)

    rng = np.random.RandomState(0)
    for i in range(args.requests):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, cfg.vocab, args.prompt_len,
                                              dtype=np.int32),
                           max_new_tokens=args.max_new))
    if args.stats_every > 0:
        # drive step() directly so periodic snapshots land on step edges
        done, steps = [], 0
        while steps < 10_000:
            done.extend(eng.step())
            steps += 1
            if steps % args.stats_every == 0:
                print(_pctl_line(eng.stats, prefix=f"step {steps}: "))
            if not eng._occupied() and not len(eng.sched):
                break
    else:
        done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.out_tokens}")
    s = eng.stats
    mesh_desc = "none" if mesh is None else \
        "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    async_desc = f"async({eng.ready_order})" if eng.prefill_async else "sync"
    print(f"engine: {dengine}  family={cfg.family}"
          f"[{type(eng.family).__name__}]  admission={args.admission}  "
          f"mesh={mesh_desc} ({len(jax.devices())} devices)  "
          f"decode_block={eng.decode_block}  prefill={async_desc}")
    print(f"stats: prefills={s.prefills} batches={s.prefill_batches} "
          f"decode_steps={s.decode_steps} blocks={s.blocks} "
          f"folds={s.tail_folds} stalls={s.stalls} "
          f"inflight_peak={s.prefill_inflight_peak} "
          f"tokens={s.tokens_out} stopped_eos={s.stopped_eos} "
          f"stopped_budget={s.stopped_budget} wall={s.wall_s:.2f}s "
          f"tok/s={s.tokens_out / max(s.wall_s, 1e-9):.1f} "
          f"ttft={s.mean_ttft_s * 1e3:.1f}ms "
          f"(queue={s.mean_ttft_queue_s * 1e3:.1f}ms "
          f"compute={s.mean_ttft_compute_s * 1e3:.1f}ms) "
          f"itl={s.mean_itl_s * 1e3:.1f}ms")
    print(_pctl_line(s))
    if eng.pager is not None:
        pg = eng.pager
        line = (f"paged: page={pg.page} pool={pg.num_pages}p "
                f"free={pg.alloc.free_pages}p "
                f"pool_bytes={pg.pool_bytes}")
        if pg.prefix is not None:
            line += (f" prefix_hits={s.prefix_hits} "
                     f"prefix_misses={s.prefix_misses} "
                     f"entries={len(pg.prefix)}")
        print(line)

    cw = compile_stats()
    if cw:
        print("compiles: " + " ".join(
            f"{ph}={d['compiles']}({d['seconds']:.2f}s)"
            for ph, d in sorted(cw.items())))
    if args.metrics_out:
        # engine registry (serving_*) + the process GLOBAL registry
        # (decompose/tuner/compile telemetry) in one exposition
        if args.metrics_out.endswith(".json"):
            write_json_snapshot(args.metrics_out, obs.registry, GLOBAL)
        else:
            write_prometheus(args.metrics_out, obs.registry, GLOBAL)
        print(f"metrics: wrote {args.metrics_out}")
    if args.trace_out:
        obs.tracer.export(args.trace_out)
        print(f"trace: wrote {args.trace_out} "
              f"({len(obs.tracer.events)} events, "
              f"{obs.tracer.dropped} dropped)")


def _pctl_line(s, prefix: str = "") -> str:
    """p50/p95/p99 TTFT + ITL line from the streaming histograms."""
    def pct(series):
        return "/".join(f"{series.quantile(q) * 1e3:.1f}"
                        for q in (0.5, 0.95, 0.99))
    return (f"{prefix}pctl: ttft_ms p50/p95/p99={pct(s.ttft_s)} "
            f"itl_ms p50/p95/p99={pct(s.itl_s)} "
            f"tokens={s.tokens_out}")


if __name__ == "__main__":
    main()
