"""Pure roofline helpers (no jax device-state side effects on import).

``launch.dryrun`` (which MUST set XLA_FLAGS before any jax import) re-uses
these; tests import from here so the pytest process keeps its single
device.
"""
from __future__ import annotations

import re
from typing import Any, Dict

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (roofline denominators)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "u64": 8, "s64": 8,
                "u32": 4, "s32": 4, "u16": 2, "s16": 2, "u8": 1, "s8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Ring-schedule per-device traffic multiplier relative to RESULT bytes
# (documented convention, EXPERIMENTS.md §Roofline): all-reduce moves ~2×
# payload per device; all-gather/reduce-scatter/all-to-all/permute ~1×.
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every shape literal in an HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-collective-type payload bytes + op counts from optimized HLO."""
    stats = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?\S+ = (\([^)]*\)|\S+) ([a-z\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        # normalize fusion-start variants like "all-gather-start"
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            stats[base]["bytes"] += _shape_bytes(m.group(1))
            stats[base]["count"] += 1
    return stats


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   coll: Dict[str, Any]) -> Dict[str, float]:
    """Three roofline terms in seconds (all PER-DEVICE quantities).

    cost_analysis of the SPMD-partitioned module is per-device, so we divide
    by single-chip peaks (equivalent to global/chips — see EXPERIMENTS.md).
    """
    coll_bytes = sum(v["bytes"] * _RING_FACTOR[k] for k, v in coll.items())
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS,
        "memory_s": hbm_bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
        "collective_bytes": coll_bytes,
    }


def probe_plan(cfg):
    """[(probe_cfg, n_units)] ×2 + n_units_full for linear extrapolation of
    while-body-undercounted costs (see dryrun.calibrate)."""
    if cfg.family == "vlm":
        per = cfg.cross_attn_period
        mk = lambda g: cfg.replace(num_layers=g * per)
        return [(mk(1), 1), (mk(2), 2)], cfg.num_layers // per
    if cfg.family == "hybrid":
        per = cfg.attn_period
        mk = lambda g: cfg.replace(num_layers=g * per)
        # tail mamba layers folded into the per-layer average (documented)
        return [(mk(1), per), (mk(2), 2 * per)], cfg.num_layers
    if cfg.family == "audio":
        mk = lambda p: cfg.replace(num_layers=2 * p, enc_layers=p,
                                   num_audio_frames=cfg.num_audio_frames)
        return [(mk(1), 1), (mk(2), 2)], cfg.enc_layers
    if cfg.family == "moe" and cfg.first_k_dense:
        mk = lambda m: cfg.replace(num_layers=cfg.first_k_dense + m)
        return [(mk(1), 1), (mk(2), 2)], cfg.num_layers - cfg.first_k_dense
    mk = lambda n: cfg.replace(num_layers=n)
    return [(mk(1), 1), (mk(2), 2)], cfg.num_layers
