"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--tag ""]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

FIX_NOTES = {
    ("train", "collective_s"): ("shrink DP-gradient / FSDP all-gathers: "
                                "grad compression, 2D-sharding rebalance, or "
                                "larger per-step compute (microbatching)"),
    ("train", "memory_s"): ("cut activation traffic: larger fused attention "
                            "blocks, bf16 score path, selective remat"),
    ("train", "compute_s"): "already compute-bound — good; tune MXU tiling",
    ("prefill", "collective_s"): ("all-gather of TP activations dominates: "
                                  "sequence-shard attention (ring) or "
                                  "reduce-scatter the FFN outputs"),
    ("prefill", "memory_s"): "KV write + score traffic: fuse QK/PV chunks",
    ("prefill", "compute_s"): "compute-bound — good",
    ("decode", "memory_s"): ("decode is KV-bandwidth-bound by nature: "
                             "decomposed/quantized KV track shrinks bytes"),
    ("decode", "collective_s"): "TP all-reduce per token: wider DP, fuse",
    ("decode", "compute_s"): "unusual for decode — check batching",
}


import re as _re

_BASE_RE = _re.compile(r"^(.+)_(train_4k|prefill_32k|decode_32k|long_500k)"
                       r"_(single|multi)$")


def load(tag: str = "") -> List[Dict]:
    """tag="" loads ONLY untagged baseline cells; tag="_x" loads that
    variant."""
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        if tag:
            if not base.endswith(tag):
                continue
        elif not _BASE_RE.match(base):
            continue
        recs.append(json.load(open(f)))
    return recs


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | useful FLOP ratio | bytes/device | fix |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        mem_gb = (r["memory_analysis"].get("argument_size_in_bytes", 0)
                  + r["memory_analysis"].get("temp_size_in_bytes", 0)) / 1e9
        fix = FIX_NOTES.get((r["kind"], r["dominant_term"]), "")
        ufr = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"{r['dominant_term'].replace('_s', '')} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{'' if ufr is None else f'{ufr:.2f}'} | {mem_gb:.1f} GB | "
            f"{fix} |")
    return "\n".join(out)


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | chips | compile s | FLOPs/dev | "
           "HBM bytes/dev | coll bytes/dev | args GB | temp GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ma = r["memory_analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']} | {r['flops_per_device']:.2e} | "
            f"{r['hbm_bytes_per_device']:.2e} | "
            f"{r['roofline']['collective_bytes']:.2e} | "
            f"{ma.get('argument_size_in_bytes', 0) / 1e9:.2f} | "
            f"{ma.get('temp_size_in_bytes', 0) / 1e9:.2f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = [r for r in load() if True]
    print("## §Dry-run (both meshes)\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod, per-device terms)\n")
    print(roofline_table(recs, "single"))


if __name__ == "__main__":
    main()
