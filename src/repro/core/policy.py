"""Per-layer decomposition policy (paper §6.2's configuration axes).

The paper's design space: WHICH layers decompose (non-adjacent preferred),
at what RANK (1/10/20), with what OUTLIER fraction (~3%), input-only vs
input+weight, and whether outputs stay in preserved form.  This module is the
single source of truth consulted by ``models/decomposed.py``; the Table 2/3
benchmark sweeps construct policies directly from the paper's rows.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence

from .outlier import ThresholdTable


@dataclasses.dataclass(frozen=True)
class LayerPolicy:
    """Decomposition directive for one transformer layer."""
    decompose: bool = False
    rank: int = 10
    iters: Optional[int] = None          # Lanczos iterations (default: rank)
    outlier_frac: float = 0.03           # fraction of H channels extracted
    decompose_weights: bool = False      # input+weight mode (paper Table 3)
    weight_rank: int = 10
    preserve_output: bool = True         # paper §3.2 output-preserved compute
    expansion_factor: int = 8            # D-com kernel grid factor f

    @property
    def effective_iters(self) -> int:
        return self.rank if self.iters is None else self.iters


@dataclasses.dataclass
class DecompositionPolicy:
    """Whole-model policy: default + per-layer overrides + threshold table."""
    num_layers: int
    default: LayerPolicy = dataclasses.field(default_factory=LayerPolicy)
    overrides: Dict[int, LayerPolicy] = dataclasses.field(default_factory=dict)
    thresholds: ThresholdTable = dataclasses.field(
        default_factory=ThresholdTable)

    def layer(self, idx: int) -> LayerPolicy:
        return self.overrides.get(int(idx), self.default)

    def decomposed_layers(self) -> Sequence[int]:
        return [i for i in range(self.num_layers) if self.layer(i).decompose]

    # -- constructors matching the paper's experiment tables ---------------
    @classmethod
    def none(cls, num_layers: int) -> "DecompositionPolicy":
        return cls(num_layers=num_layers,
                   default=LayerPolicy(decompose=False))

    @classmethod
    def from_layer_list(cls, num_layers: int, layers: Sequence[int],
                        rank: int = 10, outlier_frac: float = 0.03,
                        decompose_weights: bool = False,
                        weight_rank: Optional[int] = None,
                        iters: Optional[int] = None,
                        expansion_factor: int = 8) -> "DecompositionPolicy":
        """Paper Table 2/3 row: e.g. layers=[10,15,20,25], rank=20."""
        on = LayerPolicy(decompose=True, rank=rank, iters=iters,
                         outlier_frac=outlier_frac,
                         decompose_weights=decompose_weights,
                         weight_rank=weight_rank or rank,
                         expansion_factor=expansion_factor)
        return cls(num_layers=num_layers,
                   default=LayerPolicy(decompose=False),
                   overrides={int(i): on for i in layers})

    @classmethod
    def all_layers(cls, num_layers: int, rank: int = 1,
                   outlier_frac: float = 0.065,
                   decompose_weights: bool = False) -> "DecompositionPolicy":
        """Paper's 'All Layers (Most aggressive)' row."""
        return cls(num_layers=num_layers,
                   default=LayerPolicy(decompose=True, rank=rank,
                                       outlier_frac=outlier_frac,
                                       decompose_weights=decompose_weights))

    def has_adjacent_decomposed(self) -> bool:
        """Paper/[16]: adjacent decomposed layers hurt quality — flag them."""
        ls = sorted(self.decomposed_layers())
        return any(b - a == 1 for a, b in zip(ls, ls[1:]))

    # -- persistence --------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "num_layers": self.num_layers,
            "default": dataclasses.asdict(self.default),
            "overrides": {str(k): dataclasses.asdict(v)
                          for k, v in self.overrides.items()},
            "thresholds": {"default": self.thresholds.default,
                           "table": {str(k): v for k, v in
                                     self.thresholds.thresholds.items()}},
        }, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "DecompositionPolicy":
        d = json.loads(s)
        tt = ThresholdTable(
            thresholds={int(k): float(v)
                        for k, v in d["thresholds"]["table"].items()},
            default=float(d["thresholds"]["default"]))
        return cls(num_layers=int(d["num_layers"]),
                   default=LayerPolicy(**d["default"]),
                   overrides={int(k): LayerPolicy(**v)
                              for k, v in d["overrides"].items()},
                   thresholds=tt)


# The paper's Table 2 layer-choice configurations (Llama-2-7b, 32 layers).
PAPER_LAYER_CONFIGS = {
    "4layer": [10, 15, 20, 25],
    "6layer": [6, 10, 14, 18, 22, 26],
    "8layer": [7, 10, 13, 16, 19, 22, 25, 28],
    "10layer": [9, 10, 13, 14, 17, 18, 21, 22, 26, 27],
}
PAPER_BEST_CONFIG = ("10layer", 20)   # highlighted row: 0.78×, 70.15% acc
