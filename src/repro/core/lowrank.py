"""Low-rank activation representation (the paper's central data structure).

An activation matrix ``X [S, H]`` is represented as ``U @ core @ Vt`` where

* ``U  [S, k]``   — left factor (token subspace),
* ``core``        — either a vector ``[k]`` (diagonal, fresh SVD output) or a
                    dense matrix ``[k, k2]`` (after input+weight preserved
                    contractions, paper Eq. 7),
* ``Vt [k2, H]``  — right factor (channel subspace).

The optional *outlier track* (paper §4, "multi-track decomposition") carries
the extracted outlier channels either densely (``ov [S, C]``) or themselves
decomposed (``o_u/o_core/o_vt``), together with the static-size channel index
vector ``o_idx [C]``.  ``Vt`` of the base track always lives in the *original*
H-sized channel space with the outlier channels zeroed, so reconstruction is
``U @ core @ Vt  +  scatter(outlier_track, o_idx)``.

Everything is a registered pytree so it flows through jit/vmap/scan/pjit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LowRank:
    """U @ core @ Vt (+ optional outlier track)."""

    u: Array                      # [..., S, k]
    core: Array                   # [..., k] (diag) or [..., k, k2]
    vt: Array                     # [..., k2, H]
    # ---- outlier track (all None when disabled) ----
    o_idx: Optional[Array] = None   # [..., C] int32 channel indices
    o_u: Optional[Array] = None     # [..., S, ko]
    o_core: Optional[Array] = None  # [..., ko] or [..., ko, ko2]
    o_vt: Optional[Array] = None    # [..., ko2, C]
    o_dense: Optional[Array] = None  # [..., S, C] (dense outlier mode)

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        children = (self.u, self.core, self.vt, self.o_idx, self.o_u,
                    self.o_core, self.o_vt, self.o_dense)
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- conveniences ----------------------------------------------------
    @property
    def rank(self) -> int:
        return self.u.shape[-1]

    @property
    def seq_len(self) -> int:
        return self.u.shape[-2]

    @property
    def hidden(self) -> int:
        return self.vt.shape[-1]

    @property
    def has_outliers(self) -> bool:
        """True when a second (outlier) track is present.

        When ``o_idx`` is not None the track lives in the indexed channel
        subspace (width C); after a preserved matmul the track becomes
        full-width (``o_idx is None`` but factors present) and is simply
        added during reconstruction.
        """
        return (self.o_idx is not None or self.o_u is not None
                or self.o_dense is not None)

    @property
    def core_is_diag(self) -> bool:
        return self.core.ndim == self.u.ndim - 1

    def scaled_u(self) -> Array:
        """U @ core folded to the left:  [..., S, k2]."""
        if self.core_is_diag:
            return self.u * self.core[..., None, :]
        return jnp.einsum("...sk,...kl->...sl", self.u, self.core)

    def outlier_values(self) -> Optional[Array]:
        """Dense [..., S, C] values of the outlier track (None if disabled)."""
        if not self.has_outliers:
            return None
        if self.o_dense is not None:
            return self.o_dense
        if self.o_core.ndim == self.o_u.ndim - 1:
            su = self.o_u * self.o_core[..., None, :]
        else:
            su = jnp.einsum("...sk,...kl->...sl", self.o_u, self.o_core)
        return jnp.einsum("...sk,...kc->...sc", su, self.o_vt)

    def reconstruct(self) -> Array:
        """Materialize the dense [..., S, H] activation."""
        x = jnp.einsum("...sk,...kh->...sh", self.scaled_u(), self.vt)
        ov = self.outlier_values()
        if ov is not None:
            if self.o_idx is not None:
                x = _scatter_channels_add(x, ov, self.o_idx)
            else:  # full-width second track (post preserved-matmul)
                x = x + ov
        return x

    def without_outliers(self) -> "LowRank":
        return LowRank(self.u, self.core, self.vt)

    def astype(self, dtype) -> "LowRank":
        cast = lambda a: None if a is None else (
            a if jnp.issubdtype(a.dtype, jnp.integer) else a.astype(dtype))
        return LowRank(cast(self.u), cast(self.core), cast(self.vt),
                       self.o_idx, cast(self.o_u), cast(self.o_core),
                       cast(self.o_vt), cast(self.o_dense))

    # -- bookkeeping for benchmarks ---------------------------------------
    def param_count(self) -> int:
        n = self.u.size + self.core.size + self.vt.size
        for a in (self.o_u, self.o_core, self.o_vt, self.o_dense):
            if a is not None:
                n += a.size
        if self.o_idx is not None:
            n += self.o_idx.size
        return n


def _scatter_channels_add(x: Array, vals: Array, idx: Array) -> Array:
    """x[..., :, idx[c]] += vals[..., :, c] with batched idx support."""
    if idx.ndim == 1:
        return x.at[..., idx].add(vals)

    # batched index vectors: vmap over every leading dim of idx.
    def body(x2, v2, i2):
        return _scatter_channels_add(x2, v2, i2)

    return jax.vmap(body)(x, vals, idx)


def gather_channels(x: Array, idx: Array) -> Array:
    """x[..., :, idx] with batched idx support → [..., S, C]."""
    if idx.ndim == 1:
        return x[..., idx]
    return jax.vmap(gather_channels)(x, idx)


def zero_channels(x: Array, idx: Array) -> Array:
    """Return x with the indexed channels set to zero (batched idx ok)."""
    if idx.ndim == 1:
        return x.at[..., idx].set(0.0)
    return jax.vmap(zero_channels)(x, idx)


def from_dense_svd(x: Array, rank: int) -> LowRank:
    """Oracle construction via jnp.linalg.svd (LAPACK); baseline for tests."""
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    return LowRank(u[..., :, :rank], s[..., :rank], vt[..., :rank, :])


def relative_error(lr: LowRank, x: Array) -> Array:
    """‖X − X̂‖_F / ‖X‖_F (paper Eq. 2's ε)."""
    num = jnp.linalg.norm((lr.reconstruct() - x).reshape(x.shape[:-2] + (-1,)),
                          axis=-1)
    den = jnp.linalg.norm(x.reshape(x.shape[:-2] + (-1,)), axis=-1)
    return num / jnp.maximum(den, 1e-12)


@partial(jax.jit, static_argnames=("new_rank",))
def retruncate(lr: LowRank, new_rank: int) -> LowRank:
    """Re-compress a LowRank whose factors lost orthogonality (e.g. after
    rank concatenation for residual adds).  Cost O(S·k² + H·k²), never
    O(S·H·min(S,H)).  Outlier track is passed through unchanged."""
    su = lr.scaled_u()                          # [..., S, k2]
    qu, ru = jnp.linalg.qr(su)                  # S×k2, k2×k2
    qv, rv = jnp.linalg.qr(jnp.swapaxes(lr.vt, -1, -2))  # H×k2, k2×k2
    small = jnp.einsum("...ij,...kj->...ik", ru, rv)      # k2 × k2
    us, ss, vts = jnp.linalg.svd(small, full_matrices=False)
    u = jnp.einsum("...sk,...kr->...sr", qu, us[..., :, :new_rank])
    vt = jnp.einsum("...rk,...hk->...rh", vts[..., :new_rank, :], qv)
    return LowRank(u, ss[..., :new_rank], vt, lr.o_idx, lr.o_u, lr.o_core,
                   lr.o_vt, lr.o_dense)


def add_bias_rank(lr: LowRank, bias: Array) -> LowRank:
    """Exact  lr + 1·biasᵀ  as one extra rank (U gains a ones column, Vᵀ the
    bias row; dense/indexed outlier tracks pass through unchanged)."""
    u, core, vt = lr.u, lr.core, lr.vt
    ones = jnp.ones(u.shape[:-1] + (1,), u.dtype)
    u = jnp.concatenate([u, ones], axis=-1)
    brow = jnp.broadcast_to(bias.astype(vt.dtype),
                            vt.shape[:-2] + (1, vt.shape[-1]))
    if lr.core_is_diag:
        core = jnp.concatenate(
            [core, jnp.ones(core.shape[:-1] + (1,), core.dtype)], axis=-1)
        vt = jnp.concatenate([vt, brow], axis=-2)
    else:
        k, k2 = core.shape[-2], core.shape[-1]
        core = jnp.pad(core, [(0, 0)] * (core.ndim - 2) + [(0, 1), (0, 1)])
        core = core.at[..., k, k2].set(1.0)
        vt = jnp.concatenate([vt, brow], axis=-2)
    return LowRank(u, core, vt, lr.o_idx, lr.o_u, lr.o_core, lr.o_vt,
                   lr.o_dense)


def rank_concat(a: LowRank, b: LowRank) -> LowRank:
    """Exact sum  a + b  as a rank-(ka+kb) LowRank (for residual streams).

    Outlier tracks must match channel indices (or be absent on one side);
    they are summed densely when both present.
    """
    su_a, su_b = a.scaled_u(), b.scaled_u()
    u = jnp.concatenate([su_a, su_b], axis=-1)
    vt = jnp.concatenate([a.vt, b.vt], axis=-2)
    core = jnp.ones(u.shape[:-2] + (u.shape[-1],), u.dtype)
    o_idx = a.o_idx if a.o_idx is not None else b.o_idx
    o_dense = None
    if a.has_outliers or b.has_outliers:
        ov_a = a.outlier_values()
        ov_b = b.outlier_values()
        if ov_a is not None and ov_b is not None:
            o_dense = ov_a + ov_b
        else:
            o_dense = ov_a if ov_a is not None else ov_b
    return LowRank(u, core, vt, o_idx, o_dense=o_dense)
