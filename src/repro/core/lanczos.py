"""Lanczos bidiagonalization (Golub–Kahan) — paper Algorithm 1.

The paper chooses Lanczos over QR / divide-and-conquer because it converges
fastest at the small ranks (1–20) useful for activation compression, and it
works directly on A (no AᵀA).  The runtime is dominated by the two
re-orthogonalization steps in the inner loop (paper Fig. 3); those are the
ops the D-com accelerator — and our Pallas kernel — fuse and expand.

Implementation notes
--------------------
* Fixed iteration count ``iters`` (static) so the whole factorization jits
  and scans; early-exit (paper line 6) is replaced by a numerical guard that
  zeroes further directions once ‖z‖ falls below ε — the resulting singular
  values come out ≈0, which is equivalent to the break.
* Full re-orthogonalization, classical Gram–Schmidt applied twice (CGS2) —
  matches the paper's "orthogonalize against V/U" and is what their
  accelerator executes.  U/V buffers are zero-padded to [.., iters], so
  projecting against not-yet-filled columns is a no-op.
* Internally fp32 regardless of input dtype (bf16 inputs upcast), matching
  the fp32-accumulate behaviour of MXU/MAC hardware.
* ``matvec``/``rmatvec``/``reorth`` are pluggable so the Pallas kernels in
  ``repro.kernels`` can replace the jnp reference implementations.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .lowrank import LowRank

Array = jax.Array
EPS = 1e-8


class LanczosHooks(NamedTuple):
    """Pluggable fused inner steps (jnp reference by default; Pallas kernels
    via ``repro.kernels.ops.make_pallas_hooks``).

    Each step fuses (matvec → CGS2 re-orthogonalization) — exactly the op
    sequence the D-com accelerator expands (paper Fig. 9).  Normalization
    stays outside (O(S) / O(H), negligible).  Passing an all-zero Q buffer
    makes the re-orthogonalization a no-op (used for the first iteration).
    """
    right_step: Callable[[Array, Array, Array], Array]  # (A, u[S], V[H,k]) -> z[H]
    left_step: Callable[[Array, Array, Array], Array]   # (A, v[H], U[S,k]) -> u[S]


def _reorth_cgs2(z: Array, q: Array) -> Array:
    """Twice-is-enough classical Gram–Schmidt: z ← z − Q(Qᵀz), twice."""
    z = z - q @ (q.T @ z)
    z = z - q @ (q.T @ z)
    return z


DEFAULT_HOOKS = LanczosHooks(
    right_step=lambda a, u, vbuf: _reorth_cgs2(a.T @ u, vbuf),
    left_step=lambda a, v, ubuf: _reorth_cgs2(a @ v, ubuf),
)


class BidiagResult(NamedTuple):
    u: Array       # [S, k] left Lanczos vectors
    v: Array       # [H, k] right Lanczos vectors
    alpha: Array   # [k]   diagonal of B
    beta: Array    # [k-1] superdiagonal of B


def _safe_normalize(x: Array):
    n = jnp.linalg.norm(x)
    ok = n > EPS
    inv = jnp.where(ok, 1.0 / jnp.maximum(n, EPS), 0.0)
    return x * inv, jnp.where(ok, n, 0.0)


@partial(jax.jit, static_argnames=("iters", "hooks"))
def lanczos_bidiag(a: Array, iters: int,
                   z0: Optional[Array] = None,
                   hooks: LanczosHooks = DEFAULT_HOOKS) -> BidiagResult:
    """Golub–Kahan bidiagonalization of ``a [S, H]`` with ``iters`` steps.

    Produces A ≈ U B Vᵀ with B upper-bidiagonal (diag=alpha, superdiag=beta).
    """
    s_dim, h_dim = a.shape
    a32 = a.astype(jnp.float32)
    if z0 is None:
        # Deterministic start vector; any non-degenerate direction works and
        # a fixed one keeps runs reproducible (the paper does not specify).
        key = jax.random.PRNGKey(0)
        z0 = jax.random.normal(key, (h_dim,), jnp.float32)
    z0 = z0.astype(jnp.float32)

    u_buf = jnp.zeros((s_dim, iters), jnp.float32)
    v_buf = jnp.zeros((h_dim, iters), jnp.float32)
    alpha = jnp.zeros((iters,), jnp.float32)
    beta = jnp.zeros((max(iters - 1, 1),), jnp.float32)

    v0, _ = _safe_normalize(z0)
    u0 = hooks.left_step(a32, v0, u_buf)   # U buffer all-zero ⇒ pure matvec
    u0, a0 = _safe_normalize(u0)
    u_buf = u_buf.at[:, 0].set(u0)
    v_buf = v_buf.at[:, 0].set(v0)
    alpha = alpha.at[0].set(a0)

    def body(j, carry):
        u_buf, v_buf, alpha, beta = carry
        u_prev = u_buf[:, j - 1]
        # --- right step: z = Aᵀ u_{j-1}, re-orthogonalized against V -----
        z = hooks.right_step(a32, u_prev, v_buf)
        z, b = _safe_normalize(z)
        v_buf = v_buf.at[:, j].set(z)
        beta = beta.at[j - 1].set(b)
        # --- left step: u = A v_j, re-orthogonalized against U ----------
        u = hooks.left_step(a32, z, u_buf)
        u, al = _safe_normalize(u)
        u_buf = u_buf.at[:, j].set(u)
        alpha = alpha.at[j].set(al)
        return u_buf, v_buf, alpha, beta

    u_buf, v_buf, alpha, beta = jax.lax.fori_loop(
        1, iters, body, (u_buf, v_buf, alpha, beta))
    return BidiagResult(u_buf, v_buf, alpha, beta)


def bidiag_to_svd(res: BidiagResult, rank: int):
    """SVD of the tiny k×k bidiagonal B; rotate the Lanczos bases.

    Returns (U [S, rank], s [rank], Vt [rank, H]).
    """
    k = res.alpha.shape[0]
    b = jnp.diag(res.alpha)
    if k > 1:
        b = b + jnp.diag(res.beta[:k - 1], k=1)
    p, s, qt = jnp.linalg.svd(b)               # k×k each
    u = res.u @ p[:, :rank]                     # [S, rank]
    vt = qt[:rank, :] @ res.v.T                 # [rank, H]
    return u, s[:rank], vt


@partial(jax.jit, static_argnames=("rank", "iters", "hooks"))
def lanczos_svd(a: Array, rank: int, iters: Optional[int] = None,
                z0: Optional[Array] = None,
                hooks: LanczosHooks = DEFAULT_HOOKS):
    """Truncated SVD of a single matrix [S, H] via Lanczos bidiag.

    ``iters`` defaults to ``rank`` (paper-faithful: K iterations for rank K);
    oversampling (iters > rank) improves the trailing singular triplets.
    """
    iters = rank if iters is None else iters
    assert iters >= rank, "need at least `rank` Lanczos iterations"
    res = lanczos_bidiag(a, iters, z0=z0, hooks=hooks)
    return bidiag_to_svd(res, rank)


@partial(jax.jit, static_argnames=("rank", "iters", "hooks"))
def decompose(x: Array, rank: int, iters: Optional[int] = None,
              hooks: LanczosHooks = DEFAULT_HOOKS) -> LowRank:
    """Batched activation decomposition: x [..., S, H] → LowRank.

    Each prompt's [S, H] slice is decomposed independently (paper §3.1:
    "we apply the decomposition on each prompt separately").
    """
    batch_shape = x.shape[:-2]
    flat = x.reshape((-1,) + x.shape[-2:])

    def one(m):
        u, s, vt = lanczos_svd(m, rank, iters=iters, hooks=hooks)
        return u, s, vt

    u, s, vt = jax.vmap(one)(flat)
    u = u.reshape(batch_shape + u.shape[1:])
    s = s.reshape(batch_shape + s.shape[1:])
    vt = vt.reshape(batch_shape + vt.shape[1:])
    return LowRank(u.astype(x.dtype), s.astype(x.dtype), vt.astype(x.dtype))
