"""Lanczos bidiagonalization (Golub–Kahan) — paper Algorithm 1.

The paper chooses Lanczos over QR / divide-and-conquer because it converges
fastest at the small ranks (1–20) useful for activation compression, and it
works directly on A (no AᵀA).  The runtime is dominated by the two
re-orthogonalization steps in the inner loop (paper Fig. 3); those are the
ops the D-com accelerator — and our Pallas kernel — fuse and expand.

Implementation notes
--------------------
* Fixed iteration count ``iters`` (static) so the whole factorization jits
  and scans; early-exit (paper line 6) is replaced by a numerical guard that
  zeroes further directions once ‖z‖ falls below ε — the resulting singular
  values come out ≈0, which is equivalent to the break.
* Full re-orthogonalization, classical Gram–Schmidt applied twice (CGS2) —
  matches the paper's "orthogonalize against V/U" and is what their
  accelerator executes.  U/V buffers are zero-padded to [.., iters], so
  projecting against not-yet-filled columns is a no-op.
* Internally fp32 regardless of input dtype (bf16 inputs upcast), matching
  the fp32-accumulate behaviour of MXU/MAC hardware.
* ``matvec``/``rmatvec``/``reorth`` are pluggable so the Pallas kernels in
  ``repro.kernels`` can replace the jnp reference implementations.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .lowrank import LowRank

Array = jax.Array
EPS = 1e-8


class LanczosHooks(NamedTuple):
    """Pluggable fused inner steps (jnp reference by default; Pallas kernels
    via ``repro.kernels.ops.make_pallas_hooks``).

    Each step fuses (matvec → CGS2 re-orthogonalization) — exactly the op
    sequence the D-com accelerator expands (paper Fig. 9).  Normalization
    stays outside (O(S) / O(H), negligible).  Passing an all-zero Q buffer
    makes the re-orthogonalization a no-op (used for the first iteration).
    """
    right_step: Callable[[Array, Array, Array], Array]  # (A, u[S], V[H,k]) -> z[H]
    left_step: Callable[[Array, Array, Array], Array]   # (A, v[H], U[S,k]) -> u[S]


class BatchedLanczosHooks(NamedTuple):
    """Batched variant of :class:`LanczosHooks` — one call covers the whole
    prompt batch, so a Pallas backend launches ONE fused kernel per Lanczos
    pass (batch axis in the grid) instead of vmap-of-scalar-kernel per
    prompt.  ``repro.kernels.ops.make_batched_pallas_hooks`` builds the
    kernel-backed instance; :func:`batch_hooks` lifts any scalar hooks via
    ``jax.vmap`` (the compatibility fallback).
    """
    right_step: Callable[[Array, Array, Array], Array]  # (A[B,S,H], u[B,S], V[B,H,k]) -> z[B,H]
    left_step: Callable[[Array, Array, Array], Array]   # (A[B,S,H], v[B,H], U[B,S,k]) -> u[B,S]


def _reorth_cgs2(z: Array, q: Array) -> Array:
    """Twice-is-enough classical Gram–Schmidt: z ← z − Q(Qᵀz), twice."""
    z = z - q @ (q.T @ z)
    z = z - q @ (q.T @ z)
    return z


def _reorth_cgs2_batched(z: Array, q: Array) -> Array:
    """Batched CGS2: z [B, N], q [B, N, k] → z − Q(Qᵀz), twice."""
    for _ in range(2):
        p = jnp.einsum("bnk,bn->bk", q, z)
        z = z - jnp.einsum("bnk,bk->bn", q, p)
    return z


DEFAULT_HOOKS = LanczosHooks(
    right_step=lambda a, u, vbuf: _reorth_cgs2(a.T @ u, vbuf),
    left_step=lambda a, v, ubuf: _reorth_cgs2(a @ v, ubuf),
)

DEFAULT_BATCHED_HOOKS = BatchedLanczosHooks(
    right_step=lambda a, u, vbuf: _reorth_cgs2_batched(
        jnp.einsum("bsh,bs->bh", a, u), vbuf),
    left_step=lambda a, v, ubuf: _reorth_cgs2_batched(
        jnp.einsum("bsh,bh->bs", a, v), ubuf),
)


@lru_cache(maxsize=64)
def batch_hooks(hooks: LanczosHooks) -> BatchedLanczosHooks:
    """Lift scalar hooks to the batched protocol via ``jax.vmap``.

    This is the compatibility fallback (one kernel trace per prompt under
    vmap); native batched backends skip it entirely.  Cached per scalar
    hooks so the lifted functions hash stably as static jit arguments —
    BOUNDED, because callers may construct hooks from fresh closures and an
    unbounded cache would pin them for the process lifetime.
    """
    return BatchedLanczosHooks(right_step=jax.vmap(hooks.right_step),
                               left_step=jax.vmap(hooks.left_step))


class BidiagResult(NamedTuple):
    u: Array       # [S, k] left Lanczos vectors
    v: Array       # [H, k] right Lanczos vectors
    alpha: Array   # [k]   diagonal of B
    beta: Array    # [k-1] superdiagonal of B


def _safe_normalize(x: Array):
    n = jnp.linalg.norm(x)
    ok = n > EPS
    inv = jnp.where(ok, 1.0 / jnp.maximum(n, EPS), 0.0)
    return x * inv, jnp.where(ok, n, 0.0)


def _safe_normalize_batched(x: Array):
    """Row-wise safe normalize: x [B, N] → (unit rows, norms [B])."""
    n = jnp.linalg.norm(x, axis=-1)
    ok = n > EPS
    inv = jnp.where(ok, 1.0 / jnp.maximum(n, EPS), 0.0)
    return x * inv[:, None], jnp.where(ok, n, 0.0)


@partial(jax.jit, static_argnames=("iters", "hooks"))
def lanczos_bidiag(a: Array, iters: int,
                   z0: Optional[Array] = None,
                   hooks: LanczosHooks = DEFAULT_HOOKS) -> BidiagResult:
    """Golub–Kahan bidiagonalization of ``a [S, H]`` with ``iters`` steps.

    Produces A ≈ U B Vᵀ with B upper-bidiagonal (diag=alpha, superdiag=beta).
    The scalar path IS the B=1 slice of :func:`lanczos_bidiag_batched` —
    there is exactly one copy of the iteration math in this module.
    """
    res = lanczos_bidiag_batched(a[None], iters, z0=z0,
                                 hooks=batch_hooks(hooks))
    return BidiagResult(res.u[0], res.v[0], res.alpha[0], res.beta[0])


def bidiag_to_svd(res: BidiagResult, rank: int):
    """SVD of the tiny k×k bidiagonal B; rotate the Lanczos bases.

    Returns (U [S, rank], s [rank], Vt [rank, H]).
    """
    u, s, vt = bidiag_to_svd_batched(
        BidiagResult(res.u[None], res.v[None], res.alpha[None],
                     res.beta[None]), rank)
    return u[0], s[0], vt[0]


@partial(jax.jit, static_argnames=("rank", "iters", "hooks"))
def lanczos_svd(a: Array, rank: int, iters: Optional[int] = None,
                z0: Optional[Array] = None,
                hooks: LanczosHooks = DEFAULT_HOOKS):
    """Truncated SVD of a single matrix [S, H] via Lanczos bidiag.

    ``iters`` defaults to ``rank`` (paper-faithful: K iterations for rank K);
    oversampling (iters > rank) improves the trailing singular triplets.
    """
    iters = rank if iters is None else iters
    assert iters >= rank, "need at least `rank` Lanczos iterations"
    res = lanczos_bidiag(a, iters, z0=z0, hooks=hooks)
    return bidiag_to_svd(res, rank)


# ---------------------------------------------------------------------------
# Natively batched pipeline — one fused step per Lanczos pass for the WHOLE
# prompt batch (the batch axis lives in the hook / Pallas grid, never in a
# Python-level vmap over pallas_call).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters", "hooks"))
def lanczos_bidiag_batched(a: Array, iters: int,
                           z0: Optional[Array] = None,
                           hooks: BatchedLanczosHooks = DEFAULT_BATCHED_HOOKS
                           ) -> BidiagResult:
    """Golub–Kahan bidiagonalization of a batch ``a [B, S, H]``.

    Identical math to :func:`lanczos_bidiag` per batch element (same start
    vector when ``z0`` is None), but every inner step is ONE batched hook
    call, so kernel backends see the full batch per pass.  ``z0`` may be
    [H] (broadcast over the batch) or [B, H].
    """
    b_dim, s_dim, h_dim = a.shape
    a32 = a.astype(jnp.float32)
    if z0 is None:
        key = jax.random.PRNGKey(0)
        z0 = jax.random.normal(key, (h_dim,), jnp.float32)
    z0 = jnp.broadcast_to(z0.astype(jnp.float32), (b_dim, h_dim))

    u_buf = jnp.zeros((b_dim, s_dim, iters), jnp.float32)
    v_buf = jnp.zeros((b_dim, h_dim, iters), jnp.float32)
    alpha = jnp.zeros((b_dim, iters), jnp.float32)
    beta = jnp.zeros((b_dim, max(iters - 1, 1)), jnp.float32)

    v0, _ = _safe_normalize_batched(z0)
    u0 = hooks.left_step(a32, v0, u_buf)   # U buffer all-zero ⇒ pure matvec
    u0, a0 = _safe_normalize_batched(u0)
    u_buf = u_buf.at[..., 0].set(u0)
    v_buf = v_buf.at[..., 0].set(v0)
    alpha = alpha.at[..., 0].set(a0)

    def body(j, carry):
        u_buf, v_buf, alpha, beta = carry
        u_prev = u_buf[..., j - 1]
        z = hooks.right_step(a32, u_prev, v_buf)
        z, b = _safe_normalize_batched(z)
        v_buf = v_buf.at[..., j].set(z)
        beta = beta.at[..., j - 1].set(b)
        u = hooks.left_step(a32, z, u_buf)
        u, al = _safe_normalize_batched(u)
        u_buf = u_buf.at[..., j].set(u)
        alpha = alpha.at[..., j].set(al)
        return u_buf, v_buf, alpha, beta

    u_buf, v_buf, alpha, beta = jax.lax.fori_loop(
        1, iters, body, (u_buf, v_buf, alpha, beta))
    return BidiagResult(u_buf, v_buf, alpha, beta)


def bidiag_to_svd_batched(res: BidiagResult, rank: int):
    """Batched SVD of the tiny k×k bidiagonal B; rotate the Lanczos bases.

    Returns (U [B, S, rank], s [B, rank], Vt [B, rank, H]).
    """
    k = res.alpha.shape[-1]
    b = jax.vmap(jnp.diag)(res.alpha)
    if k > 1:
        b = b + jax.vmap(partial(jnp.diag, k=1))(res.beta[..., :k - 1])
    p, s, qt = jnp.linalg.svd(b)
    u = jnp.einsum("bsk,bkr->bsr", res.u, p[..., :, :rank])
    vt = jnp.einsum("brk,bhk->brh", qt[..., :rank, :], res.v)
    return u, s[..., :rank], vt


@partial(jax.jit, static_argnames=("rank", "iters", "hooks", "batched_hooks"))
def decompose(x: Array, rank: int, iters: Optional[int] = None,
              hooks: Optional[LanczosHooks] = None,
              batched_hooks: Optional[BatchedLanczosHooks] = None,
              z0: Optional[Array] = None) -> LowRank:
    """Batched activation decomposition: x [..., S, H] → LowRank.

    Each prompt's [S, H] slice is decomposed independently (paper §3.1:
    "we apply the decomposition on each prompt separately"), but the whole
    batch runs through ONE natively batched Lanczos pipeline: a kernel
    backend (``batched_hooks``) sees one fused launch per pass.  Scalar
    ``hooks`` are still accepted and lifted via :func:`batch_hooks` (the
    vmap fallback).  Prefer constructing a ``repro.engine.DecomposeEngine``,
    which also handles padding, outlier tracks, and backend selection.
    """
    iters = rank if iters is None else iters
    assert iters >= rank, "need at least `rank` Lanczos iterations"
    if batched_hooks is None:
        batched_hooks = (DEFAULT_BATCHED_HOOKS if hooks is None
                         else batch_hooks(hooks))
    batch_shape = x.shape[:-2]
    flat = x.reshape((-1,) + x.shape[-2:])
    res = lanczos_bidiag_batched(flat, iters, z0=z0, hooks=batched_hooks)
    u, s, vt = bidiag_to_svd_batched(res, rank)
    u = u.reshape(batch_shape + u.shape[1:])
    s = s.reshape(batch_shape + s.shape[1:])
    vt = vt.reshape(batch_shape + vt.shape[1:])
    return LowRank(u.astype(x.dtype), s.astype(x.dtype), vt.astype(x.dtype))
