"""Baseline SVD algorithms (paper §2.3 / Fig. 2 comparison set).

The paper motivates Lanczos by comparing convergence speed across QR
decomposition, divide-and-conquer, and Lanczos for small ranks.  We provide
JAX implementations of the comparison set so ``benchmarks/fig2_convergence``
can reproduce the ordering on identical inputs:

* ``oracle_svd``        — jnp.linalg.svd (LAPACK divide-and-conquer on CPU;
                          the paper's red dotted "optimal" line).
* ``qr_iteration_svd``  — block QR / subspace iteration on AᵀA: the classical
                          "QR decomposition" contender.
* ``randomized_svd``    — Halko-style randomized range finder (one extra
                          contender showing the small-rank regime trade-off).
* Lanczos lives in ``core.lanczos`` (the paper's choice).

All are fixed-iteration and jit-friendly.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .lowrank import LowRank

Array = jax.Array


def oracle_svd(a: Array, rank: int) -> Tuple[Array, Array, Array]:
    """Full LAPACK SVD, truncated — the accuracy oracle."""
    u, s, vt = jnp.linalg.svd(a.astype(jnp.float32), full_matrices=False)
    return u[:, :rank], s[:rank], vt[:rank, :]


@partial(jax.jit, static_argnames=("rank", "iters"))
def qr_iteration_svd(a: Array, rank: int, iters: int = 8
                     ) -> Tuple[Array, Array, Array]:
    """Subspace (block power) iteration with QR re-orthogonalization.

    Works on AᵀA implicitly: V ← qr(Aᵀ(A·V)).  Cost per iter: two dense
    matmuls [S,H]·[H,r] — much heavier per-iteration than Lanczos' matvecs
    at equal rank, which is exactly the paper's point for small r.
    """
    a32 = a.astype(jnp.float32)
    h = a.shape[-1]
    v = jax.random.normal(jax.random.PRNGKey(1), (h, rank), jnp.float32)
    v, _ = jnp.linalg.qr(v)

    def body(_, v):
        w = a32 @ v                    # [S, r]
        z = a32.T @ w                  # [H, r]
        v, _ = jnp.linalg.qr(z)
        return v

    v = jax.lax.fori_loop(0, iters, body, v)
    av = a32 @ v                       # [S, r]
    u, r_small = jnp.linalg.qr(av)
    us, s, vts = jnp.linalg.svd(r_small)
    return u @ us, s, (vts @ v.T)


@partial(jax.jit, static_argnames=("rank", "oversample", "power_iters"))
def randomized_svd(a: Array, rank: int, oversample: int = 4,
                   power_iters: int = 2) -> Tuple[Array, Array, Array]:
    """Halko–Martinsson–Tropp randomized SVD with power iterations."""
    a32 = a.astype(jnp.float32)
    s_dim, h_dim = a.shape
    k = min(rank + oversample, min(s_dim, h_dim))
    omega = jax.random.normal(jax.random.PRNGKey(2), (h_dim, k), jnp.float32)
    y = a32 @ omega
    q, _ = jnp.linalg.qr(y)

    def body(_, q):
        z = a32.T @ q
        z, _ = jnp.linalg.qr(z)
        y = a32 @ z
        q, _ = jnp.linalg.qr(y)
        return q

    q = jax.lax.fori_loop(0, power_iters, body, q)
    b = q.T @ a32                       # [k, H]
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    return (q @ ub)[:, :rank], s[:rank], vt[:rank, :]


def as_lowrank(u: Array, s: Array, vt: Array) -> LowRank:
    return LowRank(u, s, vt)


def reconstruction_error(a: Array, u: Array, s: Array, vt: Array) -> Array:
    """Relative Frobenius error of U·diag(s)·Vᵀ vs A."""
    rec = (u * s[None, :]) @ vt
    return (jnp.linalg.norm(rec - a.astype(jnp.float32))
            / jnp.maximum(jnp.linalg.norm(a.astype(jnp.float32)), 1e-12))
