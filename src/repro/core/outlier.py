"""Channel-wise outlier extraction (paper §4, "multi-track decomposition").

SVD minimizes squared error and is therefore hypersensitive to the few large-
magnitude activation entries; the paper observes these live in a small set of
*channels* (columns of the [S, H] activation map) and extracts them before
decomposition.  Channel granularity keeps metadata tiny (one index per
channel) and the gather/scatter cheap.

Static shapes: jit needs a fixed outlier-channel count, so the policy fixes
``num_channels = round(frac · H)`` and we take the top-``num_channels``
channels ranked by (outlier-element count, max |value|) — channels whose
count is zero still get selected but carry ~zero energy, which is harmless.

Thresholds are calibrated *offline* per layer (paper: "a table including the
outlier thresholds for each layer in the model is created offline using
statistical analysis"); see :func:`calibrate_threshold` / :class:`ThresholdTable`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .lowrank import LowRank, gather_channels, zero_channels

Array = jax.Array


@partial(jax.jit, static_argnames=())
def channel_outlier_counts(x: Array, threshold: Array) -> Array:
    """Per-channel count of |x| > T over all token rows: [..., H] int32."""
    return jnp.sum((jnp.abs(x) > threshold), axis=-2).astype(jnp.int32)


@partial(jax.jit, static_argnames=("num_channels",))
def select_outlier_channels(x: Array, threshold: Array,
                            num_channels: int) -> Array:
    """Top-``num_channels`` channel indices by outlier count (ties broken by
    channel max-|x|).  Returns sorted int32 indices [..., C]."""
    counts = channel_outlier_counts(x, threshold).astype(jnp.float32)
    maxabs = jnp.max(jnp.abs(x), axis=-2)
    # count dominates; bounded [0,1) magnitude tiebreak keeps ordering stable
    score = counts + maxabs / (1.0 + jnp.max(maxabs, axis=-1, keepdims=True))
    _, idx = jax.lax.top_k(score, num_channels)
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


def split_outliers(x: Array, idx: Array) -> Tuple[Array, Array]:
    """Return (x with outlier channels zeroed, dense outlier values [..,S,C])."""
    vals = gather_channels(x, idx)
    base = zero_channels(x, idx)
    return base, vals


@partial(jax.jit, static_argnames=("num_channels",))
def extract(x: Array, threshold: Array, num_channels: int):
    """One-shot extraction: (x_base, outlier_vals, channel_idx)."""
    idx = select_outlier_channels(x, threshold, num_channels)
    base, vals = split_outliers(x, idx)
    return base, vals, idx


def attach_dense_outliers(lr: LowRank, vals: Array, idx: Array) -> LowRank:
    return LowRank(lr.u, lr.core, lr.vt, o_idx=idx, o_dense=vals)


# ---------------------------------------------------------------------------
# Offline calibration
# ---------------------------------------------------------------------------

def calibrate_threshold(samples: np.ndarray, target_channel_frac: float,
                        element_quantile: float = 0.999) -> float:
    """Pick T so that ≈ ``target_channel_frac`` of channels trip the detector.

    Method (matches the paper's offline statistical analysis): compute each
    channel's high quantile of |x|; channels whose tail value exceeds T are
    "outlier channels", so T is the (1 - frac) quantile of those tail values.
    """
    a = np.abs(np.asarray(samples, dtype=np.float32))
    a = a.reshape(-1, a.shape[-1])                      # [N·S, H]
    per_channel_tail = np.quantile(a, element_quantile, axis=0)   # [H]
    t = float(np.quantile(per_channel_tail, 1.0 - target_channel_frac))
    return t


@dataclasses.dataclass
class ThresholdTable:
    """Per-layer outlier thresholds, built offline, consulted at runtime."""

    thresholds: Dict[int, float] = dataclasses.field(default_factory=dict)
    default: float = 6.0    # ~"6 sigma" style default for unit-scale acts

    def get(self, layer: int) -> float:
        return self.thresholds.get(int(layer), self.default)

    def set(self, layer: int, value: float) -> None:
        self.thresholds[int(layer)] = float(value)

    def calibrate_layer(self, layer: int, samples: np.ndarray,
                        target_channel_frac: float) -> float:
        t = calibrate_threshold(samples, target_channel_frac)
        self.set(layer, t)
        return t

    # -- persistence -----------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic write (tmp file + ``os.replace``, the same pattern as
        ``tune.cache``): a crash mid-write leaves either the previous table
        or the new one on disk, never a truncated JSON — this file is
        calibrated offline once and consulted by every serving run."""
        payload = {"default": self.default,
                   "thresholds": {str(k): v
                                  for k, v in self.thresholds.items()}}
        d = os.path.dirname(os.path.abspath(path))
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".thresholds-",
                                   suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def load(cls, path: str) -> "ThresholdTable":
        """Load a saved table; a corrupt/unreadable file degrades to the
        built-in defaults with a warning (serving keeps running on the
        conservative default threshold rather than crashing on a table a
        pre-atomic-save writer truncated)."""
        try:
            with open(path) as f:
                d = json.load(f)
            return cls(thresholds={int(k): float(v)
                                   for k, v in d["thresholds"].items()},
                       default=float(d.get("default", 6.0)))
        except (OSError, ValueError, KeyError, TypeError) as e:
            warnings.warn(f"ThresholdTable.load({path!r}): unreadable or "
                          f"corrupt table ({e!r}); falling back to defaults",
                          RuntimeWarning, stacklevel=2)
            return cls()


def measured_extraction_frac(x: Array, threshold: float,
                             num_channels: int) -> Array:
    """Fraction of total |energy| captured by the selected channels —
    reported alongside the paper's 2.12–5.05% channel percentages."""
    idx = select_outlier_channels(x, jnp.asarray(threshold), num_channels)
    vals = gather_channels(x, idx)
    num = jnp.sum(vals.astype(jnp.float32) ** 2)
    den = jnp.sum(x.astype(jnp.float32) ** 2)
    return num / jnp.maximum(den, 1e-12)
