"""Decomposition-preserved computation (paper §3.2).

The paper's key computational trick: once an activation X ≈ U·Σ·Vᵀ exists,
a linear layer  Y = X·W  is evaluated as  Vᵀ* = Vᵀ·W  ONLY (Eq. 6), keeping
the output in decomposed form (U, Σ, Vᵀ*).  Consecutive decomposed matmuls
never re-run the decomposer, and output activation memory stays compressed.

For input+weight decomposition (W ≈ U_w·Σ_w·Vᵀ_w) only the inner chain
Σ* = Σ_I · Vᵀ_I · U_W · Σ_W  is evaluated (Eq. 7) and the output is
(U_I, Σ*, Vᵀ_W).

The *outlier track* (paper §4) rides along: a dense [S, C] channel slice
becomes, after a preserved matmul by W, the factored pair
(o_u = vals [S, C], o_vt = W[idx, :] [C, H]) — i.e. a rank-C full-width
side-track, still never materializing an [S, H] tensor.

This module also provides the contraction-order planner (the paper's Eq. 4/5
"optimal computation order" analysis, generalized to measured FLOP counts)
and preserved-form attention contractions (QKᵀ and P·V through the factors),
which is the natural TPU extension of the paper's "keep inputs decomposed
for all matmuls within a layer".
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .lowrank import LowRank

Array = jax.Array


# ---------------------------------------------------------------------------
# FLOP accounting / contraction-order planner (paper Eq. 4, 5, 8, 9)
# ---------------------------------------------------------------------------

def matmul_flops(m: int, k: int, n: int) -> int:
    """MACs×2 for an [m,k]@[k,n] product."""
    return 2 * m * k * n


def chain_flops(dims: Sequence[int], order: Sequence[int]) -> int:
    """FLOPs of multiplying the matrix chain M0[d0,d1]·M1[d1,d2]·…

    ``order`` lists which adjacent pair is contracted at each step, indexing
    into the *current* chain.  Used by tests to verify the paper's claimed
    optimal orders (Eq. 4/5) are what the planner picks.
    """
    dims = list(dims)
    total = 0
    for pos in order:
        total += matmul_flops(dims[pos], dims[pos + 1], dims[pos + 2])
        del dims[pos + 1]
    return total


def plan_chain(dims: Sequence[int]) -> Tuple[List[int], int]:
    """Optimal matrix-chain order by exhaustive DP (chains here are ≤ 6 long).

    Returns (order as successive adjacent-pair indices, total FLOPs).
    """
    dims = tuple(dims)
    n = len(dims) - 1  # number of matrices
    if n == 1:
        return [], 0

    best = {}

    def solve(d: Tuple[int, ...]):
        if d in best:
            return best[d]
        if len(d) == 3:
            best[d] = ([0], matmul_flops(*d))
            return best[d]
        opt = None
        for pos in range(len(d) - 2):
            cost = matmul_flops(d[pos], d[pos + 1], d[pos + 2])
            rest = d[:pos + 1] + d[pos + 2:]
            sub_order, sub_cost = solve(rest)
            total = cost + sub_cost
            if opt is None or total < opt[1]:
                opt = ([pos] + sub_order, total)
        best[d] = opt
        return opt

    return solve(dims)


def compute_reduction_ratio_input_only(s: int, r2: int) -> float:
    """Paper Eq. 8: dense(S·D·W) / preserved(r2·D·W) = S / r2."""
    return s / r2


def compute_reduction_ratio_input_weight(s: int, d: int, w: int,
                                         r1: int, r2: int,
                                         p1: int, p2: int) -> float:
    """Paper Eq. 9 (denominator = preserved Eq. 7 chain cost)."""
    dense = s * d * w
    preserved = r2 * d * p1 + r2 * p1 * p2 + r1 * r2 * p2
    return dense / preserved


def activation_compression_ratio(s: int, d: int, r1: int, r2: int) -> float:
    """Paper Eq. 10 (with p→r): dense S·D vs factored storage."""
    return (s * d) / (s * r1 + r1 * r2 + r2 * d)


def weight_compression_ratio(d: int, w: int, p1: int, p2: int) -> float:
    """Paper Eq. 12."""
    return (d * w) / (d * p1 + p1 * p2 + p2 * w)


def weight_rank_break_even(d: int, w: int) -> float:
    """Paper Eq. 11: p below this bound ⇒ decomposed weight is smaller."""
    return (((d + w) ** 2 + 4 * d * w) ** 0.5 - (d + w)) / 2


# ---------------------------------------------------------------------------
# Preserved matmuls
# ---------------------------------------------------------------------------

def _apply_core_left(u: Array, core: Array) -> Array:
    if core.ndim == u.ndim - 1:
        return u * core[..., None, :]
    return jnp.einsum("...sk,...kl->...sl", u, core)


def lowrank_matmul(lr: LowRank, w: Array, *,
                   bias: Optional[Array] = None) -> LowRank:
    """Preserved-format  (U·Σ·Vᵀ [+outliers]) @ W  →  LowRank (paper Eq. 6).

    Only ``Vᵀ* = Vᵀ @ W`` (shape [k2, N]) is computed — S never appears in
    any contraction.  The dense outlier track (o_dense [S, C] at channels
    ``o_idx``) turns into the factored full-width pair
    (o_u = o_dense, o_vt = W[o_idx, :]), because
    scatter(o_dense, idx) @ W ≡ o_dense @ W[idx, :].

    ``bias`` (shape [N]) is absorbed as one extra rank: U gains a column of
    ones and Vᵀ gains the bias row (exact, costs rank+1).
    """
    vt_new = jnp.einsum("...kh,hn->...kn", lr.vt, w)

    o_idx = o_u = o_core = o_vt = o_dense = None
    if lr.has_outliers:
        if lr.o_dense is not None and lr.o_idx is not None:
            o_u = lr.o_dense                              # [..., S, C]
            o_core = jnp.ones(o_u.shape[:-2] + (o_u.shape[-1],), o_u.dtype)
            o_vt = w[lr.o_idx, :] if lr.o_idx.ndim == 1 else (
                jax.vmap(lambda i: w[i, :])(
                    lr.o_idx.reshape(-1, lr.o_idx.shape[-1])
                ).reshape(lr.o_idx.shape[:-1] + (lr.o_idx.shape[-1],
                                                 w.shape[-1])))
            o_vt = o_vt.astype(o_u.dtype)
        else:
            # already full-width factored track: push W through its Vᵀ
            o_u, o_core = lr.o_u, lr.o_core
            o_vt = jnp.einsum("...kh,hn->...kn", lr.o_vt, w)

    u, core = lr.u, lr.core
    if bias is not None:
        ones = jnp.ones(u.shape[:-1] + (1,), u.dtype)
        u = jnp.concatenate([u, ones], axis=-1)
        if lr.core_is_diag:
            core = jnp.concatenate(
                [core, jnp.ones(core.shape[:-1] + (1,), core.dtype)], axis=-1)
            vt_new = jnp.concatenate(
                [vt_new,
                 jnp.broadcast_to(bias.astype(vt_new.dtype),
                                  vt_new.shape[:-2] + (1, vt_new.shape[-1]))],
                axis=-2)
        else:
            k, k2 = core.shape[-2], core.shape[-1]
            core = jnp.pad(core, [(0, 0)] * (core.ndim - 2) + [(0, 1), (0, 1)])
            core = core.at[..., k, k2].set(1.0)
            vt_new = jnp.concatenate(
                [vt_new,
                 jnp.broadcast_to(bias.astype(vt_new.dtype),
                                  vt_new.shape[:-2] + (1, vt_new.shape[-1]))],
                axis=-2)
    return LowRank(u, core, vt_new, o_idx, o_u, o_core, o_vt, o_dense)


def lowrank_x_lowrank_weight(lr: LowRank, w_lr: LowRank) -> LowRank:
    """Input+weight preserved product (paper Eq. 7).

    X @ W ≈ (U_I Σ_I Vᵀ_I) (U_W Σ_W Vᵀ_W)
          = U_I · [Σ_I (Vᵀ_I U_W) Σ_W] · Vᵀ_W  =  U_I · Σ* · Vᵀ_W
    with Σ* of shape [r1, p2]; cost r2·H·p1 + r1·r2·p1 + r1·p1·p2 — no S, no
    output-H contraction at all.
    """
    m = jnp.einsum("...kh,hp->...kp", lr.vt, w_lr.scaled_u()
                   if w_lr.u.ndim == 2 else w_lr.u)       # Vᵀ_I · (U_W Σ_W)
    if lr.core_is_diag:
        core_new = lr.core[..., :, None] * m
    else:
        core_new = jnp.einsum("...kl,...lp->...kp", lr.core, m)

    o_idx = o_u = o_core = o_vt = o_dense = None
    if lr.has_outliers:
        w_dense_rows = None
        if lr.o_dense is not None and lr.o_idx is not None:
            # outlier channels hit U_W rows idx: vals @ (U_W Σ_W)[idx] @ Vᵀ_W
            su_w = w_lr.scaled_u()                        # [H, p2]
            w_dense_rows = su_w[lr.o_idx, :] if lr.o_idx.ndim == 1 else (
                jax.vmap(lambda i: su_w[i, :])(
                    lr.o_idx.reshape(-1, lr.o_idx.shape[-1])
                ).reshape(lr.o_idx.shape[:-1] + (lr.o_idx.shape[-1],
                                                 su_w.shape[-1])))
            o_u = lr.o_dense
            o_core = jnp.einsum("...cp->...cp", w_dense_rows).astype(o_u.dtype)
            o_vt = jnp.broadcast_to(
                w_lr.vt.astype(o_u.dtype),
                o_core.shape[:-2] + w_lr.vt.shape) if o_core.ndim > 2 \
                else w_lr.vt.astype(o_u.dtype)
        else:
            o_u, o_core = lr.o_u, lr.o_core
            inner = jnp.einsum("...kh,hp->...kp", lr.o_vt, w_lr.scaled_u())
            if lr.o_core is not None and lr.o_core.ndim == lr.o_u.ndim - 1:
                o_core = inner * lr.o_core[..., :, None]
                o_u = lr.o_u
            else:
                o_core = jnp.einsum("...kl,...lp->...kp", lr.o_core, inner)
            o_vt = w_lr.vt.astype(o_u.dtype)

    vt_out = jnp.broadcast_to(
        w_lr.vt, core_new.shape[:-2] + w_lr.vt.shape) \
        if core_new.ndim > 2 and w_lr.vt.ndim == 2 else w_lr.vt
    return LowRank(lr.u, core_new, vt_out.astype(lr.u.dtype),
                   o_idx, o_u, o_core, o_vt, o_dense)


def decompose_weight(w: Array, rank: int) -> LowRank:
    """Offline weight decomposition (exact truncated SVD — offline cost is
    irrelevant per the paper; runtime decomposition is only for activations).
    """
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    return LowRank(u[..., :, :rank].astype(w.dtype),
                   s[..., :rank].astype(w.dtype),
                   vt[..., :rank, :].astype(w.dtype))


# ---------------------------------------------------------------------------
# Preserved-form attention contractions
# ---------------------------------------------------------------------------
# With Q = U_q Σ_q Vᵀ_q and K = U_k Σ_k Vᵀ_k (the SAME U per prompt when QKV
# share a decomposed input), per-head scores factor through a tiny [kq, kk]
# inner matrix: scores_h = U_q · (Σ_q Vᵀ_q,h · V_k,h Σ_k) · Uᵀ_k.
# Cost per head: kq·dh·kk + S·kq·kk + S·S·kq  vs dense  S·S·dh
# — an dh/kq ≈ 12× FLOP cut at rank 10, head_dim 128.

def preserved_qk_scores(q: LowRank, k: LowRank, num_heads: int,
                        scale: float,
                        num_kv_heads: Optional[int] = None) -> Array:
    """Per-head attention scores from factored Q, K → dense [..., nh, S, T].

    GQA-aware: K may carry ``num_kv_heads`` < num_heads; Q heads are grouped.
    Outlier tracks are folded in exactly (they're low-rank side tracks, so the
    concatenated factorization [base | outlier] is still low-rank).
    """
    kvh = num_kv_heads or num_heads
    g = num_heads // kvh
    uq, vq = _with_outlier_concat(q)     # [..., S, kq'] , [..., kq', nh·dh]
    uk, vk = _with_outlier_concat(k)
    dh = vk.shape[-1] // kvh
    vq_h = vq.reshape(vq.shape[:-1] + (kvh, g, dh))  # [..., kq, kvh, g, dh]
    vk_h = vk.reshape(vk.shape[:-1] + (kvh, dh))     # [..., kk, kvh, dh]
    inner = jnp.einsum("...qkgd,...pkd->...kgqp", vq_h, vk_h)
    left = jnp.einsum("...sq,...kgqp->...kgsp", uq, inner)
    sc = jnp.einsum("...kgsp,...tp->...kgst", left, uk)
    shape = sc.shape[:-4] + (num_heads,) + sc.shape[-2:]
    return scale * sc.reshape(shape)


def preserved_pv(p: Array, v: LowRank, num_heads: int,
                 num_kv_heads: Optional[int] = None) -> Array:
    """probs [..., nh, S, T] × factored V → per-head out [..., S, nh·dh].

    P @ V = (P @ U_v) @ (Σ_v Vᵀ_v)_h : the S·T·k contraction is shared-U, the
    per-head part is rank-k.  GQA-aware like :func:`preserved_qk_scores`.
    """
    kvh = num_kv_heads or num_heads
    g = num_heads // kvh
    uv, vv = _with_outlier_concat(v)
    dh = vv.shape[-1] // kvh
    vv_h = vv.reshape(vv.shape[:-1] + (kvh, dh))     # [..., kv, kvh, dh]
    pg = p.reshape(p.shape[:-3] + (kvh, g) + p.shape[-2:])
    pu = jnp.einsum("...kgst,...tp->...kgsp", pg, uv)
    out = jnp.einsum("...kgsp,...pkd->...skgd", pu, vv_h)
    return out.reshape(out.shape[:-3] + (num_heads * dh,))


def _with_outlier_concat(lr: LowRank) -> Tuple[Array, Array]:
    """(U·Σ, Vᵀ) with any outlier track folded in as extra rank columns.

    Channel-indexed dense tracks are scattered into an H-wide zero row-space
    first (exact; the [C, H] scatter touches only C rows).
    """
    su = lr.scaled_u()
    vt = lr.vt
    if not lr.has_outliers:
        return su, vt
    if lr.o_dense is not None and lr.o_idx is not None:
        c = lr.o_idx.shape[-1]
        h = lr.hidden
        eye_rows = jnp.zeros(lr.o_idx.shape[:-1] + (c, h), vt.dtype)
        if lr.o_idx.ndim == 1:
            eye_rows = eye_rows.at[jnp.arange(c), lr.o_idx].set(1.0)
        else:
            def scat(e, i):
                return e.at[jnp.arange(c), i].set(1.0)
            flat_i = lr.o_idx.reshape(-1, c)
            flat_e = eye_rows.reshape(-1, c, h)
            eye_rows = jax.vmap(scat)(flat_e, flat_i).reshape(eye_rows.shape)
        su = jnp.concatenate([su, lr.o_dense.astype(su.dtype)], axis=-1)
        vt = jnp.concatenate([vt, eye_rows], axis=-2)
        return su, vt
    # full-width factored track
    if lr.o_core.ndim == lr.o_u.ndim - 1:
        so = lr.o_u * lr.o_core[..., None, :]
    else:
        so = jnp.einsum("...sk,...kl->...sl", lr.o_u, lr.o_core)
    su = jnp.concatenate([su, so.astype(su.dtype)], axis=-1)
    vt = jnp.concatenate([vt, lr.o_vt.astype(vt.dtype)], axis=-2)
    return su, vt


# ---------------------------------------------------------------------------
# Residual add in preserved form
# ---------------------------------------------------------------------------

def preserved_residual_add(lr: LowRank, residual: LowRank) -> LowRank:
    """Exact x + y for two LowRanks sharing nothing: rank-concat (cheap, grows
    rank; callers retruncate on a policy-chosen cadence)."""
    from .lowrank import rank_concat
    return rank_concat(lr, residual)
