"""D-com core: runtime activation decomposition (paper's contribution).

Public surface:
* ``LowRank``            — factored activation pytree (+ outlier track)
* ``decompose``          — batched Lanczos truncated SVD of activations
* ``lowrank_matmul`` …   — decomposition-preserved linear algebra (§3.2)
* ``extract`` / ``ThresholdTable`` — channel-wise outlier handling (§4)
* ``DecompositionPolicy`` — per-layer configuration (§6.2)
"""
from .lowrank import (LowRank, from_dense_svd, gather_channels, rank_concat,
                      relative_error, retruncate, zero_channels)
from .lanczos import (DEFAULT_BATCHED_HOOKS, DEFAULT_HOOKS,
                      BatchedLanczosHooks, BidiagResult, LanczosHooks,
                      batch_hooks, bidiag_to_svd, bidiag_to_svd_batched,
                      decompose, lanczos_bidiag, lanczos_bidiag_batched,
                      lanczos_svd)
from .outlier import (ThresholdTable, attach_dense_outliers,
                      calibrate_threshold, channel_outlier_counts, extract,
                      measured_extraction_frac, select_outlier_channels,
                      split_outliers)
from .preserved import (activation_compression_ratio, chain_flops,
                        compute_reduction_ratio_input_only,
                        compute_reduction_ratio_input_weight,
                        decompose_weight, lowrank_matmul,
                        lowrank_x_lowrank_weight, matmul_flops, plan_chain,
                        preserved_pv, preserved_qk_scores,
                        preserved_residual_add, weight_compression_ratio,
                        weight_rank_break_even)
from .policy import (PAPER_BEST_CONFIG, PAPER_LAYER_CONFIGS,
                     DecompositionPolicy, LayerPolicy)
from . import svd_alt

__all__ = [k for k in dir() if not k.startswith("_")]
