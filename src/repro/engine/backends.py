"""Backend-dispatch registry for the decomposition pipeline.

A backend decides HOW the batched Lanczos inner steps execute; it is
selected ONCE per engine (not per op, not per callsite):

* ``reference``        — pure-jnp batched einsum steps (always available,
                         the numerical oracle).
* ``pallas_interpret`` — the fused D-com re-orth kernel with the batch axis
                         in the Pallas grid, interpreter mode (CPU
                         containers / CI).
* ``pallas``           — same kernels compiled via Mosaic (TPU deployment).
* ``pallas_vmap``      — vmap-of-scalar-kernel fallback: the pre-engine
                         batching scheme, kept for A/B benchmarking and as
                         an escape hatch.

Hook factories are lru-cached upstream, so ``make_hooks`` returns stable
function identities — they are static jit arguments in ``core.lanczos``.
New backends (e.g. a sharded decomposition backend) register themselves
with :func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from ..core.lanczos import (DEFAULT_BATCHED_HOOKS, BatchedLanczosHooks)


@dataclasses.dataclass(frozen=True)
class Backend:
    """One way of executing the batched Lanczos inner steps."""
    name: str
    make_hooks: Callable[[int], BatchedLanczosHooks]   # expansion -> hooks
    requires_padding: bool      # S and H must divide by the expansion factor
    batched_launch: bool        # True: one kernel launch covers the batch


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown decompose backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def available_backends():
    return sorted(_REGISTRY)


def _reference_hooks(expansion: int) -> BatchedLanczosHooks:
    del expansion                       # reference steps need no blocking
    return DEFAULT_BATCHED_HOOKS


def _pallas_interpret_hooks(expansion: int) -> BatchedLanczosHooks:
    from ..kernels import ops
    return ops.make_batched_pallas_hooks(expansion, interpret=True)


def _pallas_hooks(expansion: int) -> BatchedLanczosHooks:
    from ..kernels import ops
    return ops.make_batched_pallas_hooks(expansion, interpret=False)


def _pallas_vmap_hooks(expansion: int) -> BatchedLanczosHooks:
    from ..kernels import ops
    return ops.make_vmapped_pallas_hooks(expansion, interpret=True)


register_backend(Backend("reference", _reference_hooks,
                         requires_padding=False, batched_launch=True))
register_backend(Backend("pallas_interpret", _pallas_interpret_hooks,
                         requires_padding=True, batched_launch=True))
register_backend(Backend("pallas", _pallas_hooks,
                         requires_padding=True, batched_launch=True))
register_backend(Backend("pallas_vmap", _pallas_vmap_hooks,
                         requires_padding=True, batched_launch=False))
