"""Single derivation point for the Pallas ``interpret`` flag.

Every kernel used to default ``interpret=True`` (this container is
CPU-only), which meant a real TPU deployment had to pass
``interpret=False`` at every call site.  The flag is now derived ONCE from
the platform: interpret mode everywhere except a real TPU, where the same
BlockSpecs compile via Mosaic with no manual flags.

Kernel modules resolve their ``interpret=None`` default through
:func:`resolve_interpret`; ``kernels.ops`` seeds its module-level
``INTERPRET`` escape hatch from :func:`default_interpret`.  The answer is
memoized — the process's device set is fixed after jax initializes, so a
per-call re-check would only add dispatch latency.
"""
from __future__ import annotations

import functools
from typing import Optional


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True unless this process runs on a real TPU."""
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(flag: Optional[bool]) -> bool:
    """None → the platform default; an explicit flag always wins."""
    return default_interpret() if flag is None else bool(flag)
