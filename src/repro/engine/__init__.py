"""Unified batched decomposition engine (see DESIGN.md §3).

One :class:`DecomposeEngine` owns the full activation-decomposition
pipeline — batched Lanczos, backend dispatch, outlier multi-track,
preserved-form consumption — and is the single entry point for
``models/decomposed*.py``, ``runtime/steps.py``, ``serving``, and
``launch/serve.py``.
"""
from .backends import (Backend, available_backends, get_backend,
                       register_backend)
from .config import EngineConfig
from .engine import DecomposeEngine, make_engine
from .platform import default_interpret, resolve_interpret

__all__ = ["Backend", "DecomposeEngine", "EngineConfig",
           "available_backends", "default_interpret", "get_backend",
           "make_engine", "register_backend", "resolve_interpret"]
