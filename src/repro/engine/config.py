"""EngineConfig — the single configuration surface of the decomposition
pipeline.

The paper's thesis is that activation decomposition only pays off when the
whole pipeline (progressive Lanczos + compute expansion + shape-preserving
consumption + multi-track outliers) is co-designed.  EngineConfig therefore
folds every axis that used to be wired per-callsite — per-layer policy
(``core.policy``), outlier thresholds (``core.outlier``), preserved-form
consumption (``core.preserved``), kernel backend and expansion factor —
into one frozen value from which a :class:`~repro.engine.DecomposeEngine`
is built exactly once and then threaded through models/runtime/serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from ..core.outlier import ThresholdTable
from ..core.policy import DecompositionPolicy, LayerPolicy


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything a DecomposeEngine needs, chosen once.

    * ``policy``      — per-layer decomposition directives (§6.2); None means
                        "no layer policy" (raw / KV-only use, e.g. serving).
    * ``backend``     — registry key: ``"reference"`` (pure jnp),
                        ``"pallas_interpret"`` (batched fused kernels,
                        interpreter), ``"pallas"`` (compiled, TPU),
                        ``"pallas_vmap"`` (vmap-of-scalar fallback) — or
                        ``"auto"``: resolved at engine build through
                        ``repro.tune`` (measured cache override, else
                        platform heuristic).
    * ``expansion``   — the D-com compute-expansion factor f (Pallas grid
                        size along the reduced axis), or ``"auto"``: the
                        engine resolves f per shape-bucket through the
                        ``repro.tune`` cost model + tuning cache
                        (DESIGN.md §6).
    * ``attn_mode``   — ``"dense"`` | ``"preserved"`` consumption of the
                        decomposed QKV inputs (paper §3.2).
    * ``kv_rank`` / ``kv_tail`` / ``kv_iters_extra`` — decomposed-KV-cache
                        serving knobs (rank 0 disables); ``kv_exact``
                        switches prefill factorization to direct SVD
                        (near-full-rank regime, §2.3).
    * ``kv_page`` / ``kv_pool_pages`` / ``kv_prefix_cache`` — paged-cache
                        geometry (``serving.Engine(paged=True)``):
                        rows per page, total pool pages (0 = sized from
                        slots × max_len with fold headroom), and the
                        prefix-cache entry capacity (0 = no prefix
                        reuse).
    * ``sched_*``     — serving-scheduler knobs: prefill COSTS (the
                        family-reported prompt length plus any modality
                        constant, e.g. VLM image rows — see
                        ``serving.families``) round up to multiples of
                        ``sched_bucket`` (bounds the set of prefill
                        shapes, hence re-jits), admission is checked
                        every ``sched_admit_every`` decode rounds
                        (prefill/decode interleaving policy), and one
                        admission batch takes at most ``sched_max_admit``
                        requests (0 = as many as there are free slots).
                        These and ``decode_block`` apply to EVERY
                        registered ServingFamily, not just the
                        decomposed-KV path.
    * ``decode_block`` — fused decode steps per device launch (serving):
                        1 (default) is the classic one-dispatch-per-token
                        loop; N > 1 runs up to N steps inside one jitted
                        on-device loop (token-exact — see DESIGN.md §11);
                        ``"auto"`` picks N through ``repro.tune``.
    * ``prefill_async`` — serving: dispatch admissions (forward prefill +
                        Lanczos, or prefix-suffix prefill) asynchronously
                        and splice results into slots only when ready, so
                        decode never blocks on an in-flight decomposition
                        (vLLM-style P/D disaggregation — DESIGN.md §12).
                        False (default) keeps the synchronous path.
    * ``mesh``        — optional ``jax.sharding.Mesh``: the engine runs its
                        jitted Lanczos pipeline DP-sharded over the batch
                        axis (explicit in/out shardings; ``shard_map`` for
                        Pallas kernel backends so each device launches its
                        own grid), and a serving engine built from this
                        config shards its decode caches with
                        ``distributed.sharding.cache_sharding``.  None (the
                        default) is the single-device path, bit-identical
                        to pre-mesh behavior.
    """
    policy: Optional[DecompositionPolicy] = None
    backend: str = "reference"
    expansion: Union[int, str] = 8      # int f, or "auto" (tuner-resolved)
    attn_mode: str = "dense"            # "dense" | "preserved"
    kv_rank: int = 0
    kv_tail: int = 128
    kv_iters_extra: int = 8
    kv_exact: bool = False
    kv_page: int = 16                   # rows per page (paged serving)
    kv_pool_pages: int = 0              # page-pool size (0 = auto-sized)
    kv_prefix_cache: int = 0            # prefix-cache entries (0 = off)
    sched_bucket: int = 16
    sched_admit_every: int = 1
    sched_max_admit: int = 0
    decode_block: Union[int, str] = 1   # fused decode steps/launch, or "auto"
    prefill_async: bool = False         # async P/D split (serving.Engine)
    mesh: Optional[Any] = None          # jax.sharding.Mesh (hashable)

    def __post_init__(self):
        if self.expansion != "auto" and (
                not isinstance(self.expansion, int) or self.expansion < 1):
            raise ValueError(
                f"expansion must be a positive int or 'auto', "
                f"got {self.expansion!r}")
        if self.decode_block != "auto" and (
                not isinstance(self.decode_block, int)
                or self.decode_block < 1):
            raise ValueError(
                f"decode_block must be a positive int or 'auto', "
                f"got {self.decode_block!r}")

    def layer(self, idx: int) -> LayerPolicy:
        if self.policy is None:
            return LayerPolicy(decompose=False)
        return self.policy.layer(idx)

    def threshold(self, idx: int) -> float:
        if self.policy is None:
            return ThresholdTable().default
        return self.policy.thresholds.get(idx)

    def with_policy(self, policy: DecompositionPolicy) -> "EngineConfig":
        return dataclasses.replace(self, policy=policy)
