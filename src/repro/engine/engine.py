"""DecomposeEngine — the one owner of the activation-decomposition pipeline.

Every consumer (``models/decomposed*.py``, ``runtime/steps.py``,
``serving``, ``launch/serve.py``) constructs ONE engine from an
:class:`~repro.engine.config.EngineConfig` and obtains decomposition
exclusively through it.  The engine owns, end to end:

1. **Backend dispatch** — jnp reference / Pallas interpret / Pallas
   compiled / vmap fallback, selected once at construction (never per op).
2. **Batched Lanczos** — ``decompose`` runs the natively batched pipeline:
   one fused kernel launch per Lanczos pass for the whole [B, S, H] batch.
3. **Shape plumbing** — kernel backends need the reduced axes to divide the
   expansion factor; the engine pads through the cached plan in
   ``kernels.ops`` (``padded_dims``/``pad_plan``) and slices factors back.
   The start vector is zero-padded, so pad rows/columns stay EXACTLY zero
   through every iteration — padded and unpadded runs are the same math.
4. **Multi-track outliers** — ``decompose_activation`` applies the per-layer
   policy (rank, iters, outlier fraction, calibrated threshold) before the
   base-track Lanczos and re-attaches the dense outlier track (paper §4).
5. **Preserved consumption** — Eq. 6/7 projections and the factored
   attention contractions (paper §3.2) are exposed as engine methods so the
   consumption side of the pipeline rides the same object.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import lanczos as lz
from ..core import outlier as ol
from ..obs import GLOBAL as _OBS, bucket_label
from ..core.lowrank import LowRank, add_bias_rank, from_dense_svd
from ..core.policy import LayerPolicy
from ..core.preserved import (decompose_weight, lowrank_matmul,
                              lowrank_x_lowrank_weight, preserved_pv,
                              preserved_qk_scores)
from .backends import Backend, get_backend
from .config import EngineConfig

Array = jax.Array


@functools.lru_cache(maxsize=None)
def _padded_z0(h_dim: int, h_pad: int) -> np.ndarray:
    """Fixed start direction of the UNPADDED width, zero-extended: pad
    components then stay exactly zero through every re-orth step, so all
    backends (padded or not) run the same arithmetic.  Cached per width so
    the per-layer hot path doesn't re-dispatch the eager normal+pad; the
    value is identical to the default the jitted core generates (same key,
    same shape, deterministic threefry).

    The cache holds the HOST-side numpy value, never a committed device
    array: jit places it per call site, so the same entry serves every
    device/mesh and the cache cannot pin stale device buffers (it used to
    hold device arrays keyed only on widths — wrong device under a mesh
    and a per-width buffer leak)."""
    with jax.ensure_compile_time_eval():     # concrete even under a trace
        z0 = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (h_dim,),
                                          jnp.float32))
    return np.pad(z0, (0, h_pad - h_dim))


@functools.lru_cache(maxsize=None)
def _sharded_decompose(mesh, batch_spec: P, rank: int, iters: int, hooks,
                       use_shard_map: bool):
    """Jitted Lanczos pipeline with EXPLICIT in/out shardings on ``mesh``.

    ``batch_spec`` shards the flat [B, S, H] batch axis over the mesh's DP
    super-axis (P() = replication fallback when B doesn't divide).  Two
    lowerings, same math:

    * plain jit + in/out shardings — GSPMD partitions the batched einsum
      steps (reference backend; every op is batch-parallel so no
      collectives appear),
    * ``shard_map`` over DP — each device runs the decomposition on ITS
      batch shard with a device-local Pallas grid (kernel backends: the
      grid is sized by the LOCAL batch, which a global-view lowering
      cannot express).

    Cached per (mesh, spec, rank, iters, hooks, lowering) so serving's
    per-prefill hot path reuses one executable.
    """
    dp = batch_spec[0] if len(batch_spec) else None

    def run(xf: Array, z0: Array):
        return lz.decompose(xf, rank, iters=iters, batched_hooks=hooks,
                            z0=z0)

    if use_shard_map and dp is not None:
        from jax.experimental.shard_map import shard_map
        in_specs = (P(dp, None, None), P())
        out_specs = LowRank(P(dp, None, None), P(dp, None), P(dp, None, None))
        return jax.jit(shard_map(run, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))
    x_sh = NamedSharding(mesh, P(dp, None, None))
    z_sh = NamedSharding(mesh, P())
    out_sh = LowRank(NamedSharding(mesh, P(dp, None, None)),
                     NamedSharding(mesh, P(dp, None)),
                     NamedSharding(mesh, P(dp, None, None)))
    return jax.jit(run, in_shardings=(x_sh, z_sh), out_shardings=out_sh)


class DecomposeEngine:
    """Single entry point for every decomposition in the system."""

    def __init__(self, config: EngineConfig):
        self.config = config
        backend_name = config.backend
        if backend_name == "auto":
            # tuner-resolved at build: measured cache override when
            # benchmarks/run.py --tune ran on this machine, else the
            # platform heuristic (Mosaic on TPU, jnp reference on CPU)
            from .. import tune
            backend_name = tune.resolve_backend()
        self.backend: Backend = get_backend(backend_name)
        self._auto_expansion = config.expansion == "auto"
        # Hooks resolved ONCE for a fixed f; factories are lru-cached
        # upstream so the returned functions hash stably as static jit
        # arguments.  With expansion="auto" the f — and therefore the
        # hooks — resolve per shape-bucket at decompose time through the
        # tuner's in-process lru (same cached factories, same identities
        # as a fixed-f engine at that f).
        self._hooks = None if self._auto_expansion \
            else self.backend.make_hooks(config.expansion)

    # -- config passthroughs ---------------------------------------------
    def layer_policy(self, idx: int) -> LayerPolicy:
        return self.config.layer(idx)

    def threshold(self, idx: int) -> float:
        return self.config.threshold(idx)

    @property
    def attn_mode(self) -> str:
        return self.config.attn_mode

    @property
    def resolved_backend(self) -> str:
        """The registry key actually in use (``"auto"`` resolved)."""
        return self.backend.name

    def resolve_expansion(self, s_dim: int, h_dim: int, batch: int = 1,
                          dtype: object = "float32") -> int:
        """The expansion factor f this engine runs a [batch, S, H]
        decomposition at: the configured int, or — for ``"auto"`` — the
        ``repro.tune`` answer for this shape-bucket (cache hit / cost
        model; in-process lru, so the per-layer hot path is a dict
        lookup)."""
        if not self._auto_expansion:
            return self.config.expansion
        from .. import tune
        return tune.tuned_expansion((int(batch), int(s_dim), int(h_dim)),
                                    dtype=str(dtype),
                                    backend=self.backend.name)

    # -- stage 1: batched Lanczos decomposition ---------------------------
    def decompose(self, x: Array, rank: int,
                  iters: Optional[int] = None) -> LowRank:
        """x [..., S, H] → LowRank via the engine's backend.

        One natively batched Lanczos run; kernel backends get zero-padding
        to the cached (S_pad, H_pad) plan and exact slice-back.
        """
        from ..kernels import ops
        s_dim, h_dim = x.shape[-2:]
        batch = 1
        for d in x.shape[:-2]:
            batch *= int(d)
        f = self.resolve_expansion(s_dim, h_dim, max(1, batch), x.dtype)
        hooks = self._hooks if self._hooks is not None \
            else self.backend.make_hooks(f)
        pad = self.backend.requires_padding
        if pad:
            s_pad, h_pad = ops.padded_dims(s_dim, h_dim, f)
            pad = (s_pad, h_pad) != (s_dim, h_dim)
        if pad:
            widths = [(0, 0)] * (x.ndim - 2) + \
                [(0, s_pad - s_dim), (0, h_pad - h_dim)]
            xp = jnp.pad(x, widths)
            # zero-extended start vector keeps pad rows/cols exactly zero,
            # so padded and unpadded runs are the same arithmetic
            z0 = _padded_z0(h_dim, h_pad)
        else:
            xp, z0 = x, None        # jitted core generates the same z0
        # decomposition telemetry (DESIGN.md §13): one counter bump per
        # decompose call, labeled with the pow2 shape bucket, the RESOLVED
        # backend and expansion f, and which execution path ran.  Host-side
        # only — the landscape of what actually decomposed, per process.
        path = "sharded" if self.config.mesh is not None else "local"
        _OBS.counter(
            "decompose_total", "batched Lanczos decompositions",
            bucket=bucket_label(max(1, batch), s_dim, h_dim),
            backend=self.backend.name, f=str(f), path=path).inc()
        if self.config.mesh is not None:
            lr = self._decompose_sharded(xp, rank, iters, hooks, z0)
        else:
            lr = lz.decompose(xp, rank, iters=iters,
                              batched_hooks=hooks, z0=z0)
        if pad:
            lr = LowRank(lr.u[..., :s_dim, :], lr.core,
                         lr.vt[..., :h_dim])
        return lr

    def _decompose_sharded(self, xp: Array, rank: int,
                           iters: Optional[int], hooks, z0) -> LowRank:
        """Run the batched Lanczos pipeline DP-sharded over ``config.mesh``.

        The flat batch axis shards over the DP super-axis when it divides
        (replication fallback otherwise — the same divisibility guard as
        every rule in ``distributed.sharding``).  The per-element math is
        identical to the unsharded path: the explicit ``z0`` equals the
        default the jitted core generates, every op is batch-parallel, and
        kernel backends go through ``shard_map`` so each device launches a
        grid over its LOCAL batch shard.
        """
        from ..distributed import sharding as sh
        mesh = self.config.mesh
        iters = rank if iters is None else iters
        batch_shape = xp.shape[:-2]
        flat = xp.reshape((-1,) + xp.shape[-2:])
        if z0 is None:
            # same key/shape as the jitted core's default → same values
            z0 = _padded_z0(flat.shape[-1], flat.shape[-1])
        dp_sz = sh.axis_size(mesh, sh.dp_axes(mesh))
        shard = flat.shape[0] % dp_sz == 0 and flat.shape[0] > 0
        spec = P(sh.dp_name(mesh)) if shard else P()
        fn = _sharded_decompose(mesh, spec, rank, iters, hooks,
                                self.backend.requires_padding)
        lr = fn(flat, np.asarray(z0, np.float32))
        return LowRank(lr.u.reshape(batch_shape + lr.u.shape[1:]),
                       lr.core.reshape(batch_shape + lr.core.shape[1:]),
                       lr.vt.reshape(batch_shape + lr.vt.shape[1:]))

    # -- stage 2: policy-driven multi-track activation decomposition ------
    def decompose_activation(self, x: Array, layer_idx: Optional[int] = None,
                             lp: Optional[LayerPolicy] = None,
                             threshold: Optional[float] = None) -> LowRank:
        """x [B, S, H] → LowRank with dense outlier channel track.

        Each prompt decomposes independently (paper §3.1); outlier channel
        count is the static ``round(outlier_frac · H)`` with the layer's
        calibrated threshold (paper §4).
        """
        if lp is None:
            lp = self.layer_policy(layer_idx)
        if threshold is None:
            threshold = self.threshold(layer_idx)
        h_dim = x.shape[-1]
        num_c = max(1, round(lp.outlier_frac * h_dim)) \
            if lp.outlier_frac > 0 else 0
        x32 = x.astype(jnp.float32)
        if num_c:
            base, vals, idx = ol.extract(
                x32, jnp.asarray(threshold, jnp.float32), num_c)
        else:
            base = x32
        lr = self.decompose(base, lp.rank, iters=lp.effective_iters)
        lr = lr.astype(x.dtype)
        if num_c:
            lr = ol.attach_dense_outliers(lr, vals.astype(x.dtype), idx)
        return lr

    # -- KV-cache decomposition (serving) ---------------------------------
    def decompose_kv(self, x: Array, rank: int,
                     iters: Optional[int] = None,
                     exact: bool = False) -> Tuple[Array, Array]:
        """x [B, T, kvw] → (U·Σ [B, T, r], Vᵀ [B, r, kvw]).

        Lanczos through the engine backend for r ≪ min(T, kvw); ``exact``
        switches to direct SVD — used when r approaches full rank, where
        floating-point Lanczos loses trailing directions (§2.3).  The
        requested rank caps at min(T, kvw) — a factorization cannot carry
        more directions than the matrix has."""
        rank = min(rank, *x.shape[-2:])
        _OBS.counter("decompose_kv_total", "KV-cache factorizations",
                     mode="exact" if exact else "lanczos",
                     bucket=bucket_label(*x.shape[-2:])).inc()
        if exact:
            lr = from_dense_svd(x.astype(jnp.float32), rank)
        else:
            iters = iters or min(rank + self.config.kv_iters_extra,
                                 min(x.shape[-2:]))
            lr = self.decompose(x.astype(jnp.float32), rank, iters=iters)
        return lr.scaled_u().astype(x.dtype), lr.vt.astype(x.dtype)

    # -- stage 3: preserved-form consumption (paper §3.2) -----------------
    def project(self, lr: LowRank, wp, wfac: Optional[LowRank] = None
                ) -> LowRank:
        """Preserved matmul through a layer's weight dict ``{"w": …[, "b"]}``;
        uses the Eq. 7 input+weight chain when an offline weight factor is
        supplied."""
        if wfac is not None:
            y = lowrank_x_lowrank_weight(lr, wfac)
            if "b" in wp:
                y = add_bias_rank(y, wp["b"])   # exact rank-1 bias fold
            return y
        return lowrank_matmul(lr, wp["w"], bias=wp.get("b"))

    def qk_scores(self, q: LowRank, k: LowRank, num_heads: int, scale: float,
                  num_kv_heads: Optional[int] = None) -> Array:
        return preserved_qk_scores(q, k, num_heads, scale, num_kv_heads)

    def pv(self, p: Array, v: LowRank, num_heads: int,
           num_kv_heads: Optional[int] = None) -> Array:
        return preserved_pv(p, v, num_heads, num_kv_heads)

    def decompose_weight(self, w: Array, rank: int) -> LowRank:
        """Offline weight factorization (Table 3 mode) — exact SVD."""
        return decompose_weight(w, rank)

    def __repr__(self) -> str:
        exp = "auto" if self._auto_expansion else self.config.expansion
        return (f"DecomposeEngine(backend={self.backend.name!r}, "
                f"expansion={exp}, "
                f"attn_mode={self.config.attn_mode!r}, "
                f"kv_rank={self.config.kv_rank})")


def make_engine(policy=None, backend: str = "reference", **kw
                ) -> DecomposeEngine:
    """Convenience constructor: ``make_engine(policy, backend="pallas")``."""
    return DecomposeEngine(EngineConfig(policy=policy, backend=backend, **kw))
