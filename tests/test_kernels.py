"""Per-kernel allclose vs ref.py across shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


SHAPES = [(64, 128), (128, 512), (256, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]
EXPANSIONS = [2, 4, 8]


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("f", [4, 8])
def test_matvec(shape, dtype, f):
    s, h = shape
    a = _mk(jax.random.PRNGKey(0), (s, h), dtype)
    v = _mk(jax.random.PRNGKey(1), (h,), dtype)
    got = ops.matvec(a, v, expansion=f)
    want = ref.matvec(a, v)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dtype == jnp.bfloat16
                               else 1e-5, atol=1e-1)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("f", EXPANSIONS)
def test_rmatvec(shape, f):
    s, h = shape
    a = _mk(jax.random.PRNGKey(2), (s, h), jnp.float32)
    u = _mk(jax.random.PRNGKey(3), (s,), jnp.float32)
    np.testing.assert_allclose(ops.rmatvec(a, u, expansion=f),
                               ref.rmatvec(a, u), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("s,h", [(64, 128), (520, 128)])  # 520 % 512 != 0
def test_matvec_batched(b, s, h):
    """One launch over the batch == per-element oracle (incl. a row count
    that is NOT divisible by the default row_block)."""
    a = _mk(jax.random.PRNGKey(40), (b, s, h), jnp.float32)
    v = _mk(jax.random.PRNGKey(41), (b, h), jnp.float32)
    u = _mk(jax.random.PRNGKey(42), (b, s), jnp.float32)
    y = ops.matvec_batched(a, v, expansion=4)
    z = ops.rmatvec_batched(a, u, expansion=4)
    for i in range(b):
        np.testing.assert_allclose(np.asarray(y[i]),
                                   np.asarray(ref.matvec(a[i], v[i])),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(z[i]),
                                   np.asarray(ref.rmatvec(a[i], u[i])),
                                   rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("k", [4, 12])
def test_reorth_batched_matches_scalar(b, k):
    """Batched fused re-orth (grid (B,3,f)) == the scalar kernel per prompt."""
    s, h, f = 64, 128, 8
    a = _mk(jax.random.PRNGKey(50), (b, s, h), jnp.float32)
    u = _mk(jax.random.PRNGKey(51), (b, s), jnp.float32)
    v = _mk(jax.random.PRNGKey(52), (b, h), jnp.float32)
    qv = jnp.stack([jnp.linalg.qr(_mk(jax.random.PRNGKey(53 + i),
                                      (h, k), jnp.float32))[0]
                    for i in range(b)])
    qu = jnp.stack([jnp.linalg.qr(_mk(jax.random.PRNGKey(63 + i),
                                      (s, k), jnp.float32))[0]
                    for i in range(b)])
    z, zn = ops.reorth_right_batched(a, u, qv, expansion=f)
    w, wn = ops.reorth_left_batched(a, v, qu, expansion=f)
    for i in range(b):
        z_i, zn_i = ops.reorth_right(a[i], u[i], qv[i], expansion=f)
        w_i, wn_i = ops.reorth_left(a[i], v[i], qu[i], expansion=f)
        np.testing.assert_allclose(np.asarray(z[i]), np.asarray(z_i),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(w[i]), np.asarray(w_i),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(float(zn[i]), float(zn_i), rtol=1e-5)
        np.testing.assert_allclose(float(wn[i]), float(wn_i), rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k", [8, 16])
@pytest.mark.parametrize("f", [4, 8])
def test_reorth_right(shape, k, f):
    s, h = shape
    a = _mk(jax.random.PRNGKey(4), (s, h), jnp.float32)
    u = _mk(jax.random.PRNGKey(5), (s,), jnp.float32)
    q = jnp.linalg.qr(_mk(jax.random.PRNGKey(6), (h, k), jnp.float32))[0]
    z, n2 = ops.reorth_right(a, u, q, expansion=f)
    z_ref, n2_ref = ref.reorth_right(a, u, q)
    np.testing.assert_allclose(z, z_ref, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(n2, n2_ref, rtol=1e-4)
    # the defining property: output orthogonal to the Q columns
    assert float(jnp.abs(q.T @ z).max()) < 1e-3


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("f", [4, 8])
def test_reorth_left(shape, f):
    s, h = shape
    a = _mk(jax.random.PRNGKey(7), (s, h), jnp.float32)
    v = _mk(jax.random.PRNGKey(8), (h,), jnp.float32)
    q = jnp.linalg.qr(_mk(jax.random.PRNGKey(9), (s, 12), jnp.float32))[0]
    z, n2 = ops.reorth_left(a, v, q, expansion=f)
    z_ref, n2_ref = ref.reorth_left(a, v, q)
    np.testing.assert_allclose(z, z_ref, rtol=1e-4, atol=1e-2)
    assert float(jnp.abs(q.T @ z).max()) < 1e-3


@pytest.mark.parametrize("k", [4, 10, 16])
@pytest.mark.parametrize("n", [128, 384])
@pytest.mark.parametrize("f", [4, 8])
def test_lowrank_matmul(k, n, f):
    vt = _mk(jax.random.PRNGKey(10), (k, 512), jnp.float32)
    w = _mk(jax.random.PRNGKey(11), (512, n), jnp.float32) * 0.1
    np.testing.assert_allclose(ops.lowrank_matmul(vt, w, expansion=f),
                               ref.lowrank_matmul(vt, w),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("t", [0.5, 1.5, 3.0])
def test_outlier_stats(shape, t):
    a = _mk(jax.random.PRNGKey(12), shape, jnp.float32)
    cnt, mx = ops.outlier_stats(a, t, expansion=4)
    cnt_ref, mx_ref = ref.outlier_stats(a, t)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))
    np.testing.assert_allclose(mx, mx_ref, rtol=1e-6)


def test_pallas_hooks_full_lanczos():
    """End-to-end: Lanczos with Pallas fused steps == jnp reference."""
    from repro.core import lanczos_svd
    a = jax.random.normal(jax.random.PRNGKey(13), (128, 8)) @ \
        jax.random.normal(jax.random.PRNGKey(14), (8, 256))
    hooks = ops.make_pallas_hooks(expansion=8)
    u1, s1, v1 = lanczos_svd(a, rank=8, iters=12, hooks=hooks)
    u2, s2, v2 = lanczos_svd(a, rank=8, iters=12)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3)
    rec = (u1 * s1) @ v1
    assert float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a)) < 1e-3


@pytest.mark.parametrize("t", [128, 512])
@pytest.mark.parametrize("g,r", [(4, 16), (8, 32)])
@pytest.mark.parametrize("f", [4, 8])
def test_dkv_attention_stats(t, g, r, f):
    """Rank-space flash stats == full-score oracle."""
    inner = _mk(jax.random.PRNGKey(20), (g, r), jnp.float32)
    k_u = _mk(jax.random.PRNGKey(21), (t, r), jnp.float32)
    v_u = _mk(jax.random.PRNGKey(22), (t, r), jnp.float32)
    a, m, l = ops.dkv_attention_stats(inner, k_u, v_u, expansion=f)
    a_r, m_r, l_r = ref.dkv_attention_stats(inner, k_u, v_u)
    np.testing.assert_allclose(m, m_r, rtol=1e-5)
    np.testing.assert_allclose(l, l_r, rtol=1e-4)
    np.testing.assert_allclose(a, a_r, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("t", [1, 7, 100, 130])
@pytest.mark.parametrize("f", [4, 8])
def test_dkv_attention_stats_arbitrary_length(t, f):
    """Non-divisible cache lengths (incl. t < f, where whole grid blocks
    are padding): the wrapper pads the time axis through the cached pad
    plan and the kernel masks pad rows out of the softmax EXACTLY."""
    g, r = 4, 16
    inner = _mk(jax.random.PRNGKey(30), (g, r), jnp.float32)
    k_u = _mk(jax.random.PRNGKey(31), (t, r), jnp.float32)
    v_u = _mk(jax.random.PRNGKey(32), (t, r), jnp.float32)
    a, m, l = ops.dkv_attention_stats(inner, k_u, v_u, expansion=f)
    a_r, m_r, l_r = ref.dkv_attention_stats(inner, k_u, v_u)
    np.testing.assert_allclose(m, m_r, rtol=1e-5)
    np.testing.assert_allclose(l, l_r, rtol=1e-4)
    np.testing.assert_allclose(a, a_r, rtol=1e-4, atol=1e-3)


def test_dkv_attention_stats_padding_is_bit_exact():
    """Padded launch (t=96+pad at f=8 → 96 divisible; compare t=90) must
    equal slicing a longer divisible launch's inputs — the masked rows
    contribute literal zeros, not epsilon."""
    g, r, f = 4, 8, 8
    inner = _mk(jax.random.PRNGKey(33), (g, r), jnp.float32)
    k_u = _mk(jax.random.PRNGKey(34), (96, r), jnp.float32)
    v_u = _mk(jax.random.PRNGKey(35), (96, r), jnp.float32)
    # oracle on the 90-row prefix, computed WITHOUT padding (f=1 divides)
    a1, m1, l1 = ops.dkv_attention_stats(inner, k_u[:90], v_u[:90],
                                         expansion=1)
    a8, m8, l8 = ops.dkv_attention_stats(inner, k_u[:90], v_u[:90],
                                         expansion=f)
    np.testing.assert_allclose(np.asarray(m8), np.asarray(m1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a8), np.asarray(a1),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("perm_seed,t_valid", [(0, 32), (1, 27), (2, 9)])
def test_dkv_attention_stats_paged_matches_contiguous(perm_seed, t_valid):
    """Paged stats (blocks DMA'd by prefetched page id through the block
    table) are BIT-IDENTICAL to the contiguous kernel run on the gathered
    rows at expansion == n_pages: same block partitioning, same online-
    softmax math — only the addressing differs.  Covers permuted page
    order and a partially filled last page (t_valid < n·page)."""
    P, page, g, r, n = 12, 8, 4, 16, 4
    rng = np.random.RandomState(50 + perm_seed)
    pools_k = jnp.asarray(rng.randn(P, page, r).astype(np.float32))
    pools_v = jnp.asarray(rng.randn(P, page, r).astype(np.float32))
    inner = jnp.asarray(rng.randn(g, r).astype(np.float32))
    ids = jnp.asarray(rng.permutation(np.arange(1, P))[:n].astype(np.int32))
    a_p, m_p, l_p = ops.dkv_attention_stats_paged(
        inner, pools_k, pools_v, ids, t_valid=t_valid)
    from repro.kernels import dkv_attention as _dkv
    gath_k = pools_k[ids].reshape(-1, r)
    gath_v = pools_v[ids].reshape(-1, r)
    a_c, m_c, l_c = _dkv.dkv_attention_stats(inner, gath_k, gath_v,
                                             expansion=n, t_valid=t_valid,
                                             interpret=True)
    assert (np.asarray(a_p) == np.asarray(a_c)).all()
    assert (np.asarray(m_p) == np.asarray(m_c)).all()
    assert (np.asarray(l_p) == np.asarray(l_c)).all()


def test_dkv_merge_with_tail_exact():
    """Kernel stats + dense-tail merge == softmax over the full sequence."""
    g, r, t, tl, d = 4, 8, 256, 16, 32
    inner = _mk(jax.random.PRNGKey(23), (g, r), jnp.float32)
    k_u = _mk(jax.random.PRNGKey(24), (t, r), jnp.float32)
    v_u = _mk(jax.random.PRNGKey(25), (t, r), jnp.float32)
    v_vt = _mk(jax.random.PRNGKey(26), (r, d), jnp.float32)
    tail_sc = _mk(jax.random.PRNGKey(27), (g, tl), jnp.float32)
    tail_v = _mk(jax.random.PRNGKey(28), (tl, d), jnp.float32)

    a, m, l = ops.dkv_attention_stats(inner, k_u, v_u, expansion=8)
    out = ops.merge_with_tail(a, m, l, v_vt, tail_sc, tail_v)

    # oracle: one softmax over [prefix scores | tail scores]
    s_pre = inner @ k_u.T
    s_all = jnp.concatenate([s_pre, tail_sc], axis=1)
    p_all = jax.nn.softmax(s_all, axis=1)
    v_pre = v_u @ v_vt                    # [t, d] reconstructed prefix V
    v_all = jnp.concatenate([v_pre, tail_v], axis=0)
    out_ref = p_all @ v_all
    np.testing.assert_allclose(out, out_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,nh,hd", [(16, 4, 8), (32, 8, 16), (64, 4, 32)])
@pytest.mark.parametrize("hb", [2, 4])
def test_ssd_chunk_intra(q, nh, hd, hb):
    """Fused intra-chunk SSD == materialized masked-decay oracle."""
    g = 3
    cb = _mk(jax.random.PRNGKey(30), (g, q, q), jnp.float32) * 0.3
    # log-decay must be non-increasing along the chunk (cumsum of negatives)
    da = -jnp.abs(_mk(jax.random.PRNGKey(31), (g, q, nh), jnp.float32)) * 0.05
    l = jnp.cumsum(da, axis=1)
    dt = jnp.abs(_mk(jax.random.PRNGKey(32), (g, q, nh), jnp.float32))
    x = _mk(jax.random.PRNGKey(33), (g, q, nh, hd), jnp.float32)
    got = ops.ssd_chunk_intra(cb, l, dt, x, head_block=hb)
    want = ref.ssd_chunk_intra(cb, l, dt, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_matches_model_math():
    """The kernel reproduces mamba2.ssd_apply's intra-chunk term exactly."""
    q, nh, hd, ds = 16, 4, 8, 8
    g = 2
    key = jax.random.PRNGKey(40)
    cm = jax.random.normal(key, (g, q, ds))
    bm = jax.random.normal(jax.random.PRNGKey(41), (g, q, ds))
    cb = jnp.einsum("gqd,gsd->gqs", cm, bm)
    da = -jnp.abs(jax.random.normal(jax.random.PRNGKey(42), (g, q, nh))) * 0.1
    l = jnp.cumsum(da, axis=1)
    dt = jnp.abs(jax.random.normal(jax.random.PRNGKey(43), (g, q, nh)))
    xh = jax.random.normal(jax.random.PRNGKey(44), (g, q, nh, hd))
    # model formulation (mamba2.ssd_apply intra-chunk lines)
    decay = jnp.exp(l[:, :, None, :] - l[:, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = cb[..., None] * jnp.where(mask[None, :, :, None], decay, 0.0) \
        * dt[:, None, :, :]
    y_model = jnp.einsum("gqsn,gsnd->gqnd", m, xh)
    y_kernel = ops.ssd_chunk_intra(cb, l, dt, xh, head_block=4)
    np.testing.assert_allclose(y_kernel, y_model, rtol=1e-4, atol=1e-4)
