"""Serving engine: continuous batching, slot reuse, stats."""
import jax
import numpy as np

from repro.configs import all_archs
from repro.models import model_fns
from repro.serving import Engine, Request


def _engine(slots=2, max_len=48):
    cfg = all_archs()["llama2-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    return cfg, Engine(cfg, params, slots=slots, max_len=max_len)


def test_completes_all_requests():
    cfg, eng = _engine()
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab, 8,
                                              dtype=np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) >= 4 for r in done)
    assert all(0 <= t < cfg.padded_vocab for r in done for t in r.out_tokens)


def test_continuous_batching_reuses_slots():
    cfg, eng = _engine(slots=2)
    rng = np.random.RandomState(1)
    for i in range(6):
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, 4,
                                                     dtype=np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 6
    assert eng.stats.prefills == 6      # counted PER REQUEST, not per gang
    assert eng.stats.prefill_batches >= 3   # 6 requests / 2 slots
    assert eng.stats.tokens_out > 0
    assert len(eng.stats.ttft_s) == 6   # one first-token latency each
    assert eng.stats.mean_ttft_s > 0.0


def test_deterministic_outputs():
    cfg, e1 = _engine()
    _, e2 = _engine()
    prompt = np.arange(8, dtype=np.int32)
    for e in (e1, e2):
        e.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    o1 = e1.run()[0].out_tokens
    o2 = e2.run()[0].out_tokens
    assert o1 == o2


def test_admission_preserves_live_sequences():
    """Admitting new requests must not corrupt in-flight KV (splice path)."""
    cfg, eng_mixed = _engine(slots=2, max_len=64)
    prompt = np.arange(8, dtype=np.int32)
    # reference: run the long request ALONE
    _, eng_solo = _engine(slots=2, max_len=64)
    eng_solo.submit(Request(uid=0, prompt=prompt, max_new_tokens=10))
    solo = eng_solo.run()[0].out_tokens
    # mixed: same long request + a short one admitted mid-flight
    eng_mixed.submit(Request(uid=0, prompt=prompt, max_new_tokens=10))
    eng_mixed.submit(Request(uid=1, prompt=prompt[:4], max_new_tokens=2))
    # force staggered admission: only one free slot at t=0
    eng_mixed.live[1] = Request(uid=99, prompt=prompt[:2], max_new_tokens=3)
    eng_mixed.pos[1] = 2
    out = {r.uid: r.out_tokens for r in eng_mixed.run()}
    assert out[0] == solo, "live sequence corrupted by later admission"


def test_decomposed_kv_serving():
    """Engine on the low-rank KV cache completes requests + compacts tail."""
    from repro.configs import all_archs
    import jax
    from repro.models import model_fns
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=2, max_len=64,
                 decompose_kv_rank=8, dkv_tail=4)
    rng = np.random.RandomState(0)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, 12,
                                                     dtype=np.int32),
                           max_new_tokens=10))   # > tail => compaction runs
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.out_tokens) >= 10 for r in done)
    # frozen_len is PER SLOT now; both slots folded their tail at least once
    assert (eng.frozen_len > 12).all()
    assert eng.stats.tail_folds >= 2


def test_bucket_never_rounds_past_max_len():
    """A prompt that fits in max_len must get its full decode budget even
    when its scheduler bucket would round past the cache length."""
    cfg, eng = _engine(slots=2, max_len=60)   # not a bucket multiple
    assert eng.sched.bucket_of(50) > eng.max_len - 1
    eng.submit(Request(uid=0, prompt=np.arange(50, dtype=np.int32) % cfg.vocab,
                       max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) >= 4


def test_oversized_prompt_rejected_at_submit():
    cfg, eng = _engine(slots=1, max_len=32)
    import pytest
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(32, np.int32)))
