"""Serving engine: continuous batching, slot reuse, stats."""
import jax
import numpy as np

from repro.configs import all_archs
from repro.models import model_fns
from repro.serving import Engine, Request


def _engine(slots=2, max_len=48):
    cfg = all_archs()["llama2-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    return cfg, Engine(cfg, params, slots=slots, max_len=max_len)


def test_completes_all_requests():
    cfg, eng = _engine()
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab, 8,
                                              dtype=np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) >= 4 for r in done)
    assert all(0 <= t < cfg.padded_vocab for r in done for t in r.out_tokens)


def test_continuous_batching_reuses_slots():
    cfg, eng = _engine(slots=2)
    rng = np.random.RandomState(1)
    for i in range(6):
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, 4,
                                                     dtype=np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 6
    assert eng.stats.prefills == 6      # counted PER REQUEST, not per gang
    assert eng.stats.prefill_batches >= 3   # 6 requests / 2 slots
    assert eng.stats.tokens_out > 0
    assert len(eng.stats.ttft_s) == 6   # one first-token latency each
    assert eng.stats.mean_ttft_s > 0.0


def test_deterministic_outputs():
    cfg, e1 = _engine()
    _, e2 = _engine()
    prompt = np.arange(8, dtype=np.int32)
    for e in (e1, e2):
        e.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    o1 = e1.run()[0].out_tokens
    o2 = e2.run()[0].out_tokens
    assert o1 == o2


def test_admission_preserves_live_sequences():
    """Admitting new requests must not corrupt in-flight KV (splice path)."""
    cfg, eng_mixed = _engine(slots=2, max_len=64)
    prompt = np.arange(8, dtype=np.int32)
    # reference: run the long request ALONE
    _, eng_solo = _engine(slots=2, max_len=64)
    eng_solo.submit(Request(uid=0, prompt=prompt, max_new_tokens=10))
    solo = eng_solo.run()[0].out_tokens
    # mixed: same long request + a short one admitted mid-flight
    eng_mixed.submit(Request(uid=0, prompt=prompt, max_new_tokens=10))
    eng_mixed.submit(Request(uid=1, prompt=prompt[:4], max_new_tokens=2))
    # force staggered admission: only one free slot at t=0
    eng_mixed.live[1] = Request(uid=99, prompt=prompt[:2], max_new_tokens=3)
    eng_mixed.pos[1] = 2
    out = {r.uid: r.out_tokens for r in eng_mixed.run()}
    assert out[0] == solo, "live sequence corrupted by later admission"


def test_decomposed_kv_serving():
    """Engine on the low-rank KV cache completes requests + compacts tail."""
    import jax

    from repro.configs import all_archs
    from repro.models import model_fns
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=2, max_len=64,
                 decompose_kv_rank=8, dkv_tail=4)
    rng = np.random.RandomState(0)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, 12,
                                                     dtype=np.int32),
                           max_new_tokens=10))   # > tail => compaction runs
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.out_tokens) >= 10 for r in done)
    # frozen_len is PER SLOT now; both slots folded their tail at least once
    assert (eng.frozen_len > 12).all()
    assert eng.stats.tail_folds >= 2


def test_bucket_never_rounds_past_max_len():
    """A prompt that fits in max_len must get its full decode budget even
    when its scheduler bucket would round past the cache length."""
    cfg, eng = _engine(slots=2, max_len=60)   # not a bucket multiple
    assert eng.sched.bucket_of(50) > eng.max_len - 1
    eng.submit(Request(uid=0, prompt=np.arange(50, dtype=np.int32) % cfg.vocab,
                       max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) >= 4


def test_oversized_prompt_rejected_at_submit():
    cfg, eng = _engine(slots=1, max_len=32)
    import pytest
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(32, np.int32)))


# ---------------------------------------------------------------------------
# Decode-loop correctness fixes (PR 5)
# ---------------------------------------------------------------------------

def _const_sampler(tok):
    import jax.numpy as jnp
    return lambda lg, k: jnp.full((lg.shape[0],), tok, jnp.int32)


def test_eos_stops_request_and_frees_slot():
    """A request finishes the moment it emits eos_id — not after burning
    its whole max_new_tokens budget — and its slot frees immediately."""
    cfg, eng = _engine(slots=2)
    eng.sampler = _const_sampler(7)
    eng.eos_id = 7
    eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=50))
    done = eng.run()
    assert len(done) == 1 and done[0].done
    assert done[0].out_tokens == [7]     # stopped at the very first token
    assert eng.live == [None] * 2        # slot freed at once
    assert eng.stats.stopped_eos == 1
    assert eng.stats.stopped_budget == 0


def test_per_request_stop_tokens_and_budget_counters():
    """Request-level eos/stop_tokens override the engine default; finishes
    are attributed to stopped_eos vs stopped_budget correctly."""
    cfg, eng = _engine(slots=2)
    eng.sampler = _const_sampler(9)
    eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=40, stop_tokens=(9,)))
    eng.submit(Request(uid=1, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=3))        # no stop: runs its budget
    done = {r.uid: r for r in eng.run()}
    assert done[0].out_tokens == [9]
    assert len(done[1].out_tokens) == 3
    assert eng.stats.stopped_eos == 1
    assert eng.stats.stopped_budget == 1


def test_wall_s_accrues_per_step():
    """step()-driven callers (benchmarks, the serve CLI) must see real
    wall time — the old accounting lived only inside run() and reported
    tok/s = inf everywhere else."""
    cfg, eng = _engine(slots=2)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=4))
    done = []
    while len(done) < 1:
        done.extend(eng.step())
    assert eng.stats.wall_s > 0.0
    assert eng.stats.tokens_out / eng.stats.wall_s < float("inf")


def test_multi_bucket_admission_fills_free_slots():
    """A mixed-length queue no longer idles free slots behind the head
    request's bucket: one admission drains further buckets (one prefill
    launch per bucket)."""
    cfg = all_archs()["llama2-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=4, max_len=96)
    rng = np.random.RandomState(0)
    for i, n in enumerate((4, 4, 20, 20)):       # two plen buckets
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, n,
                                                     dtype=np.int32),
                           max_new_tokens=3))
    assert eng.sched.bucket_of(4) != eng.sched.bucket_of(20)
    eng.step()
    assert sum(r is not None for r in eng.live) == 4, \
        "free slots idled while another bucket waited"
    assert eng.stats.prefill_batches == 2        # one launch per bucket
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]


def test_fold_retruncates_back_to_configured_kv_rank():
    """Regression for the rank ratchet: after a wider-rank splice (e.g. a
    migrated cache or a config change), the next fold retruncates every
    folding slot back to the configured kv_rank and the engine slices the
    rank axis down once no live slot needs the extra width."""
    from repro.models import decomposed_kv as DK
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=2, max_len=64,
                 decompose_kv_rank=8, dkv_tail=4)
    rng = np.random.RandomState(0)
    eng.submit(Request(uid=0, prompt=rng.randint(0, cfg.vocab, 12,
                                                 dtype=np.int32),
                       max_new_tokens=12))
    eng.step()                                    # admit: rank-8 factors
    assert eng.cache["k_u"].shape[-1] == 8
    # heterogeneous splice: widen slot 1's factors to rank 12 directly
    import jax.numpy as jnp
    t = eng.cache["k_u"].shape[2]
    wide = {
        "k_u": jnp.ones(eng.cache["k_u"].shape[:-1] + (12,)) * 0.01,
        "v_u": jnp.ones(eng.cache["v_u"].shape[:-1] + (12,)) * 0.01,
        "k_vt": jnp.ones(eng.cache["k_vt"].shape[:-2] + (12,) +
                         eng.cache["k_vt"].shape[-1:]) * 0.01,
        "v_vt": jnp.ones(eng.cache["v_vt"].shape[:-2] + (12,) +
                         eng.cache["v_vt"].shape[-1:]) * 0.01,
        "tail": {k: jnp.zeros_like(v) for k, v in eng.cache["tail"].items()},
    }
    eng.cache = DK.splice_dkv(eng.cache, wide, np.array([1]), np.array([1]))
    assert eng.cache["k_u"].shape[-1] == 12       # splice padded both sides
    eng.rank_eff[1] = 12
    eng.live[1] = Request(uid=99, prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=2)
    eng.pos[1] = t
    eng.frozen_len[1] = t
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 99]
    assert eng.stats.tail_folds > 0
    # the wide occupant drained and folds retruncated: width is back to
    # the configured kv_rank (the old max(r_in, r_fold) kept 12 forever)
    assert eng.cache["k_u"].shape[-1] == 8
    assert eng.cache["k_vt"].shape[-2] == 8


def test_compress_tail_uniform_retruncates_to_rank():
    """Unit twin of the ratchet regression: uniform-mode compress_tail on
    factors wider than the configured rank comes back at exactly rank."""
    from repro.models import decomposed_kv as DK
    cfg = all_archs()["deepseek-7b"].reduced()
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    nl, b, t, tl, r_in, rank = cfg.num_layers, 2, 12, 4, 12, 8
    rng = np.random.RandomState(1)
    cache = {
        "k_u": rng.randn(nl, b, t, r_in).astype(np.float32),
        "k_vt": rng.randn(nl, b, r_in, kvw).astype(np.float32),
        "v_u": rng.randn(nl, b, t, r_in).astype(np.float32),
        "v_vt": rng.randn(nl, b, r_in, kvw).astype(np.float32),
        "tail": {"k": rng.randn(nl, b, tl, cfg.num_kv_heads,
                                cfg.resolved_head_dim).astype(np.float32),
                 "v": rng.randn(nl, b, tl, cfg.num_kv_heads,
                                cfg.resolved_head_dim).astype(np.float32)},
    }
    out = DK.compress_tail(cache, cfg, rank)
    assert out["k_u"].shape[-1] == rank           # was max(r_in, r_fold)=12
    assert out["k_vt"].shape[-2] == rank
    assert out["k_u"].shape[2] == t + tl


def test_scheduler_buckets_on_cost_hook():
    """The scheduler buckets on the injected COST function, not raw
    prompt length: a +10 modality constant (deliberately not a bucket
    multiple) moves requests across bucket boundaries and regroups the
    admission batches."""
    from repro.serving import Scheduler
    lens = (4, 8, 20, 24)
    reqs = [Request(uid=i, prompt=np.zeros(n, np.int32))
            for i, n in enumerate(lens)]
    plain = Scheduler(bucket=16)
    cost = Scheduler(bucket=16, cost=lambda r: len(r.prompt) + 10)
    for s in (plain, cost):
        for r in reqs:
            s.submit(r)
    # length-based: {4, 8} share bucket 16, {20, 24} share bucket 32
    assert [r.uid for r in plain.next_batch(4)] == [0, 1]
    assert [r.uid for r in plain.next_batch(4)] == [2, 3]
    # cost-based: 14 → 16 | 18, 30 → 32 | 34 → 48
    assert [r.uid for r in cost.next_batch(4)] == [0]
    assert [r.uid for r in cost.next_batch(4)] == [1, 2]
    assert [r.uid for r in cost.next_batch(4)] == [3]
    assert not len(cost)


def test_engine_buckets_on_family_prefill_cost():
    """The engine's scheduler uses the FAMILY-reported prefill cost: a
    VLM prompt costs its token length plus the image-embed rows that
    join the prefill batch, so two prompts whose lengths share a bucket
    land in different buckets once the modality constant is added."""
    cfg = all_archs()["llama-3.2-vision-11b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=2, max_len=96)
    req = Request(uid=0, prompt=np.zeros(7, np.int32))
    assert cfg.num_image_tokens == 16
    assert eng.family.prefill_cost(req) == 7 + 16
    assert eng.sched.cost(req) == eng.family.prefill_cost(req)
    # 7 and 15 share bucket 16 by length, but 23 vs 31: with the image
    # rows both still bucket 32 — push one across: 7+16=23→32, 20+16=36→48
    b = eng.sched.bucket_of
    assert b(eng.sched.cost(Request(uid=1, prompt=np.zeros(7, np.int32)))) \
        != b(eng.sched.cost(Request(uid=2, prompt=np.zeros(20, np.int32))))
