"""Baseline SVD algorithms (paper Fig. 2 comparison set)."""
import jax

from repro.core.svd_alt import (oracle_svd, qr_iteration_svd, randomized_svd,
                                reconstruction_error)


def _mat(s=96, h=64, r=8):
    return jax.random.normal(jax.random.PRNGKey(0), (s, r)) @ \
        jax.random.normal(jax.random.PRNGKey(1), (r, h)) + \
        0.01 * jax.random.normal(jax.random.PRNGKey(2), (s, h))


def test_all_algorithms_reach_oracle_error():
    a = _mat()
    eo = float(reconstruction_error(a, *oracle_svd(a, 8)))
    for fn in (lambda: qr_iteration_svd(a, 8, iters=12),
               lambda: randomized_svd(a, 8)):
        e = float(reconstruction_error(a, *fn()))
        assert e < eo + 0.02


def test_lanczos_fastest_at_small_rank_flopwise():
    """The paper's Fig. 2 argument as FLOP arithmetic: per-iteration Lanczos
    cost (2 matvecs + reorth) << per-iteration subspace cost (2 block
    matmuls) at equal rank."""
    s, h, r = 4096, 4096, 10
    lanczos_iter = 2 * (2 * s * h) + 2 * 2 * (s + h) * r * 2
    qr_iter = 2 * (2 * s * h * r)
    assert lanczos_iter * 1.5 < qr_iter
