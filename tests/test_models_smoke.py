"""Per-arch reduced-config smoke: one train grad step + prefill + decode on
CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.models import make_fake_batch, model_fns
from repro.runtime import steps as steps_mod
from repro.optim import make_optimizer

ARCHS = sorted(all_archs().keys())
SMOKE = ShapeSpec("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = all_archs()[arch].reduced()
    opt = make_optimizer(cfg)
    train_step = steps_mod.make_train_step(cfg, opt)
    params, opt_state = steps_mod.init_train_state(cfg,
                                                   jax.random.PRNGKey(0), opt)
    batch = make_fake_batch(cfg, SMOKE)
    params2, opt_state2, metrics = train_step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                               params, params2)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = all_archs()[arch].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    batch = make_fake_batch(cfg, SMOKE)
    if cfg.family == "vlm":
        logits, cache = fns.prefill(params, cfg, batch["tokens"],
                                    batch["image_embeds"], 64)
    elif cfg.family == "audio":
        logits, cache = fns.prefill(params, cfg, batch["frames"],
                                    batch["tokens"], 64)
    else:
        logits, cache = fns.prefill(params, cfg, batch["tokens"], 64)
    assert logits.shape == (2, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 32, jnp.int32)
    for _ in range(3):
        logits, cache = fns.decode_step(params, cfg, tok, cache, pos)
        assert logits.shape == (2, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


def test_decode_matches_forward_dense():
    """Teacher-forced decode == full forward, position by position."""
    cfg = all_archs()["deepseek-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    full = fns.forward(params, cfg, toks)            # [B, S, V]
    logits, cache = fns.prefill(params, cfg, toks[:, :4], 16)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, 3], np.float32),
                               rtol=5e-2, atol=5e-1)
    # continue decoding with teacher forcing
    for t in range(4, 8):
        logits, cache = fns.decode_step(params, cfg, toks[:, t],
                                        cache, jnp.full((2,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=5e-2, atol=5e-1)


def test_mamba_decode_matches_forward():
    """SSM state decode == chunked SSD forward (the SSD duality)."""
    cfg = all_archs()["mamba2-780m"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    full = fns.forward(params, cfg, toks)
    logits, state = fns.prefill(params, cfg, toks[:, :4])
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, 3], np.float32),
                               rtol=5e-2, atol=5e-1)
    for t in range(4, 8):
        logits, state = fns.decode_step(params, cfg, toks[:, t], state,
                                        jnp.full((2,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=5e-2, atol=5e-1)
