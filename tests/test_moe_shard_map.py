"""Explicit-EP (shard_map) MoE vs the GSPMD formulation (subprocess: needs
8 placeholder devices).  Equivalence holds modulo capacity-drop semantics
(per-data-shard vs global capacity), so the check runs drop-free."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import all_archs
    from repro.models import moe as M

    for sharding in ("1d", "2d"):
        cfg = all_archs()["olmoe-1b-7b"].reduced().replace(
            capacity_factor=16.0, expert_sharding=sharding)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = M.moe_ffn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(cfg.jax_dtype)
        y_ref, _ = M.moe_ffn(p, x, cfg)
        M.SHARD_MAP_MESH = mesh
        y_sm, _ = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg))(p, x)
        M.SHARD_MAP_MESH = None
        d = np.abs(np.asarray(y_sm, np.float32) - np.asarray(y_ref,
                                                             np.float32))
        scale = np.abs(np.asarray(y_ref, np.float32)).max()
        assert d.max() < 0.02 * scale + 1e-3, (sharding, d.max(), scale)
    print("MOE_SM_OK")
""")


def test_shard_map_matches_gspmd():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env)
    assert "MOE_SM_OK" in out.stdout, out.stderr[-2000:]
