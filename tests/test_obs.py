"""Units for ``repro.obs``: registry semantics, streaming-histogram
quantile accuracy, trace export/validation, Prometheus exposition
round-trip, and the uniform snapshot schema."""
import json

import numpy as np
import pytest

from repro.obs import (BUCKETS_PER_DECADE, NULL_SPAN, LatencySeries,
                       MetricsRegistry, Observability, Tracer, bucket_label,
                       parse_prometheus, stats_snapshot, to_prometheus,
                       validate_trace, write_json_snapshot,
                       write_prometheus)
from repro.obs.registry import RESERVOIR_CAP, Histogram

#: half-bucket relative error bound of the log-bucketed quantiles
QERR = 10.0 ** (0.5 / BUCKETS_PER_DECADE) - 1.0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", mode="a")
    b = reg.counter("x_total", mode="a")
    c = reg.counter("x_total", mode="b")
    assert a is b and a is not c
    a.inc()
    a.add(2)
    assert b.value == 3 and c.value == 0
    # same name, different kind → loud error, not silent shadowing
    with pytest.raises(TypeError):
        reg.gauge("x_total", mode="a")


def test_counter_negative_delta_and_gauge_ratchet():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.add(5)
    c.add(-2)                            # the serving cancel path unwinds
    assert c.value == 3
    g = reg.gauge("peak")
    g.max(4)
    g.max(2)
    assert g.value == 4
    g.set(1)
    g.inc()
    assert g.value == 2


def test_histogram_quantiles_within_bucket_error():
    h = Histogram("lat")
    rng = np.random.RandomState(0)
    xs = np.abs(rng.lognormal(mean=-3.0, sigma=1.5, size=5000))
    for v in xs:
        h.observe(float(v))
    assert h.count == len(xs)
    assert h.sum == pytest.approx(float(xs.sum()))
    assert h.min == pytest.approx(float(xs.min()))
    assert h.max == pytest.approx(float(xs.max()))
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(np.percentile(xs, 100 * q,
                                    method="inverted_cdf"))
        got = h.quantile(q)
        assert abs(got - exact) <= (QERR + 1e-9) * exact + 1e-12, \
            f"q={q}: {got} vs exact {exact}"


def test_histogram_zero_and_negative_samples():
    h = Histogram("lat")
    for v in (0.0, -1.0, 0.5, 2.0):
        h.observe(v)
    assert h.quantile(0.25) <= 0.0       # zero bucket sorts below positives
    assert h.quantile(1.0) == pytest.approx(2.0)
    assert h.count == 4


def test_histogram_memory_is_bounded():
    h = Histogram("lat")
    for i in range(50_000):
        h.observe(1e-6 * (1 + (i % 1000)))
    # samples span 4 decades max → bucket dict stays tiny; reservoir capped
    assert len(h._buckets) <= 4 * BUCKETS_PER_DECADE
    assert len(h.recent) == RESERVOIR_CAP
    assert h.count == 50_000


def test_latency_series_list_compat():
    s = LatencySeries(Histogram("lat"))
    assert not s                         # falsy when empty (like a list)
    s.append(0.5)
    s.extend([1.0, 2.0])
    assert len(s) == 3 and bool(s)
    assert list(s) == [0.5, 1.0, 2.0]
    assert s[0] == 0.5 and s[-1] == 2.0
    assert np.asarray(s).tolist() == [0.5, 1.0, 2.0]
    assert float(np.percentile(np.asarray(s), 99)) > 0
    assert s.mean == pytest.approx(3.5 / 3)
    assert max(s) == 2.0
    assert all(v > 0 for v in s)


def test_bucket_label_pow2():
    assert bucket_label(3, 24, 96) == "4x32x128"
    assert bucket_label(1) == "1"


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_tracer_disabled_is_noop():
    t = Tracer(enabled=False)
    sp = t.begin("x", "engine")
    assert sp is NULL_SPAN
    sp.annotate(a=1)
    sp.end()
    t.instant("i")
    assert t.events == []


def test_tracer_spans_nest_and_validate(tmp_path):
    t = Tracer(enabled=True)
    with t.span("outer", "engine", {"k": 1}):
        with t.span("inner", "engine"):
            pass
        t.instant("mark", "engine")
    sp = t.begin("req", "req/0")
    sp.end(tokens=3)
    sp.end(tokens=9)                     # idempotent: second end ignored
    obj = t.to_json()
    assert validate_trace(obj) == 3      # outer, inner, req ("i" not counted)
    names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names
    # same track → same tid; different track → different tid
    by_name = {e["name"]: e for e in t.events}
    assert by_name["outer"]["tid"] == by_name["inner"]["tid"]
    assert by_name["req"]["tid"] != by_name["outer"]["tid"]
    assert by_name["req"]["args"] == {"tokens": 3}
    # inner is contained within outer (how Perfetto renders nesting)
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
    p = tmp_path / "trace.json"
    t.export(str(p))
    assert validate_trace(str(p)) == 3
    assert validate_trace(p.read_text()) == 3


def test_tracer_event_cap():
    t = Tracer(enabled=True, max_events=3)
    for k in range(10):
        t.begin(f"s{k}").end()
    assert len(t.events) == 3 and t.dropped == 7


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"notTraceEvents": []})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "pid": 0, "tid": 1,
                                         "ts": 0.0}]})   # X without dur
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "i", "pid": 0}]})


def test_phase_stack():
    from repro.obs import current_phase, phase_scope
    assert current_phase() == "other"
    with phase_scope("prefill"):
        assert current_phase() == "prefill"
        with phase_scope("decode"):
            assert current_phase() == "decode"
        assert current_phase() == "prefill"
    assert current_phase() == "other"


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------


def _toy_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("reqs_total", "requests", mode="sync").add(7)
    reg.counter("reqs_total", mode="async").add(2)
    reg.gauge("inflight", "in flight").set(3)
    h = reg.histogram("lat_seconds", "latency")
    for v in (0.001, 0.01, 0.1, 1.0):
        h.observe(v)
    # label values that need escaping must round-trip
    reg.counter("odd_total", label='a"b\\c').inc()
    return reg


def test_prometheus_roundtrip():
    reg = _toy_registry()
    text = to_prometheus(reg)
    got = parse_prometheus(text)
    assert got["repro_reqs_total"] == [({"mode": "sync"}, 7.0),
                                       ({"mode": "async"}, 2.0)]
    assert got["repro_inflight"] == [({}, 3.0)]
    # histogram → summary: quantile series + _sum/_count
    qs = {r[0]["quantile"] for r in got["repro_lat_seconds"]}
    assert qs == {"0.5", "0.95", "0.99"}
    assert got["repro_lat_seconds_count"] == [({}, 4.0)]
    assert got["repro_lat_seconds_sum"][0][1] == pytest.approx(1.111)
    assert got["repro_odd_total"][0][0]["label"] == 'a\\"b\\\\c'
    # HELP/TYPE lines present and the format self-describes as summary
    assert "# TYPE repro_lat_seconds summary" in text
    assert "# HELP repro_reqs_total requests" in text


def test_prometheus_parser_is_strict():
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line\n")
    with pytest.raises(ValueError):
        parse_prometheus('x{bad-label="1"} 2\n')
    with pytest.raises(ValueError):
        parse_prometheus('x{a="unterminated} 2\n')


def test_write_prometheus_and_json(tmp_path):
    reg = _toy_registry()
    p = tmp_path / "m.prom"
    text = write_prometheus(str(p), reg)
    assert p.read_text() == text
    parse_prometheus(p.read_text())
    j = tmp_path / "m.json"
    snap = write_json_snapshot(str(j), reg)
    loaded = json.loads(j.read_text())
    assert loaded == json.loads(json.dumps(snap))
    assert loaded["lat_seconds"][0]["count"] == 4
    assert "p99" in loaded["lat_seconds"][0]


# ---------------------------------------------------------------------------
# snapshot schema + EngineStats back-compat (no engine needed)
# ---------------------------------------------------------------------------


def test_stats_snapshot_schema():
    from repro.serving import EngineStats
    s = EngineStats()
    s.prefills += 3
    s.tokens_out += 30
    s.wall_s += 2.0
    s.ttft_s.extend([0.1, 0.2, 0.4])
    s.itl_s.extend([0.01] * 30)
    snap = stats_snapshot(s)
    assert snap["schema"] == "repro.obs/v1"
    assert snap["prefills"] == 3 and snap["tokens_out"] == 30
    assert snap["tokens_per_s"] == pytest.approx(15.0)
    for blk in ("ttft", "ttft_queue", "ttft_compute", "itl"):
        assert set(snap[blk]) == {"mean_s", "p50_s", "p95_s", "p99_s",
                                  "count"}
    assert snap["ttft"]["count"] == 3
    assert snap["ttft"]["mean_s"] == pytest.approx(0.7 / 3)
    assert snap["itl"]["p50_s"] == pytest.approx(0.01, rel=2 * QERR)
    assert stats_snapshot(s, wall_s=1.0)["tokens_per_s"] == \
        pytest.approx(30.0)
    assert json.loads(json.dumps(snap)) == snap      # JSON-able
    # s.snapshot() is the method spelling of the same thing
    assert s.snapshot() == snap


def test_engine_stats_mutation_compat():
    """Every mutation idiom the serving engine uses must keep working on
    the registry-backed EngineStats."""
    from repro.serving import EngineStats
    s = EngineStats()
    s.prefills += 2
    s.prefills -= 1                      # cancel_pending unwinds
    s.prefill_inflight_peak = max(s.prefill_inflight_peak, 5)
    s.wall_s += 0.25
    s.ttft_s.append(0.1)
    s.itl_s.extend([0.02, 0.03])
    assert s.prefills == 1
    assert s.prefill_inflight_peak == 5
    assert s.wall_s == pytest.approx(0.25)
    assert s.mean_ttft_s == pytest.approx(0.1)
    assert s.mean_itl_s == pytest.approx(0.025)
    assert len(s.itl_s) == 2
    # two engines' stats are isolated (per-engine registries)
    s2 = EngineStats()
    assert s2.prefills == 0
    # metrics visible via the registry under serving_* names
    names = {m.name for m in s.registry.metrics()}
    assert {"serving_prefills", "serving_ttft_seconds",
            "serving_wall_seconds"} <= names


def test_observability_bundle():
    obs = Observability()
    assert not obs.trace_enabled
    assert obs.tracer.begin("x") is NULL_SPAN
    obs2 = Observability(trace=True)
    assert obs2.trace_enabled
    assert obs.registry is not obs2.registry


def test_compile_watch_counts_real_compiles():
    """The jax.monitoring listener sees one backend-compile event per real
    XLA compile, attributed to the active phase; jit-cache hits add none."""
    import jax
    import jax.numpy as jnp
    from repro.obs import GLOBAL, install_compile_watch, phase_scope
    install_compile_watch()

    def get():
        for m in GLOBAL.metrics():
            if m.name == "jit_compiles_total" \
                    and m.labels.get("phase") == "obs-test":
                return m.value
        return 0

    f = jax.jit(lambda x: x * 3 + 1)
    x = jnp.arange(7, dtype=jnp.float32)
    before = get()
    with phase_scope("obs-test"):
        f(x).block_until_ready()
    after_compile = get()
    with phase_scope("obs-test"):
        f(x).block_until_ready()         # cache hit: no new compile
    assert after_compile == before + 1
    assert get() == after_compile
