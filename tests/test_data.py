"""Data pipeline: determinism, resume, host sharding, prefetch."""
import numpy as np

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, MemmapShards, Prefetcher, SyntheticLM


CFG = all_archs()["llama2-7b"].reduced()
SHAPE = ShapeSpec("t", 16, 4, "train")


def test_batch_pure_function_of_step():
    src = SyntheticLM(CFG, SHAPE, DataConfig(seed=3))
    b1 = src.batch_at(7)
    b2 = src.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    src = SyntheticLM(CFG, SHAPE, DataConfig())
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_host_sharding_disjoint_seeds():
    a = SyntheticLM(CFG, SHAPE, DataConfig(num_hosts=2, host_id=0))
    b = SyntheticLM(CFG, SHAPE, DataConfig(num_hosts=2, host_id=1))
    assert a.host_batch == 2
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_prefetcher_resume():
    src = SyntheticLM(CFG, SHAPE, DataConfig())
    pf = Prefetcher(src, start_step=5)
    step, batch = next(pf)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"],
                                  src.batch_at(5)["tokens"])
    step, _ = next(pf)
    assert step == 6
    pf.stop()


def test_memmap_shards(tmp_path):
    rng = np.random.RandomState(0)
    p1, p2 = str(tmp_path / "a.npy"), str(tmp_path / "b.npy")
    np.save(p1, rng.randint(0, 100, (10, 32), dtype=np.int32))
    np.save(p2, rng.randint(0, 100, (6, 32), dtype=np.int32))
    src = MemmapShards([p1, p2], CFG, ShapeSpec("t", 16, 4, "train"),
                       DataConfig())
    b = src.batch_at(3)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"],
                                  src.batch_at(3)["tokens"])
