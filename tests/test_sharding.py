"""Sharding rule tables (pure: evaluated against an AbstractMesh)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import all_archs
from repro.distributed import sharding as sh

# AbstractMesh takes ((name, size), ...) pairs since jax 0.4.35
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_POD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_dp_axes():
    assert sh.dp_axes(MESH) == ("data",)
    assert sh.dp_axes(MESH_POD) == ("pod", "data")


def test_col_row_parallel_rules():
    cfg = all_archs()["deepseek-7b"]
    assert sh.param_spec("layers/attn/wq/w", (30, 4096, 4096), MESH, cfg) \
        == P(None, None, "model")
    assert sh.param_spec("layers/attn/wo/w", (30, 4096, 4096), MESH, cfg) \
        == P(None, "model", None)
    assert sh.param_spec("layers/mlp/down/w", (30, 11008, 4096), MESH, cfg) \
        == P(None, "model", None)
    assert sh.param_spec("embed/w", (102400, 4096), MESH, cfg) \
        == P("model", None)


def test_indivisible_dims_fall_back_to_replication():
    cfg = all_archs()["granite-3-2b"]
    # granite vocab 49155 is not 16-divisible *unpadded*; rule must not shard
    assert sh.param_spec("embed/w", (49155, 2048), MESH, cfg) == P(None, None)
    # but the PADDED table (49280) shards fine
    assert sh.param_spec("embed/w", (49280, 2048), MESH, cfg) \
        == P("model", None)


def test_moe_expert_sharding():
    olmoe = all_archs()["olmoe-1b-7b"]
    kimi = all_archs()["kimi-k2-1t-a32b"]
    assert sh.param_spec("layers/moe/w_gate", (16, 64, 2048, 1024), MESH,
                         olmoe) == P(None, "model", None, None)
    assert sh.param_spec("layers/moe/w_gate", (60, 384, 7168, 2048), MESH,
                         kimi) == P(None, "model", None, "data")
    assert sh.param_spec("layers/moe/w_down", (60, 384, 2048, 7168), MESH,
                         kimi) == P(None, "model", "data", None)


def test_zero1_adds_dp_axis():
    cfg = all_archs()["deepseek-7b"]
    spec = sh._zero1(P(None, None, "model"), (30, 4096, 4096), MESH)
    assert spec == P(None, "data", "model")


def test_cache_rules_batch_vs_sequence():
    cfg = all_archs()["deepseek-7b"]
    # decode_32k-style cache: batch 128 → DP on batch, kvh on model
    cache = {"k": jax.ShapeDtypeStruct((30, 128, 32768, 32, 128),
                                       jnp.bfloat16)}
    shd = sh.cache_sharding(cache, MESH, cfg)
    assert shd["k"].spec == P(None, "data", None, "model", None)
    # long_500k-style (batch 1) → sequence-sharded KV
    cache1 = {"k": jax.ShapeDtypeStruct((30, 1, 524288, 32, 128),
                                        jnp.bfloat16)}
    shd1 = sh.cache_sharding(cache1, MESH, cfg)
    assert shd1["k"].spec == P(None, None, "data", "model", None)


def test_mqa_head_dim_fallback():
    cfg = all_archs()["gemma-2b"]
    # kv heads == 1 -> shard head_dim (256) instead
    cache = {"k": jax.ShapeDtypeStruct((18, 128, 32768, 1, 256),
                                       jnp.bfloat16)}
    shd = sh.cache_sharding(cache, MESH, cfg)
    assert shd["k"].spec == P(None, "data", None, None, "model")


def test_ssm_cache_rules():
    cfg = all_archs()["mamba2-780m"]
    st = {"ssm": jax.ShapeDtypeStruct((48, 128, 48, 64, 128), jnp.float32),
          "conv": jax.ShapeDtypeStruct((48, 128, 3, 3328), jnp.bfloat16)}
    shd = sh.cache_sharding(st, MESH, cfg)
    assert shd["ssm"].spec == P(None, "data", "model", None, None)
    assert shd["conv"].spec == P(None, "data", None, "model")


def test_params_sharding_full_tree():
    """Every leaf of every arch gets a spec whose sharded dims divide."""
    for name, cfg in all_archs().items():
        shapes = jax.eval_shape(
            lambda: __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg))
        tree = sh.params_sharding(
            __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg), MESH, cfg)
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        shapes_flat, _ = jax.tree_util.tree_flatten_with_path(
            __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg))
        for (pth, shd), (_, leaf) in zip(flat, shapes_flat):
            for dim, axis in zip(leaf.shape, shd.spec + (None,) * 8):
                if axis is not None:
                    sz = MESH.shape[axis] if isinstance(axis, str) else \
                        int(jnp.prod(jnp.asarray([MESH.shape[a]
                                                  for a in axis])))
                    assert dim % sz == 0, (name, pth, leaf.shape, shd.spec)
