"""Sharding rule tables (pure: evaluated against an AbstractMesh).

The 8-device meshes below mirror the forced-host-platform serving mesh the
CI distributed job runs (``XLA_FLAGS=--xla_force_host_platform_device_count
=8``, ``launch.mesh.make_host_mesh(8, 1)`` / ``(2, 4)``); the rules are
shape-only so AbstractMesh evaluates them without devices.  Hypothesis
properties for the same rules live in tests/test_properties.py.
"""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import all_archs
from repro.distributed import sharding as sh

# AbstractMesh takes ((name, size), ...) pairs since jax 0.4.35
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_POD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
# host-platform serving meshes (8 forced devices)
MESH8 = AbstractMesh((("data", 8), ("model", 1)))
MESH8_2D = AbstractMesh((("data", 2), ("model", 4)))


def test_dp_axes():
    assert sh.dp_axes(MESH) == ("data",)
    assert sh.dp_axes(MESH_POD) == ("pod", "data")


def test_col_row_parallel_rules():
    cfg = all_archs()["deepseek-7b"]
    assert sh.param_spec("layers/attn/wq/w", (30, 4096, 4096), MESH, cfg) \
        == P(None, None, "model")
    assert sh.param_spec("layers/attn/wo/w", (30, 4096, 4096), MESH, cfg) \
        == P(None, "model", None)
    assert sh.param_spec("layers/mlp/down/w", (30, 11008, 4096), MESH, cfg) \
        == P(None, "model", None)
    assert sh.param_spec("embed/w", (102400, 4096), MESH, cfg) \
        == P("model", None)


def test_indivisible_dims_fall_back_to_replication():
    cfg = all_archs()["granite-3-2b"]
    # granite vocab 49155 is not 16-divisible *unpadded*; rule must not shard
    assert sh.param_spec("embed/w", (49155, 2048), MESH, cfg) == P(None, None)
    # but the PADDED table (49280) shards fine
    assert sh.param_spec("embed/w", (49280, 2048), MESH, cfg) \
        == P("model", None)


def test_moe_expert_sharding():
    olmoe = all_archs()["olmoe-1b-7b"]
    kimi = all_archs()["kimi-k2-1t-a32b"]
    assert sh.param_spec("layers/moe/w_gate", (16, 64, 2048, 1024), MESH,
                         olmoe) == P(None, "model", None, None)
    assert sh.param_spec("layers/moe/w_gate", (60, 384, 7168, 2048), MESH,
                         kimi) == P(None, "model", None, "data")
    assert sh.param_spec("layers/moe/w_down", (60, 384, 2048, 7168), MESH,
                         kimi) == P(None, "model", "data", None)


def test_zero1_adds_dp_axis():
    cfg = all_archs()["deepseek-7b"]
    spec = sh._zero1(P(None, None, "model"), (30, 4096, 4096), MESH)
    assert spec == P(None, "data", "model")


def test_cache_rules_batch_vs_sequence():
    cfg = all_archs()["deepseek-7b"]
    # decode_32k-style cache: batch 128 → DP on batch, kvh on model
    cache = {"k": jax.ShapeDtypeStruct((30, 128, 32768, 32, 128),
                                       jnp.bfloat16)}
    shd = sh.cache_sharding(cache, MESH, cfg)
    assert shd["k"].spec == P(None, "data", None, "model", None)
    # long_500k-style (batch 1) → sequence-sharded KV
    cache1 = {"k": jax.ShapeDtypeStruct((30, 1, 524288, 32, 128),
                                        jnp.bfloat16)}
    shd1 = sh.cache_sharding(cache1, MESH, cfg)
    assert shd1["k"].spec == P(None, None, "data", "model", None)


def test_mqa_head_dim_fallback():
    cfg = all_archs()["gemma-2b"]
    # kv heads == 1 -> shard head_dim (256) instead
    cache = {"k": jax.ShapeDtypeStruct((18, 128, 32768, 1, 256),
                                       jnp.bfloat16)}
    shd = sh.cache_sharding(cache, MESH, cfg)
    assert shd["k"].spec == P(None, "data", None, None, "model")


def test_ssm_cache_rules():
    cfg = all_archs()["mamba2-780m"]
    st = {"ssm": jax.ShapeDtypeStruct((48, 128, 48, 64, 128), jnp.float32),
          "conv": jax.ShapeDtypeStruct((48, 128, 3, 3328), jnp.bfloat16)}
    shd = sh.cache_sharding(st, MESH, cfg)
    assert shd["ssm"].spec == P(None, "data", "model", None, None)
    assert shd["conv"].spec == P(None, "data", None, "model")


def test_dkv_cache_rules_host8():
    """Low-rank KV leaves on the 8-device serving mesh: k_u/v_u batch→DP
    with the time axis model-REPLICATED (refuted §Perf C3), k_vt/v_vt
    batch→DP + kvw→model when divisible."""
    cfg = all_archs()["deepseek-7b"].reduced()
    cache = {"k_u": jax.ShapeDtypeStruct((2, 8, 24, 8), jnp.float32),
             "v_u": jax.ShapeDtypeStruct((2, 8, 24, 8), jnp.float32),
             "k_vt": jax.ShapeDtypeStruct((2, 8, 8, 64), jnp.float32),
             "v_vt": jax.ShapeDtypeStruct((2, 8, 8, 64), jnp.float32),
             "tail": {"k": jax.ShapeDtypeStruct((2, 8, 4, 2, 32),
                                                jnp.float32)}}
    shd = sh.cache_sharding(cache, MESH8, cfg)
    assert shd["k_u"].spec == P(None, "data", None, None)
    assert shd["v_u"].spec == P(None, "data", None, None)
    assert shd["k_vt"].spec == P(None, "data", None, "model")
    assert shd["v_vt"].spec == P(None, "data", None, "model")
    # dense tail rides the k/v rule: batch→DP, kvh→model (2 heads on 4-way
    # model doesn't divide → head_dim fallback on the 2D mesh)
    assert shd["tail"]["k"].spec == P(None, "data", None, "model", None)
    shd2 = sh.cache_sharding(cache, MESH8_2D, cfg)
    assert shd2["k_vt"].spec == P(None, "data", None, "model")   # 64 % 4 == 0
    assert shd2["tail"]["k"].spec == P(None, "data", None, None, "model")


def test_dkv_batch1_time_axis_sharding():
    """global_batch == 1: k_u's TIME axis shards over "data" instead
    (flash-decoding style), and an indivisible time axis replicates."""
    cache = {"k_u": jax.ShapeDtypeStruct((4, 1, 64, 8), jnp.float32)}
    assert sh.cache_sharding(cache, MESH8, None)["k_u"].spec \
        == P(None, None, "data", None)
    odd = {"k_u": jax.ShapeDtypeStruct((4, 1, 63, 8), jnp.float32)}
    assert sh.cache_sharding(odd, MESH8, None)["k_u"].spec \
        == P(None, None, None, None)


def test_cache_indivisible_batch_replicates_host8():
    """slots that don't divide the 8-way DP axis fall back to replication
    (the guard every mesh-serving engine relies on for odd slot counts)."""
    for b in (3, 5, 6):
        cache = {"k_u": jax.ShapeDtypeStruct((2, b, 24, 8), jnp.float32),
                 "k": jax.ShapeDtypeStruct((2, b, 24, 2, 32), jnp.float32)}
        shd = sh.cache_sharding(cache, MESH8, None)
        assert shd["k_u"].spec[1] is None, b
        assert shd["k"].spec[1] is None, b


def test_zero1_picks_first_divisible_dim_host8():
    """_zero1 adds DP to the FIRST unsharded dim divisible by the DP size,
    skipping already-sharded dims and indivisible ones."""
    assert sh._zero1(P(), (8, 32), MESH8) == P("data", None)
    assert sh._zero1(P(), (3, 32), MESH8) == P(None, "data")     # skip 3
    assert sh._zero1(P("model"), (8, 32), MESH8_2D) == P("model", "data")
    assert sh._zero1(P(), (3, 5, 7), MESH8) == P(None, None, None)  # none fit
    # dim == 1 is never picked even though 1 % 8 != 0 guards it anyway
    assert sh._zero1(P(), (1, 16), MESH8) == P(None, "data")


def test_param_spec_divisibility_fallback_host8():
    cfg = all_archs()["deepseek-7b"]
    # 4096 divides both 1 and 4 model axes → column-parallel
    assert sh.param_spec("layers/attn/wq/w", (2, 4096, 4096), MESH8_2D, cfg) \
        == P(None, None, "model")
    # a 6-wide output dim doesn't divide model=4 → replicated
    assert sh.param_spec("layers/attn/wq/w", (2, 4096, 6), MESH8_2D, cfg) \
        == P(None, None, None)


def test_constrain_cache_noop_without_mesh():
    cache = {"k_u": jnp.zeros((2, 4, 8, 3))}
    assert sh.constrain_cache(cache, None) is cache


def test_seq_shard_gate_for_fresh_serving_caches():
    """seq_shard=False (the serving engine's setting) disables the batch-1
    time-axis rule: a freshly prefilled single-request cache stays
    replicated instead of bouncing through a sequence reshard per
    admission; batch>1 DP sharding is unaffected."""
    one = {"k_u": jax.ShapeDtypeStruct((2, 1, 16, 8), jnp.float32),
           "k": jax.ShapeDtypeStruct((2, 1, 16, 2, 32), jnp.float32)}
    on = sh.cache_sharding(one, MESH8, None)
    off = sh.cache_sharding(one, MESH8, None, seq_shard=False)
    assert on["k_u"].spec[2] == "data" and on["k"].spec[2] == "data"
    assert off["k_u"].spec[2] is None and off["k"].spec[2] is None
    many = {"k_u": jax.ShapeDtypeStruct((2, 8, 16, 8), jnp.float32)}
    assert sh.cache_sharding(many, MESH8, None, seq_shard=False)[
        "k_u"].spec[1] == "data"


def test_params_sharding_full_tree():
    """Every leaf of every arch gets a spec whose sharded dims divide."""
    for name, cfg in all_archs().items():
        shapes = jax.eval_shape(
            lambda: __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg))
        tree = sh.params_sharding(
            __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg), MESH, cfg)
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        shapes_flat, _ = jax.tree_util.tree_flatten_with_path(
            __import__("repro.models.api", fromlist=["api"])
            .abstract_params(cfg))
        for (pth, shd), (_, leaf) in zip(flat, shapes_flat):
            for dim, axis in zip(leaf.shape, shd.spec + (None,) * 8):
                if axis is not None:
                    sz = MESH.shape[axis] if isinstance(axis, str) else \
                        int(jnp.prod(jnp.asarray([MESH.shape[a]
                                                  for a in axis])))
                    assert dim % sz == 0, (name, pth, leaf.shape, shd.spec)
