"""Channel-wise outlier extraction (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ThresholdTable, calibrate_threshold, extract,
                        measured_extraction_frac, select_outlier_channels)
from repro.core.lowrank import gather_channels


def spiky_matrix(key, s=64, h=96, channels=(3, 40, 77), scale=25.0):
    a = jax.random.normal(key, (s, h))
    return a.at[:, list(channels)].mul(scale)


def test_selects_spiky_channels():
    a = spiky_matrix(jax.random.PRNGKey(0))
    idx = select_outlier_channels(a, jnp.asarray(5.0), 3)
    assert set(np.asarray(idx).tolist()) == {3, 40, 77}


def test_split_roundtrip():
    a = spiky_matrix(jax.random.PRNGKey(1))
    base, vals, idx = extract(a, jnp.asarray(5.0), 3)
    rebuilt = np.array(base)
    rebuilt[:, np.asarray(idx)] += np.asarray(vals)
    np.testing.assert_allclose(rebuilt, np.asarray(a), atol=1e-6)
    assert float(jnp.abs(gather_channels(base, idx)).max()) == 0.0


def test_outliers_help_lowrank_error():
    """Removing outlier channels must reduce truncation error (the paper's
    whole point)."""
    from repro.core import attach_dense_outliers, decompose, relative_error
    a = spiky_matrix(jax.random.PRNGKey(2), scale=50.0)
    plain = decompose(a, rank=4, iters=10)
    base, vals, idx = extract(a, jnp.asarray(5.0), 3)
    multi = attach_dense_outliers(decompose(base, rank=4, iters=10),
                                  vals, idx)
    assert float(relative_error(multi, a)) < float(relative_error(plain, a))


def test_calibrate_threshold_targets_fraction():
    rng = np.random.RandomState(0)
    samples = rng.randn(4, 128, 256).astype(np.float32)
    samples[:, :, :8] *= 20.0          # 8/256 ≈ 3.1% outlier channels
    t = calibrate_threshold(samples, target_channel_frac=8 / 256)
    per_tail = np.quantile(np.abs(samples).reshape(-1, 256), 0.999, axis=0)
    frac = (per_tail > t).mean()
    assert 0.02 <= frac <= 0.05


def test_threshold_table_roundtrip(tmp_path):
    tt = ThresholdTable()
    tt.set(3, 4.5)
    tt.set(10, 2.25)
    path = str(tmp_path / "t.json")
    tt.save(path)
    tt2 = ThresholdTable.load(path)
    assert tt2.get(3) == 4.5 and tt2.get(10) == 2.25
    assert tt2.get(99) == tt.default


def test_threshold_table_save_is_atomic(tmp_path):
    """Save goes through tmp + os.replace: after overwriting an existing
    table no temp droppings remain and the payload is the new table."""
    import os
    path = str(tmp_path / "t.json")
    tt = ThresholdTable()
    tt.set(0, 1.5)
    tt.save(path)
    tt.set(0, 7.5)
    tt.save(path)                      # overwrite in place
    assert ThresholdTable.load(path).get(0) == 7.5
    assert os.listdir(tmp_path) == ["t.json"]   # no .tmp litter


def test_threshold_table_load_tolerates_corruption(tmp_path):
    """A truncated/garbage table degrades to defaults with a warning — a
    serving run must not crash on a file a pre-atomic writer mangled."""
    import pytest
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write('{"default": 6.0, "thresho')      # crash mid-write
    with pytest.warns(RuntimeWarning, match="corrupt"):
        tt = ThresholdTable.load(path)
    assert tt.thresholds == {} and tt.default == 6.0
    with pytest.warns(RuntimeWarning):
        tt2 = ThresholdTable.load(str(tmp_path / "missing.json"))
    assert tt2.get(5) == tt2.default


def test_measured_extraction_energy():
    a = spiky_matrix(jax.random.PRNGKey(3), scale=50.0)
    frac = measured_extraction_frac(a, 5.0, 3)
    assert float(frac) > 0.9           # spiky channels carry the energy
