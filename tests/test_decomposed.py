"""Decomposed-execution integration (the paper's technique end to end)."""
import jax
import numpy as np
import pytest

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.core.policy import DecompositionPolicy
from repro.models import decomposed as D
from repro.models import make_fake_batch, model_fns
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = all_archs()["llama2-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    batch = make_fake_batch(cfg, ShapeSpec("smoke", 32, 2, "train"))
    base = T.forward(params, cfg, batch["tokens"])
    return cfg, params, batch["tokens"], base


def _rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / np.abs(b).max()


def test_full_rank_is_exact(setup):
    cfg, params, tokens, base = setup
    pol = DecompositionPolicy.from_layer_list(cfg.num_layers, [0, 1],
                                              rank=32, outlier_frac=0.05,
                                              iters=48)
    out = D.forward(params, cfg, tokens,
                    D.DecomposedRuntime(policy=pol))
    assert _rel(out, base) < 0.05


def test_quality_monotone_in_rank(setup):
    cfg, params, tokens, base = setup
    kls = []
    for r in (2, 8, 32):
        pol = DecompositionPolicy.from_layer_list(
            cfg.num_layers, [0, 1], rank=r, outlier_frac=0.03,
            iters=min(r + 16, 48))
        kls.append(float(D.logit_kl(params, cfg, tokens,
                                    D.DecomposedRuntime(policy=pol))))
    assert kls[0] > kls[1] > kls[2]


def test_outliers_improve_quality(setup):
    """Paper Fig. 10: outlier extraction lowers degradation at small rank."""
    cfg, params, tokens, base = setup
    def kl(frac):
        pol = DecompositionPolicy.from_layer_list(cfg.num_layers, [0, 1],
                                                  rank=4, outlier_frac=frac)
        return float(D.logit_kl(params, cfg, tokens,
                                D.DecomposedRuntime(policy=pol)))
    assert kl(0.10) < kl(0.0)


def test_input_weight_mode(setup):
    cfg, params, tokens, base = setup
    pol = DecompositionPolicy.from_layer_list(cfg.num_layers, [0], rank=32,
                                              outlier_frac=0.05, iters=48,
                                              decompose_weights=True,
                                              weight_rank=128)
    wfac = D.decompose_layer_weights(params, cfg, pol)
    assert 0 in wfac
    out = D.forward(params, cfg, tokens, D.DecomposedRuntime(policy=pol),
                    wfac)
    assert _rel(out, base) < 0.05


def test_preserved_attention_mode_finite(setup):
    cfg, params, tokens, base = setup
    pol = DecompositionPolicy.from_layer_list(cfg.num_layers, [0, 1],
                                              rank=16, outlier_frac=0.03)
    out = D.forward(params, cfg, tokens,
                    D.DecomposedRuntime(policy=pol, attn_mode="preserved"))
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_policy_selects_layers(setup):
    cfg, params, tokens, base = setup
    pol = DecompositionPolicy.none(cfg.num_layers)
    out = D.forward(params, cfg, tokens, D.DecomposedRuntime(policy=pol))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(base, np.float32),
                               rtol=2e-2, atol=2e-1)
