"""DecomposeEngine: backend parity, padding exactness, consumer regression.

Acceptance checks for the unified pipeline:
* jnp reference vs Pallas-interpret BATCHED backend agree across rank/batch/
  dtype (the batched fused kernel is numerically the same algorithm);
* the batched backend issues ONE kernel launch over the whole batch (the
  hooks are the native batched ones, not a vmap lift);
* decomposed_kv prefill through the engine matches the pre-engine
  per-callsite path (lz.decompose directly);
* pad-plan caching in kernels.ops is hit, not recomputed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lanczos as lz
from repro.core.policy import DecompositionPolicy, LayerPolicy
from repro.engine import (DecomposeEngine, EngineConfig, available_backends,
                          get_backend)
from repro.kernels import ops


def _x(key, b, s, h, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), (b, s, h),
                             jnp.float32).astype(dtype)


@pytest.fixture(scope="module")
def engines():
    return {name: DecomposeEngine(EngineConfig(backend=name))
            for name in ("reference", "pallas_interpret", "pallas_vmap")}


# ---------------------------------------------------------------------------
# Backend parity: reference vs batched Pallas kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rank", [1, 4, 8])
@pytest.mark.parametrize("batch", [1, 4])
def test_parity_reference_vs_pallas_f32(engines, rank, batch):
    x = _x(rank * 10 + batch, batch, 32, 64, jnp.float32)
    lr_ref = engines["reference"].decompose(x, rank)
    lr_pal = engines["pallas_interpret"].decompose(x, rank)
    np.testing.assert_allclose(np.asarray(lr_ref.reconstruct()),
                               np.asarray(lr_pal.reconstruct()),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(lr_ref.core),
                               np.asarray(lr_pal.core), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("rank", [2, 8])
@pytest.mark.parametrize("batch", [2, 3])
def test_parity_reference_vs_pallas_bf16(engines, rank, batch):
    x = _x(rank * 100 + batch, batch, 32, 64, jnp.bfloat16)
    lr_ref = engines["reference"].decompose(x, rank)
    lr_pal = engines["pallas_interpret"].decompose(x, rank)
    assert lr_pal.u.dtype == jnp.bfloat16
    # both paths upcast to fp32 internally; bf16 output rounding dominates
    np.testing.assert_allclose(
        np.asarray(lr_ref.reconstruct(), np.float32),
        np.asarray(lr_pal.reconstruct(), np.float32), rtol=3e-2, atol=3e-2)


def test_parity_on_nondivisible_shapes_via_pad_plan(engines):
    """33×48 does not divide f=8 on S: the engine pads through the cached
    plan and slices back; padded vs unpadded must be the SAME math because
    the start vector is zero-extended."""
    x = _x(5, 2, 33, 48, jnp.float32)
    lr_ref = engines["reference"].decompose(x, 6)
    lr_pal = engines["pallas_interpret"].decompose(x, 6)
    assert lr_pal.u.shape == (2, 33, 6) and lr_pal.vt.shape == (2, 6, 48)
    np.testing.assert_allclose(np.asarray(lr_ref.reconstruct()),
                               np.asarray(lr_pal.reconstruct()),
                               rtol=5e-3, atol=5e-3)


def test_batched_backend_is_native_not_vmap(engines):
    """The acceptance property: the pallas backends run ONE batched launch
    per Lanczos pass — their hooks are kernels.ops batched hooks, distinct
    from the vmap-of-scalar lift used by the fallback backend."""
    batched = ops.make_batched_pallas_hooks(8, interpret=True)
    assert engines["pallas_interpret"]._hooks is batched
    assert get_backend("pallas_interpret").batched_launch
    assert not get_backend("pallas_vmap").batched_launch
    assert engines["pallas_vmap"]._hooks is not batched
    # and the native batched hook really consumes the whole batch at once
    a = _x(1, 3, 32, 64, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
    vbuf = jnp.zeros((3, 64, 4))
    z = batched.right_step(a, u, vbuf)
    assert z.shape == (3, 64)


@pytest.mark.parametrize("rank", [4, 8])
@pytest.mark.parametrize("batch", [1, 3])
def test_parity_decompose_kv_reference_vs_pallas(engines, rank, batch):
    """The serving KV factorization rides the same backend matrix: the
    (U·Σ, Vᵀ) product must agree between the jnp reference and the batched
    Pallas-interpret backend."""
    x = _x(rank * 7 + batch, batch, 32, 64, jnp.float32)
    u_r, vt_r = engines["reference"].decompose_kv(x, rank)
    u_p, vt_p = engines["pallas_interpret"].decompose_kv(x, rank)
    assert u_p.shape == (batch, 32, rank) and vt_p.shape == (batch, rank, 64)
    np.testing.assert_allclose(
        np.asarray(jnp.einsum("btr,brh->bth", u_r, vt_r)),
        np.asarray(jnp.einsum("btr,brh->bth", u_p, vt_p)),
        rtol=5e-3, atol=5e-3)
    # exact=True bypasses the backend entirely — identical across backends
    e_r = engines["reference"].decompose_kv(x, rank, exact=True)
    e_p = engines["pallas_interpret"].decompose_kv(x, rank, exact=True)
    np.testing.assert_allclose(np.asarray(e_r[0]), np.asarray(e_p[0]),
                               rtol=1e-5, atol=1e-5)


def test_parity_splice_admission_cache_across_backends():
    """Per-slot splice admission through the serving engine produces the
    same decomposed-KV cache (as an operator: U·Vᵀ, and the dense tail)
    under the reference and pallas_interpret backends."""
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.serving import Engine, Request

    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32) for n in (10, 6)]

    caches = {}
    for backend in ("reference", "pallas_interpret"):
        eng = Engine(cfg, params, slots=2, max_len=64,
                     decompose_engine=DecomposeEngine(EngineConfig(
                         backend=backend, kv_rank=8, kv_tail=4)))
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=8))
        for step in range(12):
            if step == 2:   # splice-admit while slot 0 is live
                eng.submit(Request(uid=1, prompt=prompts[1],
                                   max_new_tokens=6))
            eng.step()
        caches[backend] = eng.cache
        np.testing.assert_array_equal(eng.frozen_len >= 16, True)
    a, b = caches["reference"], caches["pallas_interpret"]
    for uk, vk in (("k_u", "k_vt"), ("v_u", "v_vt")):
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("lbtr,lbrh->lbth", a[uk], a[vk])),
            np.asarray(jnp.einsum("lbtr,lbrh->lbth", b[uk], b[vk])),
            rtol=5e-2, atol=5e-2)
    for k in ("k", "v"):
        np.testing.assert_allclose(np.asarray(a["tail"][k]),
                                   np.asarray(b["tail"][k]),
                                   rtol=5e-2, atol=5e-2)


def test_vmap_fallback_matches_batched_kernels(engines):
    x = _x(9, 4, 32, 64, jnp.float32)
    lr_v = engines["pallas_vmap"].decompose(x, 5)
    lr_b = engines["pallas_interpret"].decompose(x, 5)
    np.testing.assert_allclose(np.asarray(lr_v.reconstruct()),
                               np.asarray(lr_b.reconstruct()),
                               rtol=5e-3, atol=5e-3)


def test_hook_cache_does_not_freeze_interpret_flag():
    """Flipping ops.INTERPRET after a cached interpret=None resolution must
    yield different hooks (the TPU-deployment contract in ops.py's
    docstring), while equal resolved configs share one identity."""
    h_default = ops.make_batched_pallas_hooks(8)        # resolves INTERPRET
    assert h_default is ops.make_batched_pallas_hooks(8, interpret=True)
    try:
        ops.INTERPRET = False
        assert ops.make_batched_pallas_hooks(8) is not h_default
        assert ops.make_batched_pallas_hooks(8) is \
            ops.make_batched_pallas_hooks(8, interpret=False)
    finally:
        ops.INTERPRET = True
    assert ops.make_batched_pallas_hooks(8) is h_default


def test_pad_plan_is_cached():
    ops.pad_plan.cache_clear()
    ops.padded_dims.cache_clear()
    for _ in range(5):
        assert ops.padded_dims(33, 48, 8) == (40, 48)
        ops.pad_plan((2, 33, 48), 1, 8)
    assert ops.padded_dims.cache_info().hits >= 4
    assert ops.pad_plan.cache_info().hits >= 4


# ---------------------------------------------------------------------------
# Policy / outlier pipeline through the engine
# ---------------------------------------------------------------------------

def test_decompose_activation_matches_manual_pipeline():
    """Engine pipeline == hand-wired extract → decompose → attach (the old
    per-callsite decomposed.decompose_activation body)."""
    from repro.core import outlier as ol
    pol = DecompositionPolicy.from_layer_list(4, [0], rank=6,
                                              outlier_frac=0.05, iters=10)
    eng = DecomposeEngine(EngineConfig(policy=pol))
    x = _x(11, 2, 32, 64, jnp.float32)
    got = eng.decompose_activation(x, 0)

    lp = pol.layer(0)
    thr = pol.thresholds.get(0)
    num_c = max(1, round(lp.outlier_frac * 64))
    base, vals, idx = ol.extract(x, jnp.asarray(thr, jnp.float32), num_c)
    want = lz.decompose(base, lp.rank, iters=lp.effective_iters)
    want = ol.attach_dense_outliers(want, vals, idx)
    np.testing.assert_allclose(np.asarray(got.reconstruct()),
                               np.asarray(want.reconstruct()),
                               rtol=1e-4, atol=1e-4)
    assert got.o_idx is not None and got.o_idx.shape[-1] == num_c


def test_engine_config_layer_fallbacks():
    eng = DecomposeEngine(EngineConfig())       # no policy
    assert eng.layer_policy(3) == LayerPolicy(decompose=False)
    assert eng.threshold(3) == 6.0              # ThresholdTable default


# ---------------------------------------------------------------------------
# Consumer regression: decomposed_kv prefill through the engine
# ---------------------------------------------------------------------------

def test_dkv_prefill_engine_matches_per_callsite_path():
    """prefill_dkv (engine-threaded) reproduces the pre-engine path that
    called lz.decompose at the callsite with iters = min(r+8, dims)."""
    from repro.configs import all_archs
    from repro.models import decomposed_kv as DK
    from repro.models import model_fns
    from repro.models import transformer as T

    cfg = all_archs()["deepseek-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    rank = 4

    eng = DecomposeEngine(EngineConfig(kv_rank=rank))
    logits, cache = DK.prefill_dkv(params, cfg, toks, rank, tail=8,
                                   engine=eng)

    # the old per-callsite computation, inlined
    _, dense_cache = T.prefill(params, cfg, toks, 16)
    kvw = cfg.num_kv_heads * cfg.resolved_head_dim
    flat = dense_cache["k"].reshape(cfg.num_layers * 2, 16, kvw)
    lr = lz.decompose(flat.astype(jnp.float32), rank,
                      iters=min(rank + 8, min(flat.shape[-2:])))
    k_u_old = lr.scaled_u().astype(flat.dtype) \
        .reshape(cfg.num_layers, 2, 16, rank)
    np.testing.assert_allclose(np.asarray(cache["k_u"], np.float32),
                               np.asarray(k_u_old, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_runtime_steps_thread_engine():
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.runtime import steps

    cfg = all_archs()["llama2-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    pol = DecompositionPolicy.from_layer_list(cfg.num_layers, [0], rank=4)
    fwd = steps.make_decomposed_forward_step(
        cfg, EngineConfig(policy=pol))
    out = fwd(params, toks)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_backend_registry_rejects_unknown():
    with pytest.raises(KeyError):
        get_backend("no-such-backend")
    assert {"reference", "pallas", "pallas_interpret",
            "pallas_vmap"} <= set(available_backends())


def test_mesh_engine_decompose_matches_unsharded():
    """The mesh path (explicit in/out shardings on the jitted Lanczos
    pipeline; shard_map for kernel backends) reconstructs the same
    operator as the single-device engine — on a 1×1 mesh the graphs are
    identical, and the output factors carry the mesh's sharding."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 24, 40), jnp.float32)
    for backend in ("reference", "pallas_interpret"):
        e0 = DecomposeEngine(EngineConfig(backend=backend))
        e1 = DecomposeEngine(EngineConfig(backend=backend, mesh=mesh))
        lr0, lr1 = e0.decompose(x, 5), e1.decompose(x, 5)
        r0 = np.einsum("bsr,br,brh->bsh", *(np.asarray(a, np.float32)
             for a in (lr0.u, lr0.core, lr0.vt)))
        r1 = np.einsum("bsr,br,brh->bsh", *(np.asarray(a, np.float32)
             for a in (lr1.u, lr1.core, lr1.vt)))
        np.testing.assert_allclose(r1, r0, rtol=1e-5, atol=1e-5)
        assert lr1.u.sharding.mesh.shape == mesh.shape
    # decompose_kv rides the same path
    e1 = DecomposeEngine(EngineConfig(kv_rank=6, mesh=mesh))
    u, vt = e1.decompose_kv(x, 6)
    assert u.shape == (4, 24, 6) and vt.shape == (4, 6, 40)


def test_padded_z0_is_host_value():
    """The start-vector cache holds HOST numpy (jit places it per call
    site), never a committed device array — regression for the device-
    buffer leak / wrong-device-under-mesh bug."""
    from repro.engine.engine import _padded_z0
    z = _padded_z0(24, 32)
    assert isinstance(z, np.ndarray) and not isinstance(z, jax.Array)
    assert z.shape == (32,) and (z[24:] == 0).all()
    # identical to what the jitted core generates for the unpadded width
    ref = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (24,),
                                       jnp.float32))
    np.testing.assert_array_equal(z[:24], ref)
    # and usable under an outer trace (the jitted dkv prefill case)
    out = jax.jit(lambda: jnp.asarray(_padded_z0(24, 32)) * 2.0)()
    np.testing.assert_allclose(np.asarray(out), z * 2.0)
