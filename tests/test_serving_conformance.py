"""Token-level differential conformance: decomposed-KV serving vs dense.

The paper's serving claim is only checkable end-to-end (Moar et al.,
arXiv:2405.06626): greedy-sampled tokens from the low-rank KV engine must
match the dense-cache engine on the same prompts.  At near-full rank with
``dkv_exact`` (direct SVD, §2.3) every factorization and every per-slot
tail fold is mathematically exact, so the match is TOKEN-EXACT — across
tail-fold boundaries, staggered admissions, and ``slots > len(queue)``.

Also here: splice-admission conformance for a non-dense family (MoE) —
admitting while another slot is live must not perturb the live sequence's
tokens — and the §2.3 parity of ``decompose_kv(exact=True)`` vs Lanczos
at near-full rank.

Mesh-parallel conformance: serving on an 8-host-device (8, 1) mesh —
caches DP-sharded over the slot axis, factorization DP-sharded over
layers×batch — must produce BYTE-IDENTICAL greedy tokens to the 1-device
engine, across tail-fold boundaries and staggered admissions.  The
8-device twin runs in a subprocess (the device count locks at jax init;
tier-1 must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import model_fns
from repro.serving import Engine, Request

RANK, TAIL, MAX_LEN, MAX_NEW = 64, 4, 64, 12
PROMPT_LENS = (12, 7, 15)


@pytest.fixture(scope="module")
def dense_model():
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens=PROMPT_LENS, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, n, dtype=np.int32) for n in lens]


def _serve(cfg, params, prompts, *, dkv: bool, stagger: bool, slots: int):
    kw = dict(decompose_kv_rank=RANK, dkv_tail=TAIL, dkv_exact=True) \
        if dkv else {}
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN, **kw)
    done = []
    if not stagger:
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
        done = eng.run()
    else:
        # arrivals land while earlier requests are mid-decode
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MAX_NEW))
        arrivals = {3 * i: i for i in range(1, len(prompts))}
        for step in range(200):
            if step in arrivals:
                i = arrivals[step]
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=MAX_NEW))
            done.extend(eng.step())
            if len(done) == len(prompts) and not any(eng.live):
                break
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng.stats


@pytest.mark.parametrize("stagger,slots", [(False, 2), (True, 2), (True, 4)])
def test_dkv_matches_dense_token_level(dense_model, stagger, slots):
    """Greedy tokens of decomposed-KV serving == dense serving, across
    per-slot tail folds; slots=4 also covers slots > len(queue)."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    dense, _ = _serve(cfg, params, prompts, dkv=False, stagger=stagger,
                      slots=slots)
    dkv, st = _serve(cfg, params, prompts, dkv=True, stagger=stagger,
                     slots=slots)
    assert st.tail_folds > 0             # fold boundaries were crossed
    if stagger:
        assert st.prefill_batches >= 2   # admissions landed while live
    for uid in dense:
        assert dkv[uid] == dense[uid], \
            f"req {uid} diverged: {dkv[uid]} vs {dense[uid]}"


def test_dkv_admits_while_live_without_gang(dense_model):
    """The gang restriction is gone: a second request is admitted while
    slot 0 is mid-decode, and the live request's tokens are bit-identical
    to a solo run."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    solo, _ = _serve(cfg, params, prompts[:1], dkv=True, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts[:2], dkv=True, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # second admission was its own batch
    assert mixed[0] == solo[0], "live dkv sequence corrupted by admission"


def test_moe_splice_admission_token_level():
    """Non-dense family: MoE admits a request while another slot is live;
    the live request's tokens match a solo run token-for-token."""
    cfg = all_archs()["olmoe-1b-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, lens=(8, 6))
    solo, _ = _serve(cfg, params, prompts[:1], dkv=False, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts, dkv=False, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # admitted while slot 0 was live
    assert mixed[0] == solo[0], "live MoE sequence corrupted by admission"


# ---------------------------------------------------------------------------
# Mesh-parallel serving conformance (tentpole)
# ---------------------------------------------------------------------------

DKV_RANK, DKV_TAIL, MESH_SLOTS, MESH_NEW = 8, 4, 8, 12
MESH_PROMPT_LENS = (12, 7, 15)


def _serve_dkv_staggered(cfg, params, prompts, *, mesh, slots=MESH_SLOTS):
    """Staggered arrivals (admissions land mid-decode) on the dkv engine,
    rank well below full so tail folds are REAL retruncations."""
    from repro.engine import DecomposeEngine, EngineConfig
    de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                      mesh=mesh))
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN,
                 decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                 decompose_engine=de)
    done = []
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MESH_NEW))
    arrivals = {3 * i: i for i in range(1, len(prompts))}
    for step in range(200):
        if step in arrivals:
            i = arrivals[step]
            eng.submit(Request(uid=i, prompt=prompts[i],
                               max_new_tokens=MESH_NEW))
        done.extend(eng.step())
        if len(done) == len(prompts) and not any(eng.live):
            break
    assert eng.stats.tail_folds > 0          # fold boundaries were crossed
    assert eng.stats.prefill_batches >= 2    # admissions landed while live
    return {r.uid: r.out_tokens for r in done}, eng


_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    sys.path.insert(0, os.path.dirname(os.path.abspath(sys.argv[2])))
    from test_serving_conformance import (MESH_PROMPT_LENS,
                                          _serve_dkv_staggered)
    from repro.configs import all_archs
    from repro.launch.mesh import make_host_mesh
    from repro.models import model_fns

    assert len(jax.devices()) == 8
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32)
               for n in MESH_PROMPT_LENS]
    toks, eng = _serve_dkv_staggered(cfg, params, prompts,
                                     mesh=make_host_mesh(8, 1))
    ku = eng.cache["k_u"]
    json.dump({"tokens": {str(u): t for u, t in toks.items()},
               "ku_nshards": len(ku.addressable_shards),
               "ku_spec": str(ku.sharding.spec)},
              open(sys.argv[1], "w"))
""")


def test_sharded_serving_byte_identical_to_1_device(dense_model, tmp_path):
    """THE mesh-serving conformance gate: greedy tokens from the 8-host-
    device DP-sharded engine (subprocess — device count locks at jax init)
    are byte-identical to this process's 1-device engine on the same
    staggered schedule, and the live cache really was 8-way sharded."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    local, _ = _serve_dkv_staggered(cfg, params, prompts, mesh=None)

    out = tmp_path / "sharded.json"
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)           # the script forces its own 8
    subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, str(out),
         os.path.abspath(__file__)],
        check=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    got = json.load(open(out))
    assert got["ku_nshards"] == 8        # slot axis genuinely 8-way DP
    assert "data" in got["ku_spec"]
    assert {int(k): v for k, v in got["tokens"].items()} == local, \
        f"sharded tokens diverged: {got['tokens']} vs {local}"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI distributed job forces "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8)")
def test_sharded_serving_inprocess_8dev(dense_model):
    """In-process twin of the subprocess gate for the CI distributed job:
    same schedule, sharded vs unsharded engines in ONE process, plus the
    batched-admission case (all 8 slots admitted at once ⇒ the Lanczos
    factorization batch itself DP-shards)."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = dense_model
    mesh = make_host_mesh(8, 1)
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    a, _ = _serve_dkv_staggered(cfg, params, prompts, mesh=None)
    b, eng = _serve_dkv_staggered(cfg, params, prompts, mesh=mesh)
    assert a == b
    assert len(eng.cache["k_u"].addressable_shards) == 8
    # batched admission: one prefill of 8 × 12-token prompts
    many = _prompts(cfg, lens=(12,) * MESH_SLOTS, seed=1)

    def gang_all(mesh):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                          mesh=mesh))
        eng = Engine(cfg, params, slots=MESH_SLOTS, max_len=MAX_LEN,
                     decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                     decompose_engine=de)
        for i, p in enumerate(many):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MESH_NEW))
        return {r.uid: r.out_tokens for r in eng.run()}

    assert gang_all(None) == gang_all(mesh)


def test_exact_svd_vs_lanczos_near_full_rank():
    """§2.3: on a KV-like block (decaying spectrum — real K/V rows are
    strongly correlated), direct SVD (exact=True) and Lanczos agree as
    operators at near-full rank, with the exact path never worse
    (floating-point Lanczos loses trailing directions on FLAT spectra,
    which is exactly why the serving knob exists)."""
    from repro.engine import DecomposeEngine, EngineConfig
    eng = DecomposeEngine(EngineConfig())
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    q1, _ = jnp.linalg.qr(jax.random.normal(k1, (4, 24, 24)))
    q2, _ = jnp.linalg.qr(jnp.swapaxes(
        jax.random.normal(k2, (4, 24, 64)), -1, -2))
    s = jnp.power(0.6, jnp.arange(24))
    x = jnp.einsum("btr,r,bhr->bth", q1, s, q2)      # [4, 24, 64]
    nrm = float(jnp.linalg.norm(x))
    for r in (24, 20):                   # full and near-full row rank
        ue, vte = eng.decompose_kv(x, r, exact=True)
        ul, vtl = eng.decompose_kv(x, r)
        rec_e = jnp.einsum("btr,brh->bth", ue, vte)
        rec_l = jnp.einsum("btr,brh->bth", ul, vtl)
        err_e = float(jnp.linalg.norm(rec_e - x)) / nrm
        err_l = float(jnp.linalg.norm(rec_l - x)) / nrm
        assert err_e <= 1e-3             # direct SVD: (near-)exact
        assert err_e <= err_l + 1e-6     # exact never worse than Lanczos
        np.testing.assert_allclose(np.asarray(rec_l), np.asarray(rec_e),
                                   rtol=1e-3, atol=1e-3)
    # a requested rank beyond min(T, kvw) caps at the achievable rank
    uc, _ = eng.decompose_kv(x, 100, exact=True)
    assert uc.shape[-1] == 24