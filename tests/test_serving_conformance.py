"""Token-level differential conformance: decomposed-KV serving vs dense.

The paper's serving claim is only checkable end-to-end (Moar et al.,
arXiv:2405.06626): greedy-sampled tokens from the low-rank KV engine must
match the dense-cache engine on the same prompts.  At near-full rank with
``dkv_exact`` (direct SVD, §2.3) every factorization and every per-slot
tail fold is mathematically exact, so the match is TOKEN-EXACT — across
tail-fold boundaries, staggered admissions, and ``slots > len(queue)``.

Also here: splice-admission conformance for a non-dense family (MoE) —
admitting while another slot is live must not perturb the live sequence's
tokens — and the §2.3 parity of ``decompose_kv(exact=True)`` vs Lanczos
at near-full rank.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import model_fns
from repro.serving import Engine, Request

RANK, TAIL, MAX_LEN, MAX_NEW = 64, 4, 64, 12
PROMPT_LENS = (12, 7, 15)


@pytest.fixture(scope="module")
def dense_model():
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens=PROMPT_LENS, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, n, dtype=np.int32) for n in lens]


def _serve(cfg, params, prompts, *, dkv: bool, stagger: bool, slots: int):
    kw = dict(decompose_kv_rank=RANK, dkv_tail=TAIL, dkv_exact=True) \
        if dkv else {}
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN, **kw)
    done = []
    if not stagger:
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
        done = eng.run()
    else:
        # arrivals land while earlier requests are mid-decode
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MAX_NEW))
        arrivals = {3 * i: i for i in range(1, len(prompts))}
        for step in range(200):
            if step in arrivals:
                i = arrivals[step]
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=MAX_NEW))
            done.extend(eng.step())
            if len(done) == len(prompts) and not any(eng.live):
                break
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng.stats


@pytest.mark.parametrize("stagger,slots", [(False, 2), (True, 2), (True, 4)])
def test_dkv_matches_dense_token_level(dense_model, stagger, slots):
    """Greedy tokens of decomposed-KV serving == dense serving, across
    per-slot tail folds; slots=4 also covers slots > len(queue)."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    dense, _ = _serve(cfg, params, prompts, dkv=False, stagger=stagger,
                      slots=slots)
    dkv, st = _serve(cfg, params, prompts, dkv=True, stagger=stagger,
                     slots=slots)
    assert st.tail_folds > 0             # fold boundaries were crossed
    if stagger:
        assert st.prefill_batches >= 2   # admissions landed while live
    for uid in dense:
        assert dkv[uid] == dense[uid], \
            f"req {uid} diverged: {dkv[uid]} vs {dense[uid]}"


def test_dkv_admits_while_live_without_gang(dense_model):
    """The gang restriction is gone: a second request is admitted while
    slot 0 is mid-decode, and the live request's tokens are bit-identical
    to a solo run."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    solo, _ = _serve(cfg, params, prompts[:1], dkv=True, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts[:2], dkv=True, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # second admission was its own batch
    assert mixed[0] == solo[0], "live dkv sequence corrupted by admission"


def test_moe_splice_admission_token_level():
    """Non-dense family: MoE admits a request while another slot is live;
    the live request's tokens match a solo run token-for-token."""
    cfg = all_archs()["olmoe-1b-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, lens=(8, 6))
    solo, _ = _serve(cfg, params, prompts[:1], dkv=False, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts, dkv=False, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # admitted while slot 0 was live
    assert mixed[0] == solo[0], "live MoE sequence corrupted by admission"


def test_exact_svd_vs_lanczos_near_full_rank():
    """§2.3: on a KV-like block (decaying spectrum — real K/V rows are
    strongly correlated), direct SVD (exact=True) and Lanczos agree as
    operators at near-full rank, with the exact path never worse
    (floating-point Lanczos loses trailing directions on FLAT spectra,
    which is exactly why the serving knob exists)."""
    from repro.engine import DecomposeEngine, EngineConfig
    eng = DecomposeEngine(EngineConfig())
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    q1, _ = jnp.linalg.qr(jax.random.normal(k1, (4, 24, 24)))
    q2, _ = jnp.linalg.qr(jnp.swapaxes(
        jax.random.normal(k2, (4, 24, 64)), -1, -2))
    s = jnp.power(0.6, jnp.arange(24))
    x = jnp.einsum("btr,r,bhr->bth", q1, s, q2)      # [4, 24, 64]
    nrm = float(jnp.linalg.norm(x))
    for r in (24, 20):                   # full and near-full row rank
        ue, vte = eng.decompose_kv(x, r, exact=True)
        ul, vtl = eng.decompose_kv(x, r)
        rec_e = jnp.einsum("btr,brh->bth", ue, vte)
        rec_l = jnp.einsum("btr,brh->bth", ul, vtl)
        err_e = float(jnp.linalg.norm(rec_e - x)) / nrm
        err_l = float(jnp.linalg.norm(rec_l - x)) / nrm
        assert err_e <= 1e-3             # direct SVD: (near-)exact
        assert err_e <= err_l + 1e-6     # exact never worse than Lanczos
        np.testing.assert_allclose(np.asarray(rec_l), np.asarray(rec_e),
                                   rtol=1e-3, atol=1e-3)
    # a requested rank beyond min(T, kvw) caps at the achievable rank
    uc, _ = eng.decompose_kv(x, 100, exact=True)
    assert uc.shape[-1] == 24