"""Token-level differential conformance: decomposed-KV serving vs dense.

The paper's serving claim is only checkable end-to-end (Moar et al.,
arXiv:2405.06626): greedy-sampled tokens from the low-rank KV engine must
match the dense-cache engine on the same prompts.  At near-full rank with
``dkv_exact`` (direct SVD, §2.3) every factorization and every per-slot
tail fold is mathematically exact, so the match is TOKEN-EXACT — across
tail-fold boundaries, staggered admissions, and ``slots > len(queue)``.

Also here: splice-admission conformance for a non-dense family (MoE) —
admitting while another slot is live must not perturb the live sequence's
tokens — and the §2.3 parity of ``decompose_kv(exact=True)`` vs Lanczos
at near-full rank.

Mesh-parallel conformance: serving on an 8-host-device (8, 1) mesh —
caches DP-sharded over the slot axis, factorization DP-sharded over
layers×batch — must produce BYTE-IDENTICAL greedy tokens to the 1-device
engine, across tail-fold boundaries and staggered admissions.  The
8-device twin runs in a subprocess (the device count locks at jax init;
tier-1 must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import model_fns
from repro.serving import Engine, Request

RANK, TAIL, MAX_LEN, MAX_NEW = 64, 4, 64, 12
PROMPT_LENS = (12, 7, 15)


@pytest.fixture(scope="module")
def dense_model():
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens=PROMPT_LENS, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, n, dtype=np.int32) for n in lens]


def _serve(cfg, params, prompts, *, dkv: bool, stagger: bool, slots: int):
    kw = dict(decompose_kv_rank=RANK, dkv_tail=TAIL, dkv_exact=True) \
        if dkv else {}
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN, **kw)
    done = []
    if not stagger:
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
        done = eng.run()
    else:
        # arrivals land while earlier requests are mid-decode
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MAX_NEW))
        arrivals = {3 * i: i for i in range(1, len(prompts))}
        for step in range(200):
            if step in arrivals:
                i = arrivals[step]
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=MAX_NEW))
            done.extend(eng.step())
            if len(done) == len(prompts) and not any(eng.live):
                break
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng.stats


@pytest.mark.parametrize("stagger,slots", [(False, 2), (True, 2), (True, 4)])
def test_dkv_matches_dense_token_level(dense_model, stagger, slots):
    """Greedy tokens of decomposed-KV serving == dense serving, across
    per-slot tail folds; slots=4 also covers slots > len(queue)."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    dense, _ = _serve(cfg, params, prompts, dkv=False, stagger=stagger,
                      slots=slots)
    dkv, st = _serve(cfg, params, prompts, dkv=True, stagger=stagger,
                     slots=slots)
    assert st.tail_folds > 0             # fold boundaries were crossed
    if stagger:
        assert st.prefill_batches >= 2   # admissions landed while live
    for uid in dense:
        assert dkv[uid] == dense[uid], \
            f"req {uid} diverged: {dkv[uid]} vs {dense[uid]}"


def test_dkv_admits_while_live_without_gang(dense_model):
    """The gang restriction is gone: a second request is admitted while
    slot 0 is mid-decode, and the live request's tokens are bit-identical
    to a solo run."""
    cfg, params = dense_model
    prompts = _prompts(cfg)
    solo, _ = _serve(cfg, params, prompts[:1], dkv=True, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts[:2], dkv=True, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # second admission was its own batch
    assert mixed[0] == solo[0], "live dkv sequence corrupted by admission"


def test_moe_splice_admission_token_level():
    """Non-dense family: MoE admits a request while another slot is live;
    the live request's tokens match a solo run token-for-token."""
    cfg = all_archs()["olmoe-1b-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, lens=(8, 6))
    solo, _ = _serve(cfg, params, prompts[:1], dkv=False, stagger=False,
                     slots=2)
    mixed, st = _serve(cfg, params, prompts, dkv=False, stagger=True,
                       slots=2)
    assert st.prefill_batches == 2       # admitted while slot 0 was live
    assert mixed[0] == solo[0], "live MoE sequence corrupted by admission"


# ---------------------------------------------------------------------------
# Mesh-parallel serving conformance (tentpole)
# ---------------------------------------------------------------------------

DKV_RANK, DKV_TAIL, MESH_SLOTS, MESH_NEW = 8, 4, 8, 12
MESH_PROMPT_LENS = (12, 7, 15)


def _serve_dkv_staggered(cfg, params, prompts, *, mesh, slots=MESH_SLOTS,
                         paged=False):
    """Staggered arrivals (admissions land mid-decode) on the dkv engine,
    rank well below full so tail folds are REAL retruncations."""
    from repro.engine import DecomposeEngine, EngineConfig
    de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                      kv_page=4, mesh=mesh))
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN,
                 decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                 decompose_engine=de, paged=paged)
    done = []
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MESH_NEW))
    arrivals = {3 * i: i for i in range(1, len(prompts))}
    for step in range(200):
        if step in arrivals:
            i = arrivals[step]
            eng.submit(Request(uid=i, prompt=prompts[i],
                               max_new_tokens=MESH_NEW))
        done.extend(eng.step())
        if len(done) == len(prompts) and not any(eng.live):
            break
    assert eng.stats.tail_folds > 0          # fold boundaries were crossed
    assert eng.stats.prefill_batches >= 2    # admissions landed while live
    return {r.uid: r.out_tokens for r in done}, eng


_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    sys.path.insert(0, os.path.dirname(os.path.abspath(sys.argv[2])))
    from test_serving_conformance import (MESH_PROMPT_LENS,
                                          _serve_dkv_staggered)
    from repro.configs import all_archs
    from repro.launch.mesh import make_host_mesh
    from repro.models import model_fns

    assert len(jax.devices()) == 8
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32)
               for n in MESH_PROMPT_LENS]
    mesh = make_host_mesh(8, 1)
    toks, eng = _serve_dkv_staggered(cfg, params, prompts, mesh=mesh)
    ptoks, peng = _serve_dkv_staggered(cfg, params, prompts, mesh=mesh,
                                       paged=True)
    ku = eng.cache["k_u"]
    json.dump({"tokens": {str(u): t for u, t in toks.items()},
               "paged_tokens": {str(u): t for u, t in ptoks.items()},
               "ku_nshards": len(ku.addressable_shards),
               "ku_spec": str(ku.sharding.spec),
               "paged_free": peng.pager.alloc.free_pages,
               "paged_total": peng.pager.num_pages - 1},
              open(sys.argv[1], "w"))
""")


def test_sharded_serving_byte_identical_to_1_device(dense_model, tmp_path):
    """THE mesh-serving conformance gate: greedy tokens from the 8-host-
    device DP-sharded engine (subprocess — device count locks at jax init)
    are byte-identical to this process's 1-device engine on the same
    staggered schedule, and the live cache really was 8-way sharded."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    local, _ = _serve_dkv_staggered(cfg, params, prompts, mesh=None)

    out = tmp_path / "sharded.json"
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)           # the script forces its own 8
    subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, str(out),
         os.path.abspath(__file__)],
        check=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    got = json.load(open(out))
    assert got["ku_nshards"] == 8        # slot axis genuinely 8-way DP
    assert "data" in got["ku_spec"]
    assert {int(k): v for k, v in got["tokens"].items()} == local, \
        f"sharded tokens diverged: {got['tokens']} vs {local}"
    # the 8-device PAGED twin matches too (and returned every page)
    assert {int(k): v for k, v in got["paged_tokens"].items()} == local, \
        f"sharded PAGED tokens diverged: {got['paged_tokens']} vs {local}"
    assert got["paged_free"] == got["paged_total"], "leaked pages on mesh"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI distributed job forces "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8)")
def test_sharded_serving_inprocess_8dev(dense_model):
    """In-process twin of the subprocess gate for the CI distributed job:
    same schedule, sharded vs unsharded engines in ONE process, plus the
    batched-admission case (all 8 slots admitted at once ⇒ the Lanczos
    factorization batch itself DP-shards)."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = dense_model
    mesh = make_host_mesh(8, 1)
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    a, _ = _serve_dkv_staggered(cfg, params, prompts, mesh=None)
    b, eng = _serve_dkv_staggered(cfg, params, prompts, mesh=mesh)
    assert a == b
    assert len(eng.cache["k_u"].addressable_shards) == 8
    # batched admission: one prefill of 8 × 12-token prompts
    many = _prompts(cfg, lens=(12,) * MESH_SLOTS, seed=1)

    def gang_all(mesh):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                          mesh=mesh))
        eng = Engine(cfg, params, slots=MESH_SLOTS, max_len=MAX_LEN,
                     decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                     decompose_engine=de)
        for i, p in enumerate(many):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MESH_NEW))
        return {r.uid: r.out_tokens for r in eng.run()}

    assert gang_all(None) == gang_all(mesh)


# ---------------------------------------------------------------------------
# Paged-cache conformance (paged engine vs slot engine, prefix cache)
# ---------------------------------------------------------------------------


def test_paged_matches_slot_engine_staggered(dense_model):
    """THE paged gate: block-table serving is greedy-token-EXACT vs the
    slot engine at equal kv_rank (rank 8 — folds are real retruncations),
    across tail-fold boundaries and staggered mid-decode admissions.  The
    paged engine replays the slab arithmetic bit-for-bit (gathers slice
    to the mirrored slab geometry), so this holds at ANY rank, not just
    the near-full exact regime."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    slot, _ = _serve_dkv_staggered(cfg, params, prompts, mesh=None,
                                   slots=2)
    paged, eng = _serve_dkv_staggered(cfg, params, prompts, mesh=None,
                                      slots=2, paged=True)
    assert eng.stats.tail_folds > 0
    assert paged == slot, f"paged diverged: {paged} vs {slot}"
    # every page returned to the pool after the queue drained
    assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1
    assert eng.pager.talloc.free_pages == eng.pager.num_tail_pages - 1


def test_paged_matches_slot_engine_batched(dense_model):
    """Full-batch admission twin (all slots admitted in one prefill) plus
    slots > len(queue): the pow2 prefill padding and page write path must
    not perturb tokens."""
    cfg, params = dense_model
    prompts = _prompts(cfg)

    def serve(paged):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK,
                                          kv_tail=DKV_TAIL, kv_page=4))
        eng = Engine(cfg, params, slots=4, max_len=MAX_LEN,
                     decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                     decompose_engine=de, paged=paged)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
        return {r.uid: r.out_tokens for r in eng.run()}, eng

    slot, _ = serve(False)
    paged, eng = serve(True)
    assert eng.stats.tail_folds > 0
    assert paged == slot
    assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1


def test_paged_matches_slot_mixed_page_counts(dense_model):
    """Regression: staggered admissions from DIFFERENT plen buckets give
    the slots different block-table widths, so decode/fold gathers read
    the id-0 sink page through the block-table padding.  A fold must
    never leave residue in the sink (non-folding slots' rows scatter as
    zeros) or the shorter slot's next fold retruncates garbage and its
    tokens drift off the slot engine's."""
    cfg, params = dense_model

    def serve(paged):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK,
                                          kv_tail=DKV_TAIL, kv_page=4))
        eng = Engine(cfg, params, slots=2, max_len=MAX_LEN,
                     decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                     decompose_engine=de, paged=paged)
        rng = np.random.RandomState(7)
        # bucket 16 vs bucket 32 → 4 vs 8 pages per slot
        eng.submit(Request(uid=0, prompt=rng.randint(0, cfg.vocab, 12,
                                                     dtype=np.int32),
                           max_new_tokens=20))
        done = []
        for step in range(200):
            if step == 3:
                eng.submit(Request(uid=1,
                                   prompt=rng.randint(0, cfg.vocab, 20,
                                                      dtype=np.int32),
                                   max_new_tokens=8))
            done.extend(eng.step())
            if len(done) == 2 and not any(eng.live):
                break
        assert eng.stats.tail_folds >= 2
        return {r.uid: r.out_tokens for r in done}

    slot = serve(False)
    paged = serve(True)
    assert paged == slot, f"sink-page residue corrupted decode: " \
                          f"{paged} vs {slot}"


def test_prefix_cache_never_matches_padding_only(dense_model):
    """Regression: two UNRELATED short prompts share only their bucket
    left-padding (12 zero rows at bucket 16).  A boundary lying entirely
    inside the pad region must not count as a shared prefix — the cached
    low-rank basis was fit to the OTHER prompt's real rows — so the
    lookup must miss and tokens must match the prefix-cache-off engine."""
    cfg, params = dense_model
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, 4, dtype=np.int32)
               for _ in range(2)]
    assert not np.array_equal(prompts[0], prompts[1])

    def serve(prefix_cap):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(kv_rank=8, kv_tail=16, kv_page=4,
                                          kv_prefix_cache=prefix_cap))
        eng = Engine(cfg, params, slots=1, max_len=MAX_LEN,
                     decompose_kv_rank=8, dkv_tail=16,
                     decompose_engine=de, paged=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        return {r.uid: r.out_tokens for r in done}, eng

    off, _ = serve(0)
    on, eng = serve(4)
    assert eng.stats.prefix_hits == 0, \
        "padding-only boundary must not match unrelated prompts"
    assert on == off


def test_paged_prefix_cache_hit_miss_evict(dense_model):
    """Prefix-cache conformance: a shared-system-prompt workload admits
    later requests as HITS (refcounted page splice + tail-only suffix
    prefill — no prefix forward, no Lanczos) with greedy tokens matching
    the prefix-cache-off engine at near-full exact rank; capacity-1
    forces LRU eviction; no pages leak after the queue drains.

    (Hit and miss keep the suffix rows on different sides of the
    factorization — both exact vs dense to ~1e-6 — so greedy near-ties
    CAN flip; the fixed seed below is verified tie-free, like the other
    exact-rank suites in this file.)"""
    cfg, params = dense_model
    rng = np.random.RandomState(1)
    sys_prompt = rng.randint(0, cfg.vocab, 12, dtype=np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.randint(0, cfg.vocab, 3, dtype=np.int32)])
               for _ in range(4)]

    def serve(prefix_cap):
        from repro.engine import DecomposeEngine, EngineConfig
        de = DecomposeEngine(EngineConfig(
            kv_rank=48, kv_tail=8, kv_page=4, kv_exact=True,
            kv_prefix_cache=prefix_cap))
        eng = Engine(cfg, params, slots=2, max_len=MAX_LEN,
                     decompose_kv_rank=48, dkv_tail=8, dkv_exact=True,
                     decompose_engine=de, paged=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=8))
        done = eng.run()
        return {r.uid: r.out_tokens for r in done}, eng

    off, _ = serve(0)
    on, eng = serve(8)
    assert eng.stats.prefix_hits >= 2            # later arrivals hit
    assert eng.stats.prefix_misses >= 1          # first arrival missed
    assert on == off, f"prefix-cache hits diverged: {on} vs {off}"
    # cached pages outlive their slots (entries hold refs, slots drained)
    assert len(eng.pager.prefix) >= 1
    assert any(rc >= 1 for rc in eng.pager.alloc.live_refs.values())
    used = eng.pager.num_pages - 1 - eng.pager.alloc.free_pages
    assert used == sum(len(e.pages)
                       for e in eng.pager.prefix._entries.values())
    eng.pager.prefix.drop_all()                  # release the cache's refs
    assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1

    # capacity-1: the second distinct prompt evicts the first (LRU)
    evict, eng1 = serve(1)
    assert eng1.pager.prefix.evictions >= 1
    assert len(eng1.pager.prefix) == 1
    assert evict == off                          # eviction never corrupts


def test_paged_prefix_hit_skips_prefill_work(dense_model):
    """A full-page hit admits with tail-only work: the hit admission runs
    NO decomposition (stats show a hit, and the slot's frozen factors are
    the cached entry's pages — refcount 2 while both referents live)."""
    cfg, params = dense_model
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, 15, dtype=np.int32)

    from repro.engine import DecomposeEngine, EngineConfig
    de = DecomposeEngine(EngineConfig(kv_rank=48, kv_tail=8, kv_page=4,
                                      kv_exact=True, kv_prefix_cache=4))
    eng = Engine(cfg, params, slots=2, max_len=MAX_LEN,
                 decompose_kv_rank=48, dkv_tail=8, dkv_exact=True,
                 decompose_engine=de, paged=True)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    eng.run()
    assert eng.stats.prefix_misses == 1
    eng.submit(Request(uid=1, prompt=prompt.copy(), max_new_tokens=4))
    eng.step()                                   # admission lands
    assert eng.stats.prefix_hits == 1
    slot = next(i for i, r in enumerate(eng.live) if r is not None)
    shared = eng.pager.bt_u[slot]
    refs = eng.pager.alloc.live_refs
    assert shared and all(refs[p] >= 2 for p in shared), \
        "hit slot must alias the cached entry's pages, not copy them"
    eng.run()
    # copy-on-write: if the slot folded, the shared pages are untouched
    assert all(p in refs or p in eng.pager.alloc.live_refs
               for p in shared)


def test_paged_hit_survives_same_batch_eviction(dense_model):
    """Regression: one admission batch carrying a HIT on the LRU entry
    plus a MISS whose insertion evicts that entry (capacity 1).  The hit
    takes its page refs BEFORE the miss inserts, so eviction only drops
    the cache's refs — the hit slot keeps valid pages and the engine
    neither crashes nor leaks."""
    cfg, params = dense_model
    rng = np.random.RandomState(1)
    p1 = rng.randint(0, cfg.vocab, 15, dtype=np.int32)
    p2 = rng.randint(0, cfg.vocab, 15, dtype=np.int32)

    from repro.engine import DecomposeEngine, EngineConfig
    de = DecomposeEngine(EngineConfig(kv_rank=48, kv_tail=8, kv_page=4,
                                      kv_exact=True, kv_prefix_cache=1))
    eng = Engine(cfg, params, slots=2, max_len=MAX_LEN,
                 decompose_kv_rank=48, dkv_tail=8, dkv_exact=True,
                 decompose_engine=de, paged=True)
    eng.submit(Request(uid=0, prompt=p1, max_new_tokens=4))
    eng.run()                                    # populates the cache
    # one batch: hit on p1's entry + miss that evicts it (capacity 1)
    eng.submit(Request(uid=1, prompt=p1.copy(), max_new_tokens=6))
    eng.submit(Request(uid=2, prompt=p2, max_new_tokens=6))
    done = {r.uid: r for r in eng.run()}
    assert eng.stats.prefix_hits == 1
    assert eng.pager.prefix.evictions >= 1
    assert len(done[1].out_tokens) == 6 and len(done[2].out_tokens) == 6
    eng.pager.prefix.drop_all()
    assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1


# ---------------------------------------------------------------------------
# Fused decode-loop conformance (single-dispatch multi-token blocks)
# ---------------------------------------------------------------------------

FUSED_BLOCKS = (2, 3, 8, 32)


def _serve_fused(cfg, params, prompts, *, block, paged=False, slots=2,
                 eos_id=None, mesh=None, dkv=True, max_new=MESH_NEW):
    """All prompts submitted up front with slots < len(prompts): later
    requests are admitted organically as earlier ones finish, so block
    boundaries, folds, and admission rounds all interleave."""
    from repro.engine import DecomposeEngine, EngineConfig
    kw = {}
    if dkv:
        de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                          kv_page=4, decode_block=block,
                                          mesh=mesh))
        kw = dict(decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                  decompose_engine=de, paged=paged)
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN,
                 eos_id=eos_id, **kw, **({} if dkv
                                         else {"decode_block": block}))
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    done = eng.run()
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng


@pytest.mark.parametrize("paged", [False, True])
def test_fused_decode_token_exact(dense_model, paged):
    """THE fused gate: every block length produces byte-identical tokens
    to the single-step engine, across tail-fold boundaries and organic
    staggered admissions (slots < requests), slot AND paged."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    base, e1 = _serve_fused(cfg, params, prompts, block=1, paged=paged)
    assert e1.stats.tail_folds > 0           # folds were crossed
    assert e1.stats.blocks == e1.stats.decode_steps
    for blk in FUSED_BLOCKS:
        got, eb = _serve_fused(cfg, params, prompts, block=blk, paged=paged)
        assert got == base, f"block={blk} diverged: {got} vs {base}"
        assert eb.stats.decode_steps == e1.stats.decode_steps
        assert eb.stats.blocks < e1.stats.blocks, \
            "fused run should launch fewer blocks than rounds"
        assert eb.stats.tail_folds == e1.stats.tail_folds
    if paged:                                # no page leaks under fusion
        assert eb.pager.alloc.free_pages == eb.pager.num_pages - 1
        assert eb.pager.talloc.free_pages == eb.pager.num_tail_pages - 1


@pytest.mark.parametrize("paged", [False, True])
def test_fused_decode_eos_mid_block(dense_model, paged):
    """A stop token sampled mid-block ends the block early ON DEVICE, so
    the request finishes at the same round (and with the same tokens) as
    the single-step engine — no overshoot past EOS."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    probe, _ = _serve_fused(cfg, params, prompts, block=1, paged=paged)
    # pin an eos that the greedy stream REALLY emits, mid-sequence, so
    # both engines must cut that request short at the same position
    eos = probe[0][len(probe[0]) // 2]
    base, e1 = _serve_fused(cfg, params, prompts, block=1, paged=paged,
                            eos_id=eos)
    assert e1.stats.stopped_eos >= 1
    assert len(base[0]) < len(probe[0])      # it actually cut short
    for blk in FUSED_BLOCKS:
        got, eb = _serve_fused(cfg, params, prompts, block=blk, paged=paged,
                               eos_id=eos)
        assert got == base, f"block={blk} with eos diverged"
        assert eb.stats.stopped_eos == e1.stats.stopped_eos
        assert eb.stats.decode_steps == e1.stats.decode_steps


def test_fused_decode_dense_family(dense_model):
    """The dense (non-decomposed) cache path through the fused loop:
    budget horizons only, no folds."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    base, _ = _serve_fused(cfg, params, prompts, block=1, dkv=False)
    for blk in (4, 32):
        got, eb = _serve_fused(cfg, params, prompts, block=blk, dkv=False)
        assert got == base, f"dense block={blk} diverged"
        assert eb.stats.blocks < eb.stats.decode_steps


def test_fused_itl_and_blocks_accounting(dense_model):
    """Satellite: under block decode every emitted token gets one ITL
    sample (wall/steps per token of its block), tokens_out is exact, and
    the blocks counter counts LAUNCHES, not rounds."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    _, eng = _serve_fused(cfg, params, prompts, block=8)
    s = eng.stats
    assert s.blocks < s.decode_steps
    assert len(s.itl_s) == s.tokens_out      # one ITL sample per decode tok
    assert all(dt >= 0 for dt in s.itl_s)
    # each request's first token comes from admission (counted as TTFT),
    # the other max_new − 1 from decode rounds
    assert s.tokens_out == sum(MESH_NEW - 1 for _ in prompts)


_FUSED_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    sys.path.insert(0, os.path.dirname(os.path.abspath(sys.argv[2])))
    from test_serving_conformance import MESH_PROMPT_LENS, _serve_fused
    from repro.configs import all_archs
    from repro.launch.mesh import make_host_mesh
    from repro.models import model_fns

    assert len(jax.devices()) == 8
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32)
               for n in MESH_PROMPT_LENS]
    mesh = make_host_mesh(8, 1)
    out = {}
    for paged in (False, True):
        toks, eng = _serve_fused(cfg, params, prompts, block=4,
                                 paged=paged, slots=8, mesh=mesh)
        key = "paged" if paged else "slot"
        out[key] = {str(u): t for u, t in toks.items()}
        out[key + "_blocks"] = eng.stats.blocks
        out[key + "_steps"] = eng.stats.decode_steps
        if not paged:
            out["ku_nshards"] = len(eng.cache["k_u"].addressable_shards)
    json.dump(out, open(sys.argv[1], "w"))
""")


def test_fused_sharded_byte_identical_to_1_device(dense_model, tmp_path):
    """8-device fused twin: block-4 fused decode on the (8, 1) mesh
    (subprocess) is byte-identical to this process's 1-device SINGLE-STEP
    engine — fusion and sharding compose without perturbing tokens."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    local, _ = _serve_fused(cfg, params, prompts, block=1, slots=8)

    out = tmp_path / "fused_sharded.json"
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    subprocess.run(
        [sys.executable, "-c", _FUSED_SHARDED_SCRIPT, str(out),
         os.path.abspath(__file__)],
        check=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    got = json.load(open(out))
    assert got["ku_nshards"] == 8
    for key in ("slot", "paged"):
        assert {int(k): v for k, v in got[key].items()} == local, \
            f"8-device fused {key} tokens diverged"
        assert got[key + "_blocks"] < got[key + "_steps"]


# ---------------------------------------------------------------------------
# Async prefill/decode conformance (disaggregated admissions, DESIGN.md §12)
# ---------------------------------------------------------------------------


def _serve_async_det(cfg, params, prompts, *, mesh=None, block=1,
                     paged=False, sync=False, slots=MESH_SLOTS, obs=None):
    """Staggered mid-decode arrivals on the async-dispatch engine in
    DETERMINISTIC ready-order (tickets splice at their dispatch round),
    or the synchronous engine when ``sync=True`` — identical schedule,
    so the tokens must be byte-identical."""
    from repro.engine import DecomposeEngine, EngineConfig
    de = DecomposeEngine(EngineConfig(kv_rank=DKV_RANK, kv_tail=DKV_TAIL,
                                      kv_page=4, decode_block=block,
                                      mesh=mesh))
    akw = {} if sync else dict(prefill_async=True,
                               ready_order="deterministic")
    eng = Engine(cfg, params, slots=slots, max_len=MAX_LEN,
                 decompose_kv_rank=DKV_RANK, dkv_tail=DKV_TAIL,
                 decompose_engine=de, paged=paged, obs=obs, **akw)
    done = []
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MESH_NEW))
    arrivals = {3 * i: i for i in range(1, len(prompts))}
    for step in range(200):
        if step in arrivals:
            i = arrivals[step]
            eng.submit(Request(uid=i, prompt=prompts[i],
                               max_new_tokens=MESH_NEW))
        done.extend(eng.step())
        if len(done) == len(prompts) and not any(eng.live):
            break
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("block", [1, 4])
def test_async_det_conformance_1dev(dense_model, paged, block):
    """THE async gate (1 device): asynchronous admission dispatch in
    deterministic ready-order is token-byte-identical to the synchronous
    engine under staggered mid-decode arrivals — slot and paged,
    single-step and fused decode, across tail-fold boundaries."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    base, _ = _serve_async_det(cfg, params, prompts, block=block,
                               paged=paged, sync=True, slots=2)
    det, eng = _serve_async_det(cfg, params, prompts, block=block,
                                paged=paged, slots=2)
    assert eng.stats.tail_folds > 0
    assert det == base, f"async-det diverged (paged={paged}, block={block})"
    if paged:                            # clean drain, every page returned
        assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1
        assert eng.pager.talloc.free_pages == eng.pager.num_tail_pages - 1


_ASYNC_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    sys.path.insert(0, os.path.dirname(os.path.abspath(sys.argv[2])))
    from test_serving_conformance import (MESH_PROMPT_LENS,
                                          _serve_async_det)
    from repro.configs import all_archs
    from repro.launch.mesh import make_host_mesh
    from repro.models import model_fns

    assert len(jax.devices()) == 8
    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32)
               for n in MESH_PROMPT_LENS]
    mesh = make_host_mesh(8, 1)
    out = {}
    for key, block, paged in (("slot_b1", 1, False), ("slot_b4", 4, False),
                              ("paged_b1", 1, True), ("paged_b4", 4, True)):
        toks, eng = _serve_async_det(cfg, params, prompts, mesh=mesh,
                                     block=block, paged=paged)
        out[key] = {str(u): t for u, t in toks.items()}
        if key == "slot_b1":
            out["ku_nshards"] = len(eng.cache["k_u"].addressable_shards)
    json.dump(out, open(sys.argv[1], "w"))
""")


def test_async_sharded_byte_identical_to_sync_1dev(dense_model, tmp_path):
    """8-device async twin (subprocess — device count locks at jax init):
    async dispatch in deterministic ready-order on the (8, 1) mesh is
    byte-identical to this process's 1-device SYNCHRONOUS engine for
    every combination of {slot, paged} × {single-step, fused} decode —
    disaggregation, fusion, and sharding compose without perturbing
    tokens."""
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    local, _ = _serve_async_det(cfg, params, prompts, sync=True)

    out = tmp_path / "async_sharded.json"
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)           # the script forces its own 8
    subprocess.run(
        [sys.executable, "-c", _ASYNC_SHARDED_SCRIPT, str(out),
         os.path.abspath(__file__)],
        check=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    got = json.load(open(out))
    assert got["ku_nshards"] == 8        # slot axis genuinely 8-way DP
    for key in ("slot_b1", "slot_b4", "paged_b1", "paged_b4"):
        assert {int(k): v for k, v in got[key].items()} == local, \
            f"8-device async {key} tokens diverged vs 1-device sync"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI distributed job forces "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8)")
def test_async_sharded_inprocess_8dev(dense_model):
    """In-process twin of the async subprocess gate for the CI
    distributed job: sync-1dev-schedule vs async-det on the (8, 1) mesh
    in ONE process, single-step and fused."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = dense_model
    mesh = make_host_mesh(8, 1)
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    base, _ = _serve_async_det(cfg, params, prompts, sync=True)
    for block in (1, 4):
        got, eng = _serve_async_det(cfg, params, prompts, mesh=mesh,
                                    block=block)
        assert got == base, f"8-device async block={block} diverged"
    assert len(eng.cache["k_u"].addressable_shards) == 8


def test_exact_svd_vs_lanczos_near_full_rank():
    """§2.3: on a KV-like block (decaying spectrum — real K/V rows are
    strongly correlated), direct SVD (exact=True) and Lanczos agree as
    operators at near-full rank, with the exact path never worse
    (floating-point Lanczos loses trailing directions on FLAT spectra,
    which is exactly why the serving knob exists)."""
    from repro.engine import DecomposeEngine, EngineConfig
    eng = DecomposeEngine(EngineConfig())
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    q1, _ = jnp.linalg.qr(jax.random.normal(k1, (4, 24, 24)))
    q2, _ = jnp.linalg.qr(jnp.swapaxes(
        jax.random.normal(k2, (4, 24, 64)), -1, -2))
    s = jnp.power(0.6, jnp.arange(24))
    x = jnp.einsum("btr,r,bhr->bth", q1, s, q2)      # [4, 24, 64]
    nrm = float(jnp.linalg.norm(x))
    for r in (24, 20):                   # full and near-full row rank
        ue, vte = eng.decompose_kv(x, r, exact=True)
        ul, vtl = eng.decompose_kv(x, r)
        rec_e = jnp.einsum("btr,brh->bth", ue, vte)
        rec_l = jnp.einsum("btr,brh->bth", ul, vtl)
        err_e = float(jnp.linalg.norm(rec_e - x)) / nrm
        err_l = float(jnp.linalg.norm(rec_l - x)) / nrm
        assert err_e <= 1e-3             # direct SVD: (near-)exact
        assert err_e <= err_l + 1e-6     # exact never worse than Lanczos
        np.testing.assert_allclose(np.asarray(rec_l), np.asarray(rec_e),
                                   rtol=1e-3, atol=1e-3)
    # a requested rank beyond min(T, kvw) caps at the achievable rank
    uc, _ = eng.decompose_kv(x, 100, exact=True)
    assert uc.shape[-1] == 24


# ---------------------------------------------------------------------------
# Observability neutrality (DESIGN.md §13: zero device ops)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("sync", [True, False])
@pytest.mark.parametrize("block", [1, 4])
def test_observability_is_token_neutral(dense_model, paged, sync, block):
    """THE §13 gate: full observability — metrics registry AND span
    tracing enabled — must produce byte-identical tokens to the default
    (trace-off) engine, for {slot, paged} × {sync, async} × {single-step,
    fused} decode, across tail folds and staggered mid-decode arrivals.
    Instrumentation is purely host-side; if a span or counter ever feeds
    a jit or reorders a device launch, this is the test that catches it.
    """
    from repro.obs import Observability, validate_trace
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    base, _ = _serve_async_det(cfg, params, prompts, block=block,
                               paged=paged, sync=sync)
    obs = Observability(trace=True)
    got, eng = _serve_async_det(cfg, params, prompts, block=block,
                                paged=paged, sync=sync, obs=obs)
    assert got == base, \
        f"observability perturbed tokens (paged={paged}, sync={sync}, " \
        f"block={block})"
    # the instrumented run really recorded: request-lifecycle spans for
    # every request, and engine stats on the obs registry
    spans = validate_trace(obs.tracer.to_json())
    assert spans >= 4 * len(prompts)     # request/queue/prefill/decode each
    names = {ev["name"] for ev in obs.tracer.events}
    expect = {"request", "queue", "prefill", "decode", "step"}
    if not sync:
        expect |= {"splice", "ticket"}
    assert expect <= names, f"missing spans: {expect - names}"
    reg_names = {m.name for m in obs.registry.metrics()}
    assert "serving_tokens_out" in reg_names
    assert eng.stats.registry is obs.registry


def test_engine_stats_memory_bounded(dense_model):
    """Satellite (a): latency series keep O(1) streaming state + a capped
    reservoir — a long-running engine's stats must not grow with every
    token — while ``len(itl_s) == tokens_out`` still holds via the
    histogram counter."""
    from repro.obs.registry import RESERVOIR_CAP
    cfg, params = dense_model
    prompts = _prompts(cfg, lens=MESH_PROMPT_LENS)
    _, eng = _serve_async_det(cfg, params, prompts, block=1, sync=True)
    s = eng.stats
    assert len(s.itl_s) == s.tokens_out
    assert len(s.ttft_s) == len(s.ttft_queue_s) == len(s.ttft_compute_s)
    for series in (s.itl_s, s.ttft_s):
        assert len(series.hist.recent) <= RESERVOIR_CAP
        assert series.hist.count == len(series)
    # the histogram mean is exact (streaming sum/count, not reservoir)
    assert s.mean_itl_s == pytest.approx(s.itl_s.hist.sum
                                         / s.itl_s.hist.count)
    # simulate a long run: observe far past the cap, memory stays bounded
    h = s.itl_s.hist
    before = len(h.recent)
    for i in range(4 * RESERVOIR_CAP):
        s.itl_s.append(1e-3 * (1 + i % 7))
    assert len(h.recent) == RESERVOIR_CAP
    assert len(s.itl_s) == s.tokens_out + 4 * RESERVOIR_CAP
    assert before <= RESERVOIR_CAP

# ---------------------------------------------------------------------------
# Multi-family serving conformance (ServingFamily protocol, DESIGN.md §15)
# ---------------------------------------------------------------------------

FAMILY_ARCHS = ("mamba2-780m", "olmoe-1b-7b", "zamba2-1.2b")
FAM_PROMPT_LENS = (7, 12, 19, 5)
FAM_NEW = 12

_FAM_MODELS = {}


def _family_model(arch):
    """Reduced cfg + params per family arch, cached across tests.

    MoE pins ``capacity_factor=8.0``: expert capacity is
    ``ceil(tokens · top_k · cf / experts)``, which depends on the BATCH
    token count — a capacity-dropped token routes differently between
    the solo and concurrent runs by design, not by bug.  With the cap
    slack the router is batch-size-invariant and token-exactness is a
    real engine invariant."""
    if arch not in _FAM_MODELS:
        from repro.configs import all_archs as _archs
        cfg = _archs()[arch].reduced()
        if cfg.family == "moe":
            cfg = cfg.replace(capacity_factor=8.0)
        params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
        _FAM_MODELS[arch] = (cfg, params)
    return _FAM_MODELS[arch]


def _serve_family(cfg, params, prompts, *, slots=4, block=1, async_=False,
                  mesh=None, stagger=True, max_new=FAM_NEW):
    """Serve a non-transformer-dkv family on the generic engine:
    staggered mid-decode arrivals (or all-up-front), optional fused
    decode blocks, optional async admission in deterministic order, and
    an optional DP mesh (threaded through the engine config with
    ``decompose_kv_rank=0`` so the family cache path stays on)."""
    kw = {}
    if mesh is not None:
        from repro.engine import DecomposeEngine, EngineConfig
        kw.update(decompose_engine=DecomposeEngine(EngineConfig(mesh=mesh)),
                  decompose_kv_rank=0)
    if block > 1:
        kw["decode_block"] = block
    if async_:
        kw.update(prefill_async=True, ready_order="deterministic")
    eng = Engine(cfg, params, slots=slots, max_len=96, **kw)
    done = []
    if not stagger:
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
        done = eng.run()
    else:
        eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=max_new))
        arrivals = {3 * i: i for i in range(1, len(prompts))}
        for step in range(300):
            if step in arrivals:
                i = arrivals[step]
                eng.submit(Request(uid=i, prompt=prompts[i],
                                   max_new_tokens=max_new))
            done.extend(eng.step())
            if len(done) == len(prompts) and not any(eng.live):
                break
    assert sorted(r.uid for r in done) == list(range(len(prompts)))
    return {r.uid: r.out_tokens for r in done}, eng


def _solo_family(cfg, params, prompts, max_new=FAM_NEW):
    """Reference: each request alone on a fresh single-slot engine — no
    batching, no splice, no shared state."""
    out = {}
    for i, p in enumerate(prompts):
        toks, _ = _serve_family(cfg, params, [p], slots=1, stagger=False,
                                max_new=max_new)
        out[i] = toks[0]
    return out


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_staggered_matches_solo(arch):
    """THE multi-family gate: Mamba2 / MoE / hybrid traffic served with
    staggered mid-decode admissions on the generic slot engine produces
    greedy tokens token-EXACT vs each request decoded alone — admission
    splices (conv/ssm state rows, KV rows, router state) never perturb
    a live or later sequence."""
    cfg, params = _family_model(arch)
    prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
    solo = _solo_family(cfg, params, prompts)
    got, eng = _serve_family(cfg, params, prompts)
    assert eng.stats.prefill_batches >= 2    # admissions landed while live
    for uid in solo:
        assert got[uid] == solo[uid], \
            f"{arch} req {uid} diverged: {got[uid]} vs {solo[uid]}"


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_fused_block_matches_single_step(arch):
    """Fused decode blocks are pure execution strategy for EVERY family:
    block-4 serving is byte-identical to single-step, with fewer
    launches covering the same rounds."""
    cfg, params = _family_model(arch)
    prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
    base, e1 = _serve_family(cfg, params, prompts, block=1)
    got, eb = _serve_family(cfg, params, prompts, block=4)
    assert got == base, f"{arch} fused diverged"
    assert eb.stats.blocks < e1.stats.blocks
    assert eb.stats.tokens_out == e1.stats.tokens_out


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_family_async_det_matches_sync(arch):
    """Async admission dispatch (deterministic ready-order) composes
    with every family: byte-identical to the synchronous engine under
    the same staggered schedule."""
    cfg, params = _family_model(arch)
    prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
    base, _ = _serve_family(cfg, params, prompts)
    got, eng = _serve_family(cfg, params, prompts, async_=True)
    assert got == base, f"{arch} async-det diverged"
    assert not eng._pool and not eng._reserved.any()


def test_family_fused_async_compose():
    """Fusion AND async admission together on non-transformer families —
    the full feature matrix holds off the dkv path too."""
    for arch in ("mamba2-780m", "olmoe-1b-7b"):
        cfg, params = _family_model(arch)
        prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
        base, _ = _serve_family(cfg, params, prompts)
        got, _ = _serve_family(cfg, params, prompts, block=4, async_=True)
        assert got == base, f"{arch} fused+async diverged"


_FAMILY_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    sys.path.insert(0, os.path.dirname(os.path.abspath(sys.argv[2])))
    from test_serving_conformance import (FAM_PROMPT_LENS, _family_model,
                                          _serve_family)
    from repro.launch.mesh import make_host_mesh

    assert len(jax.devices()) == 8
    cfg, params = _family_model("mamba2-780m")
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, n, dtype=np.int32)
               for n in FAM_PROMPT_LENS]
    mesh = make_host_mesh(8, 1)
    toks, eng = _serve_family(cfg, params, prompts, slots=8, mesh=mesh)
    conv = eng.cache["conv"]
    json.dump({"tokens": {str(u): t for u, t in toks.items()},
               "conv_nshards": len(conv.addressable_shards),
               "conv_spec": str(conv.sharding.spec)},
              open(sys.argv[1], "w"))
""")


def test_family_sharded_byte_identical_to_1_device(tmp_path):
    """8-device non-transformer twin (subprocess — device count locks at
    jax init): Mamba2 serving with the conv/ssm state DP-sharded over
    the slot axis on an (8, 1) mesh is byte-identical to this process's
    1-device engine on the same staggered schedule."""
    cfg, params = _family_model("mamba2-780m")
    prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
    local, _ = _serve_family(cfg, params, prompts, slots=8)

    out = tmp_path / "family_sharded.json"
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)           # the script forces its own 8
    subprocess.run(
        [sys.executable, "-c", _FAMILY_SHARDED_SCRIPT, str(out),
         os.path.abspath(__file__)],
        check=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    got = json.load(open(out))
    assert got["conv_nshards"] == 8      # slot axis genuinely 8-way DP
    assert "data" in got["conv_spec"]
    assert {int(k): v for k, v in got["tokens"].items()} == local, \
        f"sharded mamba2 tokens diverged: {got['tokens']} vs {local}"


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (CI distributed job forces "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8)")
def test_family_sharded_inprocess_8dev():
    """In-process twin of the mamba2 subprocess gate for the CI
    distributed job: sharded vs unsharded family engines in ONE
    process."""
    from repro.launch.mesh import make_host_mesh
    cfg, params = _family_model("mamba2-780m")
    mesh = make_host_mesh(8, 1)
    prompts = _prompts(cfg, lens=FAM_PROMPT_LENS)
    a, _ = _serve_family(cfg, params, prompts, slots=8)
    b, eng = _serve_family(cfg, params, prompts, slots=8, mesh=mesh)
    assert a == b
    assert len(eng.cache["conv"].addressable_shards) == 8
