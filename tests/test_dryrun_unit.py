"""Dry-run machinery units: HLO collective parsing, roofline math,
probe plans (the full sweep runs via launch.dryrun --all).

Also guards the 1-device invariant: no test may import launch.dryrun."""

import jax
import pytest

from repro.configs import all_archs, cells
# import from the side-effect-free helper module (launch.dryrun sets
# XLA_FLAGS at import — the 512-device forcing must never leak into pytest)
from repro.launch.roofline import (_RING_FACTOR, _shape_bytes,
                                   collective_stats, probe_plan,
                                   roofline_terms)


HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar.1 = f32[256,128]{1,0} all-reduce(%x), to_apply=%add
  %ars = f32[8]{0} all-reduce-start(%y), to_apply=%add
  %ard = f32[8]{0} all-reduce-done(%ars)
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[4]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%l, %r)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert _shape_bytes("(f32[64], f32[64])") == 2 * 64 * 4
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_parses_types_and_starts():
    st = collective_stats(HLO)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 1024 * 2
    # all-reduce: plain + -start variant; -done NOT double counted
    assert st["all-reduce"]["count"] == 2
    assert st["all-reduce"]["bytes"] == 256 * 128 * 4 + 8 * 4
    assert st["reduce-scatter"]["bytes"] == 2 * 64 * 4
    assert st["collective-permute"]["count"] == 1


def test_roofline_terms_math():
    coll = {k: {"bytes": 0, "count": 0} for k in _RING_FACTOR}
    coll["all-reduce"]["bytes"] = 50e9       # 1 s at 2x ring factor -> 2 s
    t = roofline_terms(197e12, 819e9, coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(2.0)


def test_probe_plans_cover_all_archs():
    for name, cfg in all_archs().items():
        if name == "llama2-7b":
            continue
        plan, n_full = probe_plan(cfg)
        (p1, n1), (p2, n2) = plan
        assert n2 > n1 >= 1
        assert n_full >= n2
        assert p1.num_layers < cfg.num_layers
        # probe configs must still be structurally valid
        if cfg.family == "vlm":
            assert p1.num_layers % p1.cross_attn_period == 0
        if cfg.family == "audio":
            assert p1.enc_layers >= 1 and p1.dec_layers >= 1


def test_cells_assignment():
    """40 cells total: long_500k only for sub-quadratic archs."""
    total = 0
    for name, cfg in all_archs().items():
        if name == "llama2-7b":
            continue
        cs = cells(cfg)
        total += len(cs)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in cs
        else:
            assert "long_500k" not in cs
    assert total == 8 * 3 + 2 * 4 == 32   # 40 assigned cells − 8 documented long_500k skips


def test_pytest_process_sees_one_device():
    """launch.dryrun's XLA_FLAGS side effect must never leak into tests."""
    assert len(jax.devices()) == 1
