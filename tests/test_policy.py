"""DecompositionPolicy + paper Table-2 configurations."""
from repro.core.policy import (PAPER_BEST_CONFIG, PAPER_LAYER_CONFIGS,
                               DecompositionPolicy, LayerPolicy)


def test_paper_configs_shapes():
    assert len(PAPER_LAYER_CONFIGS["4layer"]) == 4
    assert len(PAPER_LAYER_CONFIGS["10layer"]) == 10
    assert PAPER_BEST_CONFIG == ("10layer", 20)


def test_from_layer_list():
    pol = DecompositionPolicy.from_layer_list(
        32, PAPER_LAYER_CONFIGS["4layer"], rank=20)
    assert pol.decomposed_layers() == [10, 15, 20, 25]
    assert pol.layer(10).rank == 20
    assert not pol.layer(11).decompose
    assert not pol.has_adjacent_decomposed()


def test_adjacency_detection():
    pol = DecompositionPolicy.from_layer_list(
        32, PAPER_LAYER_CONFIGS["10layer"])
    assert pol.has_adjacent_decomposed()   # [9,10,...] are adjacent


def test_all_layers():
    pol = DecompositionPolicy.all_layers(32, rank=1)
    assert len(pol.decomposed_layers()) == 32


def test_json_roundtrip():
    pol = DecompositionPolicy.from_layer_list(32, [1, 5], rank=10,
                                              decompose_weights=True)
    pol.thresholds.set(1, 3.5)
    s = pol.to_json()
    pol2 = DecompositionPolicy.from_json(s)
    assert pol2.decomposed_layers() == [1, 5]
    assert pol2.layer(1).decompose_weights
    assert pol2.thresholds.get(1) == 3.5


def test_effective_iters():
    assert LayerPolicy(rank=7).effective_iters == 7
    assert LayerPolicy(rank=7, iters=12).effective_iters == 12
