"""repro.tune: spaces, cost model, cache, tuner, engine auto-resolution.

Acceptance checks for the autotuning subsystem (ISSUE 3):
* every expansion kernel registers a tunable space whose defaults match
  the historical hard-codes (expansion=8, row_block/n_block=512);
* the roofline cost model reproduces the paper's f* = 8 on the Fig. 12
  shape under the v5e device model;
* the persistent cache round-trips through disk, survives corruption,
  and makes tuning deterministic;
* ``EngineConfig(expansion="auto")`` resolves through repro.tune on every
  backend and produces BIT-IDENTICAL decompositions vs the same engine
  with the resolved f pinned.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.engine import DecomposeEngine, EngineConfig, available_backends


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    """Point the default cache at a fresh file; the tuner's in-process lru
    is keyed on the cache path, so each test resolves from scratch."""
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return path


# ---------------------------------------------------------------------------
# Tunable spaces
# ---------------------------------------------------------------------------

def test_every_expansion_kernel_registers_a_space():
    assert set(tune.available_spaces()) >= {
        "lanczos_reorth", "matvec_expand", "lowrank_matmul",
        "dkv_attention"}


def test_space_defaults_match_historical_hardcodes():
    assert tune.get_space("lanczos_reorth").default()["expansion"] == 8
    mv = tune.get_space("matvec_expand").default()
    assert (mv["expansion"], mv["row_block"]) == (8, 512)
    lm = tune.get_space("lowrank_matmul").default()
    assert (lm["expansion"], lm["n_block"]) == (8, 512)
    assert tune.get_space("dkv_attention").default()["expansion"] == 8


def test_space_candidates_deterministic_and_complete():
    space = tune.get_space("matvec_expand")
    c1, c2 = list(space.candidates()), list(space.candidates())
    assert c1 == c2 and len(c1) == space.size()
    assert space.default() in c1
    with pytest.raises(KeyError):
        tune.get_space("no-such-kernel")


def test_candidates_for_pins_and_filters():
    cands = tune.candidates_for("lanczos_reorth",
                                fix={"backend": "pallas_interpret"})
    assert cands and all(c["backend"] == "pallas_interpret" for c in cands)
    # the compiled Mosaic backend is infeasible off-TPU and must be dropped
    if jax.default_backend() != "tpu":
        assert not any(c["backend"] == "pallas"
                       for c in tune.candidates_for("lanczos_reorth"))


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_cost_model_reproduces_paper_fstar_on_v5e():
    """Fig. 12 shape (batch 64, S = H = 4096, rank 10) under the v5e
    roofline: the model's argmin over the grid is the paper's f* = 8."""
    grid = tune.get_space("lanczos_reorth").param("expansion").choices
    ts = {f: tune.predict("lanczos_reorth", (64, 4096, 4096, 10),
                          "bfloat16", {"expansion": f}, tune.V5E)
          for f in grid}
    assert min(ts, key=ts.get) == 8
    assert ts[1] > ts[8]                 # expansion must actually pay


def test_cost_model_penalizes_interpret_overhead():
    """On the interpret-mode CPU device the per-grid-step cost dominates,
    so large f must never look cheaper than small f."""
    t1 = tune.predict("lanczos_reorth", (2, 64, 128), "float32",
                      {"expansion": 1}, tune.CPU_INTERPRET)
    t32 = tune.predict("lanczos_reorth", (2, 64, 128), "float32",
                       {"expansion": 32}, tune.CPU_INTERPRET)
    assert t32 > t1


def test_cost_model_unknown_kernel_raises():
    with pytest.raises(KeyError):
        tune.predict("nope", (2, 2), "float32", {"expansion": 1}, tune.V5E)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def test_cache_round_trip(tmp_cache):
    c = tune.TuningCache(tmp_cache)
    c.put("k1", {"best": {"expansion": 4}, "measured_s": 1e-3})
    c.save()
    c2 = tune.TuningCache(tmp_cache)
    assert c2.get("k1") == {"best": {"expansion": 4}, "measured_s": 1e-3}
    assert len(c2) == 1 and list(c2.keys()) == ["k1"]


def test_cache_merge_save_preserves_other_writers(tmp_cache):
    a, b = tune.TuningCache(tmp_cache), tune.TuningCache(tmp_cache)
    a.put("ka", {"v": 1})
    a.save()
    b.put("kb", {"v": 2})
    b.save()                              # must not clobber ka
    c = tune.TuningCache(tmp_cache)
    assert c.get("ka") == {"v": 1} and c.get("kb") == {"v": 2}


def test_cache_corrupt_file_is_empty_not_fatal(tmp_cache):
    with open(tmp_cache, "w") as fh:
        fh.write("{not json")
    c = tune.TuningCache(tmp_cache)
    assert c.get("anything") is None
    c.put("k", {"v": 1})
    c.save()                              # overwrites the corrupt file
    assert json.load(open(tmp_cache))["entries"]["k"] == {"v": 1}


def test_shape_bucketing():
    assert tune.shape_bucket((3, 33, 48)) == (4, 64, 64)
    assert tune.shape_bucket((1, 64)) == (1, 64)
    k1 = tune.entry_key("dev", "kern", (3, 33, 48), "float32")
    k2 = tune.entry_key("dev", "kern", (4, 50, 64), "float32")
    assert k1 == k2                       # same bucket, one entry


# ---------------------------------------------------------------------------
# Tuner
# ---------------------------------------------------------------------------

def test_tune_model_mode_is_deterministic(tmp_cache):
    kw = dict(fix={"backend": "pallas_interpret"})
    r1 = tune.tune("lanczos_reorth", (2, 48, 96), **kw)
    r2 = tune.tune("lanczos_reorth", (2, 48, 96), **kw)
    assert r1.best == r2.best
    assert r1.source == "model" and r2.source == "cache"   # in-proc hit
    r3 = tune.tune("lanczos_reorth", (2, 48, 96), force=True, **kw)
    assert r3.best == r1.best             # pure cost model: same answer


def test_tune_measured_persists_and_hits_cache(tmp_cache):
    kw = dict(shape=(16, 32), dtype="float32", fix={"row_block": 128},
              prune=2, reps=1)
    r1 = tune.tune("matvec_expand", measure_candidates=True, **kw)
    assert r1.source == "measured" and r1.measured_s > 0
    assert any(m is not None for _, _, m in r1.table)
    # a fresh process would read the persisted entry: simulate via a new
    # cache object over the same file
    c2 = tune.TuningCache(tmp_cache)
    r2 = tune.tune("matvec_expand", measure_candidates=True, cache=c2, **kw)
    assert r2.source == "cache" and r2.best == r1.best


def test_tuned_expansion_is_cached_in_process(tmp_cache):
    f1 = tune.tuned_expansion((2, 48, 96), backend="pallas_interpret")
    f2 = tune.tuned_expansion((2, 50, 100), backend="pallas_interpret")
    assert isinstance(f1, int) and f1 >= 1
    assert f1 == f2                       # same shape bucket → same answer


def test_resolve_backend_platform_heuristic_and_override(tmp_cache):
    name = tune.resolve_backend()
    assert name in available_backends()
    if jax.default_backend() != "tpu":
        assert name == "reference"
    # a measured cache override wins
    c = tune.default_cache()
    c.put(f"{tune.device_kind()}/engine_backend",
          {"best": {"backend": "pallas_vmap"}})
    assert tune.resolve_backend() == "pallas_vmap"
    c.put(f"{tune.device_kind()}/engine_backend",
          {"best": {"backend": "not-a-backend"}})
    assert tune.resolve_backend() in available_backends()   # ignored


def test_pretune_warms_cache(tmp_cache):
    out = tune.pretune({"lanczos_reorth": [(2, 48, 96)],
                        "dkv_attention": [(4, 96, 16)]})
    assert len(out) == 2
    for res in out.values():
        assert "expansion" in res.best


# ---------------------------------------------------------------------------
# Engine resolution: expansion="auto" / backend="auto"
# ---------------------------------------------------------------------------

def test_engine_config_rejects_bad_expansion():
    with pytest.raises(ValueError):
        EngineConfig(expansion="turbo")
    with pytest.raises(ValueError):
        EngineConfig(expansion=0)
    assert EngineConfig(expansion="auto").expansion == "auto"


@pytest.mark.parametrize("backend", ["reference", "pallas_interpret",
                                     "pallas_vmap", "pallas"])
def test_auto_expansion_resolves_on_every_backend(tmp_cache, backend):
    """expansion="auto" must resolve through repro.tune to a concrete f on
    ALL FOUR backends (construction + resolution; execution is covered
    below for the backends this container can run)."""
    eng = DecomposeEngine(EngineConfig(backend=backend, expansion="auto"))
    f = eng.resolve_expansion(33, 48, batch=2)
    assert isinstance(f, int) and f >= 1
    assert repr(eng).count("expansion=auto") == 1


@pytest.mark.parametrize("backend", ["reference", "pallas_interpret",
                                     "pallas_vmap"])
def test_auto_expansion_bit_identical_to_fixed_f(tmp_cache, backend):
    """The acceptance property: an auto-tuned engine's decomposition is
    BIT-identical to the same engine with the resolved f pinned."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 33, 48), jnp.float32)
    auto = DecomposeEngine(EngineConfig(backend=backend, expansion="auto"))
    f = auto.resolve_expansion(33, 48, batch=2)
    fixed = DecomposeEngine(EngineConfig(backend=backend, expansion=f))
    lr_a, lr_f = auto.decompose(x, 5), fixed.decompose(x, 5)
    np.testing.assert_array_equal(np.asarray(lr_a.u), np.asarray(lr_f.u))
    np.testing.assert_array_equal(np.asarray(lr_a.core),
                                  np.asarray(lr_f.core))
    np.testing.assert_array_equal(np.asarray(lr_a.vt), np.asarray(lr_f.vt))
    # and the KV factorization path rides the same resolution
    u_a, vt_a = auto.decompose_kv(x, 4)
    u_f, vt_f = fixed.decompose_kv(x, 4)
    np.testing.assert_array_equal(np.asarray(u_a), np.asarray(u_f))
    np.testing.assert_array_equal(np.asarray(vt_a), np.asarray(vt_f))


def test_auto_backend_engine_builds_and_runs(tmp_cache):
    eng = DecomposeEngine(EngineConfig(backend="auto"))
    assert eng.resolved_backend in available_backends()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 24))
    lr = eng.decompose(x, 3)
    assert lr.u.shape == (1, 16, 3)


def test_serving_engine_accepts_auto_config(tmp_cache):
    """The serving path jit-keys on the engine knobs; "auto" must thread
    through prefill decomposition end to end."""
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.serving import Engine, Request

    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, slots=2, max_len=64,
                 decompose_engine=DecomposeEngine(EngineConfig(
                     backend="auto", expansion="auto", kv_rank=6,
                     kv_tail=4)))
    rng = np.random.RandomState(0)
    eng.submit(Request(uid=0, prompt=rng.randint(0, cfg.vocab, 8,
                                                 dtype=np.int32),
                       max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) >= 4
