"""Fault-tolerant driver: checkpoint/restart, failure injection, watchdog."""

import numpy as np

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.runtime.driver import (SimulatedFailure, StragglerWatchdog,
                                  train_loop)

CFG = all_archs()["llama2-7b"].reduced().replace(name="rt-test")
SHAPE = ShapeSpec("t", 16, 4, "train")


def test_train_loop_runs_and_checkpoints(tmp_path):
    res = train_loop(CFG, SHAPE, total_steps=12, ckpt_dir=str(tmp_path),
                     ckpt_every=5, print_fn=lambda s: None)
    assert res.step == 12
    assert len(res.losses) == 12
    assert np.isfinite(res.losses).all()


def test_failure_injection_restart_resumes(tmp_path):
    """Crash at step 8 → driver restores step-4 checkpoint and completes."""
    crashed = {"done": False}

    def hook(step):
        if step == 8 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFailure("injected node failure")

    res = train_loop(CFG, SHAPE, total_steps=12, ckpt_dir=str(tmp_path),
                     ckpt_every=5, failure_hook=hook,
                     print_fn=lambda s: None)
    assert res.restarts == 1
    assert res.step == 12


def test_resume_is_deterministic(tmp_path):
    """Loss stream after restart matches an uninterrupted run."""
    r_plain = train_loop(CFG, SHAPE, total_steps=10,
                         ckpt_dir=str(tmp_path / "plain"), ckpt_every=4,
                         print_fn=lambda s: None)
    crashed = {"done": False}

    def hook(step):
        if step == 6 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFailure("boom")

    r_crash = train_loop(CFG, SHAPE, total_steps=10,
                         ckpt_dir=str(tmp_path / "crash"), ckpt_every=4,
                         failure_hook=hook, print_fn=lambda s: None)
    # steps 8..9 (after the last common checkpoint) must agree
    np.testing.assert_allclose(r_plain.losses[-2:], r_crash.losses[-2:],
                               rtol=1e-4)


def test_straggler_watchdog():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0)
    for _ in range(5):
        assert not wd.observe(0.1)
    assert wd.observe(1.0)         # 10x slower -> flagged
    assert wd.flagged == 1


ELASTIC_TRAIN_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding as sh
from repro.optim import make_optimizer
from repro.runtime import steps as steps_mod
from repro.runtime.driver import restore_for_mesh
from repro import checkpoint as ckpt

cfg = all_archs()["deepseek-7b"].reduced().replace(name="elastic-e2e")
shape = ShapeSpec("t", 16, 8, "train")
opt = make_optimizer(cfg)
src = SyntheticLM(cfg, shape, DataConfig(seed=0))

def run_steps(params, opt_state, mesh, start, n):
    pshd = sh.params_sharding(jax.eval_shape(lambda: params), mesh, cfg)
    step = jax.jit(steps_mod.make_train_step(cfg, opt))
    losses = []
    with mesh:
        for i in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
    return params, opt_state, losses

# phase 1: train 6 steps on a 2x4 mesh, checkpoint
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
params, opt_state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0),
                                               opt)
params, opt_state, la = run_steps(params, opt_state, mesh_a, 0, 6)
ckpt.save({"params": params, "opt": opt_state}, "%s", 5)

# phase 2: ELASTIC restore onto a 4x2 mesh, continue 3 steps
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
state = restore_for_mesh(cfg, "%s", mesh_b, optimizer=opt)
p2, o2, lb = run_steps(state["params"], state["opt"], mesh_b, 6, 3)

# reference: uninterrupted on mesh_a
p3, o3, lc = run_steps(params, opt_state, mesh_a, 6, 3)
np.testing.assert_allclose(lb, lc, rtol=2e-2)
print("ELASTIC_E2E_OK", lb)
"""


def test_elastic_remesh_end_to_end(tmp_path):
    """Train on mesh A -> checkpoint -> restore re-sharded on mesh B ->
    the continued loss stream matches the uninterrupted run."""
    import os
    import subprocess
    import sys
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    script = ELASTIC_TRAIN_SCRIPT % (str(tmp_path), str(tmp_path))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "ELASTIC_E2E_OK" in out.stdout, out.stderr[-2500:]
