"""Async prefill/decode disaggregation (DESIGN.md §12).

Covers the P/D split's contract surface: deterministic ready-order mode
is token-byte-identical to the synchronous engine (slot AND paged,
single-step AND fused decode), ready mode overlaps prefill with decode
and conserves slots/pages, the admission deferral path conserves page
refs across defer/retry cycles, capacity stalls surface instead of
livelocking run(), the TTFT queue/compute split is consistent, and
cancellation unwinds in-flight tickets completely.
"""
import jax
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import api, model_fns
from repro.serving import Engine, Request, Scheduler

_MODEL = {}


def _model():
    if not _MODEL:
        cfg = all_archs()["llama2-7b"].reduced()
        _MODEL["cfg"] = cfg
        _MODEL["params"] = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return _MODEL["cfg"], _MODEL["params"]


def _run(prompts, max_new=6, *, slots=2, max_len=64, seed_reqs=None, **kw):
    cfg, params = _model()
    eng = Engine(cfg, params, slots=slots, max_len=max_len, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: list(r.out_tokens) for r in done}, eng


def _prompts(n=4, seed=0):
    cfg, _ = _model()
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, int(ln), dtype=np.int32)
            for ln in (12, 7, 15, 9, 5, 13)[:n]]


# -- deterministic mode: byte-identity with the synchronous engine -------

@pytest.mark.parametrize("block", [1, 4])
def test_async_deterministic_matches_sync_slot(block):
    """Slot engine (dense + dkv slab): deterministic ready-order drives
    the sync schedule through the ticket machinery — tokens byte-equal."""
    for kw in ({}, dict(decompose_kv_rank=8, dkv_tail=4)):
        base, _ = _run(_prompts(), decode_block=block, **kw)
        det, eng = _run(_prompts(), decode_block=block, prefill_async=True,
                        ready_order="deterministic", **kw)
        assert det == base, f"kw={kw} block={block}"
        assert eng.prefill_async


@pytest.mark.parametrize("block", [1, 4])
def test_async_deterministic_matches_sync_paged(block):
    kw = dict(decompose_kv_rank=8, dkv_tail=4, paged=True)
    base, _ = _run(_prompts(), decode_block=block, **kw)
    det, eng = _run(_prompts(), decode_block=block, prefill_async=True,
                    ready_order="deterministic", **kw)
    assert det == base, f"block={block}"
    # clean drain: every page back but the sink
    pg = eng.pager
    assert pg.alloc.free_pages == pg.num_pages - 1
    assert pg.talloc.free_pages == pg.num_tail_pages - 1


def test_async_ready_dense_matches_sync():
    """Ready mode on the DENSE family (no folds, greedy sampling) with
    one-at-a-time arrivals: batch composition matches the sync engine,
    so the tokens do too — exactness isn't only a det-mode property."""
    base, _ = _run(_prompts(2), slots=4)
    rdy, eng = _run(_prompts(2), slots=4, prefill_async=True,
                    ready_order="ready")
    assert rdy == base
    assert eng.stats.prefill_inflight_peak >= 1


# -- ready mode: overlap + conservation ----------------------------------

def test_async_ready_overlaps_and_conserves():
    """Ready mode completes everything, leaks nothing, and actually held
    in-flight tickets (the pool was exercised, not bypassed)."""
    toks, eng = _run(_prompts(6), max_new=5, slots=2,
                     decompose_kv_rank=8, dkv_tail=4, paged=True,
                     prefill_async=True, ready_order="ready")
    assert all(len(v) >= 1 for v in toks.values())
    assert eng.stats.prefill_inflight_peak >= 1
    assert not eng._pool and not eng._reserved.any()
    pg = eng.pager
    assert pg.alloc.free_pages == pg.num_pages - 1
    assert pg.talloc.free_pages == pg.num_tail_pages - 1
    # dispatch log covers every request exactly once, FIFO per bucket
    assert sorted(eng.admit_log) == list(range(6))


# -- S1: deferral conserves page refs across defer/retry cycles ----------

def test_defer_retry_conserves_page_refs():
    """A batch deferred by _reserve_pages releases its prefix-hit refs
    (taken in _lookup_prefixes) exactly once per retry round; after the
    engine drains, every page ref traces back to a prefix entry and
    dropping those returns the whole pool.  Hit/miss stats count once
    per ADMITTED request, not once per retry probe."""
    cfg, params = _model()
    rng = np.random.RandomState(7)
    a = rng.randint(0, cfg.vocab, 14, dtype=np.int32)
    b = rng.randint(0, cfg.vocab, 14, dtype=np.int32)
    c = a.copy()
    c[-2:] = (c[-2:] + 1) % cfg.vocab      # shares A's page-aligned prefix
    d = rng.randint(0, cfg.vocab, 14, dtype=np.int32)
    from repro.engine import DecomposeEngine, EngineConfig
    deng = DecomposeEngine(EngineConfig(
        kv_rank=8, kv_tail=8, kv_page=4, kv_pool_pages=9,
        kv_prefix_cache=8))
    eng = Engine(cfg, params, slots=4, max_len=32, decompose_engine=deng,
                 paged=True)
    eng.submit(Request(uid=0, prompt=a, max_new_tokens=7))
    eng.submit(Request(uid=1, prompt=b, max_new_tokens=5))
    eng.step()                              # A, B admitted: pool is full
    eng.submit(Request(uid=2, prompt=c, max_new_tokens=3))
    eng.submit(Request(uid=3, prompt=d, max_new_tokens=3))
    done = eng.run()
    assert len(done) == 4
    assert eng.stats.stalls >= 1            # [C, D] deferred at least once
    # counted once per admitted request despite the retry lookups
    assert eng.stats.prefix_hits + eng.stats.prefix_misses == 4
    assert eng.stats.prefills == 4
    pg = eng.pager
    assert pg.prefix.hits + pg.prefix.misses == 4
    pg.prefix.drop_all()
    assert pg.alloc.free_pages == pg.num_pages - 1
    assert pg.alloc.live_refs == {}
    assert pg.talloc.free_pages == pg.num_tail_pages - 1


def test_requeue_preserves_arrival_order():
    """Scheduler.requeue merges a deferred batch back by arrival stamp —
    the old front-insertion reordered cross-bucket pulls."""
    sched = Scheduler(bucket=16)
    reqs = [Request(uid=i, prompt=np.zeros(ln, np.int32), max_new_tokens=1)
            for i, ln in enumerate((4, 20, 4, 20))]
    for r in reqs:
        sched.submit(r)
    # 3 free slots: the bucket-16 pair rides along, one slot stays
    # reserved for the older bucket-32 request (fairness rule)
    batch = sched.next_batch(3)
    assert [r.uid for r in batch] == [0, 2]
    sched.requeue(batch)
    assert [r.uid for r in sched._q] == [0, 1, 2, 3]


# -- S2: capacity stall surfaces instead of livelocking -----------------

def test_permanent_capacity_stall_raises():
    """A request whose page demand can NEVER be satisfied (empty engine,
    nothing in flight) raises instead of spinning run() to max_steps and
    silently dropping the request."""
    cfg, params = _model()
    from repro.engine import DecomposeEngine, EngineConfig
    deng = DecomposeEngine(EngineConfig(
        kv_rank=8, kv_tail=8, kv_page=4, kv_pool_pages=3))
    eng = Engine(cfg, params, slots=2, max_len=32, decompose_engine=deng,
                 paged=True)
    eng.submit(Request(uid=0, prompt=np.arange(14, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(RuntimeError, match="page capacity"):
        eng.run()
    assert eng.stats.stalls >= 1


# -- S3: TTFT queue/compute split ---------------------------------------

def test_ttft_split_consistent():
    for kw in ({}, dict(prefill_async=True, ready_order="ready")):
        _, eng = _run(_prompts(4), slots=2, **kw)
        s = eng.stats
        assert len(s.ttft_queue_s) == len(s.ttft_compute_s) == len(s.ttft_s)
        for q, c, t in zip(s.ttft_queue_s, s.ttft_compute_s, s.ttft_s):
            assert q >= 0.0 and c >= 0.0
            assert abs((q + c) - t) < 1e-6  # split sums to the total
        # queued-behind-full-slots requests must show real queue wait
        assert max(s.ttft_queue_s) > 0.0


def test_next_batch_head_bucket_fairness():
    """An older other-bucket request is not starved by younger same-bucket
    ride-alongs: with 2 free slots and arrivals [16a, 32b, 16c, 16d],
    the head batch takes [16a, 16c] and leaves a slot count for 32b —
    it must NOT take 16d past b's claim."""
    sched = Scheduler(bucket=16)
    for i, ln in enumerate((4, 20, 4, 4)):
        sched.submit(Request(uid=i, prompt=np.zeros(ln, np.int32),
                             max_new_tokens=1))
    batch = sched.next_batch(3)
    assert [r.uid for r in batch] == [0, 2]  # slot 3 reserved for uid=1
    batch2 = sched.next_batch(1)
    assert [r.uid for r in batch2] == [1]


# -- cancellation + the api-level probe ---------------------------------

def test_cancel_pending_unwinds_tickets():
    cfg, params = _model()
    from repro.engine import DecomposeEngine, EngineConfig
    deng = DecomposeEngine(EngineConfig(kv_rank=8, kv_tail=4, kv_page=4,
                                        kv_prefix_cache=4))
    eng = Engine(cfg, params, slots=2, max_len=48, decompose_engine=deng,
                 paged=True, prefill_async=True, ready_order="ready")
    for i, p in enumerate(_prompts(2, seed=3)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    eng._admit()                            # dispatch only (ready mode)
    assert eng._pool and eng._reserved.any()
    n = eng.cancel_pending()
    assert n == 2
    assert not eng._pool and not eng._reserved.any()
    assert [r.uid for r in eng.sched._q] == [0, 1]   # arrival order
    assert eng.stats.prefills == 0 and eng.admit_log == []
    pg = eng.pager
    pg.prefix.drop_all()
    assert pg.alloc.free_pages == pg.num_pages - 1
    assert pg.talloc.free_pages == pg.num_tail_pages - 1
    # the requeued requests still complete on a fresh run
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1]


def test_tree_ready_and_splice_on_ready():
    cfg, _ = _model()
    assert api.tree_ready({"a": np.zeros(3), "b": 1.0})
    x = jax.numpy.ones((2, 2))
    jax.block_until_ready(x)
    assert api.tree_ready([x])
    old = {"k": jax.numpy.zeros((4, 8)), "v": jax.numpy.zeros((4, 8))}
    new = {"k": jax.numpy.ones((2, 8)), "v": jax.numpy.ones((2, 8))}
    axes = {"k": 0, "v": 0}
    import repro.models.api as A
    orig = A.cache_batch_axes
    A.cache_batch_axes = lambda _cfg: axes
    try:
        out = api.splice_on_ready(cfg, old, new, [1, 3])
    finally:
        A.cache_batch_axes = orig
    assert out is not None               # ready arrays splice immediately
    np.testing.assert_array_equal(np.asarray(out["k"][1]), np.ones(8))
    np.testing.assert_array_equal(np.asarray(out["k"][0]), np.zeros(8))
