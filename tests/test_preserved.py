"""Decomposition-preserved computation (paper Eq. 4-12) equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (activation_compression_ratio,
                        attach_dense_outliers, chain_flops,
                        compute_reduction_ratio_input_only,
                        compute_reduction_ratio_input_weight, decompose,
                        decompose_weight, extract,
                        from_dense_svd, lowrank_matmul,
                        lowrank_x_lowrank_weight, plan_chain,
                        preserved_pv, preserved_qk_scores,
                        weight_compression_ratio, weight_rank_break_even)


@pytest.fixture
def lr_with_outliers():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (48, 8)) @ \
        jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    base, vals, idx = extract(a, jnp.asarray(1.0), 4)
    lr = decompose(base, rank=8, iters=16)
    return attach_dense_outliers(lr, vals, idx), a


def test_eq6_preserved_matmul(lr_with_outliers):
    lr, a = lr_with_outliers
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 40)) * 0.1
    y = lowrank_matmul(lr, w)
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(lr.reconstruct() @ w),
                               rtol=1e-3, atol=1e-3)
    # S never contracts: Vt* has shape [k, N]
    assert y.vt.shape == (8, 40)


def test_eq7_input_weight(lr_with_outliers):
    lr, a = lr_with_outliers
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 40)) * 0.1
    w_lr = decompose_weight(w, rank=32)
    y = lowrank_x_lowrank_weight(lr, w_lr)
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(lr.reconstruct()
                                          @ w_lr.reconstruct()),
                               rtol=1e-3, atol=1e-3)


def test_eq4_optimal_chain_order():
    """Paper's claimed order: multiply right-to-left when r << S,H."""
    s, r, h, n = 4096, 10, 4096, 4096
    order, flops = plan_chain((s, r, r, h, n))
    # optimal must beat the naive left-to-right reconstruction order
    naive = chain_flops((s, r, r, h, n), [0, 0, 0])
    assert flops < naive
    # and cost must be the Eq. 4 arithmetic: r*h*n + r*r*n + s*r*n ~ order
    assert flops <= 2 * (r * r * h + r * h * n + s * r * n)


def test_eq8_ratio():
    assert compute_reduction_ratio_input_only(4096, 10) == pytest.approx(409.6)


def test_eq9_ratio_positive():
    r = compute_reduction_ratio_input_weight(4096, 4096, 4096, 10, 10, 8, 8)
    assert r > 100


def test_eq10_eq12_compression():
    assert activation_compression_ratio(4096, 4096, 10, 10) > 100
    assert weight_compression_ratio(4096, 4096, 10, 10) > 100
    # Eq. 11 break-even: at p == bound the ratio is ~1
    p = weight_rank_break_even(4096, 4096)
    assert weight_compression_ratio(4096, 4096, int(p), int(p)) == \
        pytest.approx(1.0, rel=0.01)


@pytest.mark.parametrize("nh,kvh", [(4, 4), (4, 2), (8, 1)])
def test_preserved_attention_gqa(nh, kvh):
    S, H = 32, 64
    dh = H // nh
    kv_width = kvh * dh
    q = from_dense_svd(jax.random.normal(jax.random.PRNGKey(0), (S, H)), 6)
    k = from_dense_svd(jax.random.normal(jax.random.PRNGKey(1),
                                         (S, kv_width)), 6)
    v = from_dense_svd(jax.random.normal(jax.random.PRNGKey(2),
                                         (S, kv_width)), 6)
    sc = preserved_qk_scores(q, k, nh, 0.3, kvh)
    qh = q.reconstruct().reshape(S, nh, dh)
    kh = k.reconstruct().reshape(S, kvh, dh)
    g = nh // kvh
    sc_ref = 0.3 * jnp.einsum("skgd,tkd->kgst",
                              qh.reshape(S, kvh, g, dh), kh)
    sc_ref = sc_ref.reshape(nh, S, S)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_ref),
                               rtol=1e-3, atol=1e-3)
    p = jax.nn.softmax(sc, axis=-1)
    out = preserved_pv(p, v, nh, kvh)
    vh = v.reconstruct().reshape(S, kvh, dh)
    pv_ref = jnp.einsum("kgst,tkd->skgd",
                        p.reshape(kvh, g, S, S), vh).reshape(S, nh * dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(pv_ref),
                               rtol=1e-3, atol=1e-3)

# Property-based (hypothesis) invariants live in test_properties.py, which
# importorskips hypothesis at module level.
