"""Checkpointing: atomicity, async, GC, elastic re-shard."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": [jnp.ones((4,)), jnp.zeros((2, 2))]}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), 5)
    template = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    r = ckpt.restore(template, str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    for s in (1, 3, 7, 9):
        ckpt.save(_tree(s), str(tmp_path), s)
    assert ckpt.latest_step(str(tmp_path)) == 9
    ckpt.gc_old(str(tmp_path), keep=2)
    remaining = sorted(os.listdir(str(tmp_path)))
    assert remaining == ["step_7", "step_9"]


def test_atomicity_no_tmp_left(tmp_path):
    ckpt.save(_tree(), str(tmp_path), 1)
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(_tree(), str(tmp_path), 0)
    bad = {"a": jax.ShapeDtypeStruct((9, 16), jnp.float32),
           "nested": {"b": jax.ShapeDtypeStruct((10,), jnp.int32),
                      "c": [jax.ShapeDtypeStruct((4,), jnp.float32),
                            jax.ShapeDtypeStruct((2, 2), jnp.float32)]}}
    with pytest.raises(ValueError):
        ckpt.restore(bad, str(tmp_path))


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=1)
    saver.save(_tree(0), 0)
    saver.save(_tree(1), 1)     # waits for the first
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert os.listdir(str(tmp_path)) == ["step_1"]


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import checkpoint as ckpt

    d = "%s"
    # save on a 2x4 mesh with model sharding
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
    ckpt.save({"x": xa}, d, 0)
    # restore onto a DIFFERENT 4x2 mesh + different sharding
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    template = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    shard = {"x": NamedSharding(mesh_b, P("model", "data"))}
    r = ckpt.restore(template, d, shardings=shard)
    assert r["x"].sharding.mesh.shape["data"] == 4
    np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(x))
    print("ELASTIC_OK")
""")


def test_elastic_restore_cross_mesh(tmp_path):
    """Checkpoint saved on mesh A restores re-sharded on mesh B (subprocess:
    needs 8 placeholder devices without polluting this process)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c",
                          ELASTIC_SCRIPT % str(tmp_path)],
                         capture_output=True, text=True, env=env)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
