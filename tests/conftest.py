# NOTE: no XLA_FLAGS here — smoke tests must see exactly 1 device (the
# 512-device forcing lives only at the top of launch/dryrun.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
