"""Optimizers: convergence on a quadratic + clipping behavior."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import Adafactor, AdamW, clip_by_global_norm, \
    cosine_schedule


def _run(opt, steps=200):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros((3,)), "m": jnp.zeros((4, 5))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
    return float(loss_fn(params))


def test_adamw_converges():
    opt = AdamW(lr=lambda s: 0.05, weight_decay=0.0)
    assert _run(opt) < 1e-2


def test_adafactor_converges():
    opt = Adafactor(lr=lambda s: 0.1)
    assert _run(opt, 400) < 5e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 30
    total = jnp.sqrt(sum(jnp.sum(l ** 2)
                         for l in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < 1e-5
    assert abs(float(lr(jnp.asarray(5))) - 5e-4) < 1e-9
