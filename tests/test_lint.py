"""dcomlint (repro.lint) — per-rule true-positive / true-negative /
suppression fixtures, framework mechanics, CLI exit codes, and the
meta-test that the repo's own tree is clean.

Every fixture snippet is the smallest program exhibiting (or legally
avoiding) one rule's defect class; the TN twin of each TP pins the
rule's precision so a refactor of the analyzer can't silently start
flagging sanctioned idioms (or stop flagging the bug it was built for).
"""
import json
import os
import textwrap

import pytest

from repro.lint import (REGISTRY, check_file, parse_suppressions,
                        run_paths)
from repro.lint.__main__ import main as lint_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# paths chosen so package-scoped rules (J2/O1 serving & obs allowlists)
# see the right module; plain rules don't care
SERVING = "src/repro/serving/fixture.py"
OBS = "src/repro/obs/fixture.py"
KERNELS = "src/repro/kernels/fixture.py"
ANY = "src/repro/tune/fixture.py"


def lint(src: str, path: str = ANY, select=None):
    """Lint a snippet → (active rule-id list, suppressed rule-id list)."""
    rules = None
    if select:
        rules = [REGISTRY[r] for r in select]
    active, suppressed = check_file(path, rules, textwrap.dedent(src))
    return [f.rule for f in active], [f.rule for f in suppressed]


def test_registry_has_all_shipped_rules():
    assert {"D1", "D2", "D3", "F1", "J1", "J2", "O1", "P1",
            "S1"} <= set(REGISTRY)
    for rule in REGISTRY.values():
        assert rule.doc(), f"{rule.id} must document its motivating bug"
        assert rule.severity in ("error", "warning")


# ---------------------------------------------------------------- D1 ----

def test_d1_flags_builtin_hash():
    active, _ = lint("seed = abs(hash(str(path))) % 2**31\n")
    assert active == ["D1"]


def test_d1_flags_id_into_filename():
    active, _ = lint('name = f"cache-{id(table)}.json"\n')
    assert active == ["D1"]


def test_d1_allows_crc32_and_identity_dict():
    active, _ = lint("""\
        import zlib
        seed = zlib.crc32(str(path).encode()) % 2**31
        registry[id(obj)] = obj          # host-lifetime identity key
    """)
    assert active == []


def test_d1_suppression():
    active, suppressed = lint(
        "h = hash(key)  # dcomlint: disable=D1\n")
    assert active == [] and suppressed == ["D1"]


# ---------------------------------------------------------------- D2 ----

def test_d2_flags_wall_clock():
    active, _ = lint("""\
        import time
        t0 = time.time()
    """)
    assert active == ["D2"]


def test_d2_flags_from_import_alias():
    active, _ = lint("""\
        from time import time as now
        t0 = now()
    """)
    assert active == ["D2"]


def test_d2_allows_perf_counter_and_monotonic():
    active, _ = lint("""\
        import time
        t0 = time.perf_counter()
        t1 = time.monotonic()
    """)
    assert active == []


def test_d2_suppression_for_epoch_use():
    active, suppressed = lint("""\
        import time
        # compared against mtimes, which are wall-clock
        now = time.time()  # dcomlint: disable=D2
    """)
    assert active == [] and suppressed == ["D2"]


# ---------------------------------------------------------------- D3 ----

def test_d3_flags_bare_write():
    active, _ = lint("""\
        import json
        def save(path, obj):
            with open(path, "w") as f:
                json.dump(obj, f)
    """)
    assert active == ["D3"]


def test_d3_allows_tmp_replace_pattern():
    active, _ = lint("""\
        import json, os
        def save(path, obj):
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(obj, f)
            os.replace(tmp, path)
    """)
    assert active == []


def test_d3_ignores_reads():
    active, _ = lint("""\
        def load(path):
            with open(path) as f:
                return f.read()
        def load2(path):
            with open(path, "rb") as f:
                return f.read()
    """)
    assert active == []


def test_d3_suppression():
    active, suppressed = lint("""\
        def save(path, text):
            f = open(path, "w")  # dcomlint: disable=D3
            f.write(text)
    """)
    assert active == [] and suppressed == ["D3"]


# ---------------------------------------------------------------- F1 ----

def test_f1_flags_family_branch_in_serving():
    # the PR 10 motivating bug: Engine._prefill_args special-cased
    # vlm/audio in an if-chain — a new family silently fell through to
    # the dense arm instead of failing at registration
    active, _ = lint("""\
        def _prefill_args(self, toks):
            if self.cfg.family == "vlm":
                return (toks, self._image_zeros())
            return (toks,)
    """, path=SERVING)
    assert active == ["F1"]


def test_f1_flags_family_table_outside_resolver():
    active, _ = lint("""\
        def admit(self, cfg):
            return _SPLICERS[cfg.family](self.cache)
    """, path="src/repro/models/api.py")
    assert active == ["F1"]


def test_f1_allows_registered_resolvers_and_asserts():
    active, _ = lint("""\
        def model_fns(cfg):
            return _FAMILY[cfg.family]
        def serving_family(eng, paged=False):
            key = "transformer-dkv" if eng.dkv_rank else eng.cfg.family
            return _REGISTRY[key](eng, paged=paged)
        def decomposed_fns(cfg):
            assert cfg.family == "dense", "decomposed KV: dense family"
    """, path=SERVING)
    assert active == []


def test_f1_ignores_modules_outside_scope():
    # launch/benchmark/config code may branch on family (CLI plumbing);
    # only the serving engine and the model API are gated
    active, _ = lint('wide = cfg.family in ("vlm", "audio")\n', path=ANY)
    assert active == []


def test_f1_suppression():
    active, suppressed = lint("""\
        legacy = cfg.family == "audio"  # dcomlint: disable=F1
    """, path=SERVING)
    assert active == [] and suppressed == ["F1"]


# ---------------------------------------------------------------- J1 ----

def test_j1_flags_read_after_donation():
    active, _ = lint("""\
        import jax
        def serve(cache, x):
            step = jax.jit(f, donate_argnums=(0,))
            out = step(cache, x)
            return cache.sum()
    """)
    assert active == ["J1"]


def test_j1_allows_rebind_idiom():
    active, _ = lint("""\
        import jax
        def serve(cache, x):
            step = jax.jit(f, donate_argnums=(0,))
            cache = step(cache, x)
            return cache.sum()
    """)
    assert active == []


def test_j1_rebind_through_other_name_then_read_is_flagged():
    # donating position 1, reading the donated buffer later
    active, _ = lint("""\
        import jax
        def serve(cache, x):
            step = jax.jit(f, donate_argnums=1)
            y = step(x, cache)
            z = cache + 1
            return y, z
    """)
    assert active == ["J1"]


def test_j1_suppression():
    active, suppressed = lint("""\
        import jax
        def serve(cache, x):
            step = jax.jit(f, donate_argnums=(0,))
            out = step(cache, x)
            return cache.shape  # dcomlint: disable=J1
    """)
    assert active == [] and suppressed == ["J1"]


# ---------------------------------------------------------------- J2 ----

def test_j2_flags_sync_in_serving():
    active, _ = lint("""\
        import jax
        def step(self, x):
            jax.block_until_ready(x)
            n = x.item()
            return n
    """, path=SERVING)
    assert active == ["J2", "J2"]


def test_j2_flags_asarray_on_dispatch():
    active, _ = lint("""\
        import numpy as np
        def step(self, x):
            return np.asarray(self._decode_fn(x))
    """, path=SERVING)
    assert active == ["J2"]


def test_j2_ignores_non_serving_modules():
    active, _ = lint("""\
        import jax
        def measure(x):
            jax.block_until_ready(x)
            return x.item()
    """, path=ANY)
    assert active == []


def test_j2_allows_host_edge_conversion():
    # np.asarray on a plain value (not a jitted dispatch) is the
    # sanctioned host-edge conversion
    active, _ = lint("""\
        import numpy as np
        def emit(self, tok_host):
            return np.asarray(tok_host)
    """, path=SERVING)
    assert active == []


def test_j2_suppression():
    active, suppressed = lint("""\
        import numpy as np
        def sample(self, logits):
            return np.asarray(self.sampler(logits))  # dcomlint: disable=J2
    """, path=SERVING)
    assert active == [] and suppressed == ["J2"]


# ---------------------------------------------------------------- O1 ----

def test_o1_flags_jnp_import_in_obs():
    active, _ = lint("import jax.numpy as jnp\n", path=OBS)
    assert "O1" in active


def test_o1_flags_from_jax_import_numpy_in_obs():
    active, _ = lint("from jax import numpy\n", path=OBS)
    assert "O1" in active


def test_o1_allows_plain_numpy_in_obs():
    active, _ = lint("import numpy as np\nx = np.zeros(3)\n", path=OBS)
    assert active == []


def test_o1_flags_obs_call_inside_traced_body():
    active, _ = lint("""\
        import jax
        def make(self):
            def body(x):
                self.stats.tokens += 1
                return x * 2
            return jax.jit(body)
    """, path=SERVING)
    assert active == ["O1"]


def test_o1_allows_obs_call_outside_traced_body():
    active, _ = lint("""\
        import jax
        def step(self, x):
            out = self._fn(x)
            self.stats.tokens += 1
            return out
    """, path=SERVING)
    assert active == []


def test_o1_file_suppression():
    active, suppressed = lint("""\
        # dcomlint: disable-file=O1
        import jax.numpy as jnp
    """, path=OBS)
    assert active == [] and suppressed == ["O1"]


# ---------------------------------------------------------------- P1 ----

def test_p1_flags_missing_interpret():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x):
            return pl.pallas_call(kern, grid=(4,))(x)
    """, path=KERNELS)
    assert active == ["P1"]


def test_p1_flags_hardcoded_interpret():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x):
            return pl.pallas_call(kern, grid=(4,), interpret=True)(x)
    """, path=KERNELS)
    assert active == ["P1"]


def test_p1_flags_index_map_arity_mismatch():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x, interp):
            return pl.pallas_call(
                kern, grid=(4, 2), interpret=interp,
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            )(x)
    """, path=KERNELS)
    assert active == ["P1"]


def test_p1_flags_unguarded_grid_division():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x, n, b, interp):
            return pl.pallas_call(kern, grid=(n // b,),
                                  interpret=interp)(x)
    """, path=KERNELS)
    assert active == ["P1"]


def test_p1_clean_launch_site():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x, n, b, interp):
            assert n % b == 0
            return pl.pallas_call(
                kern, grid=(n // b, 2), interpret=interp,
                in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
    """, path=KERNELS)
    assert active == []


def test_p1_block_divisor_guard_recognized():
    active, _ = lint("""\
        import jax.experimental.pallas as pl
        def launch(x, n, interp):
            b = _block_divisor(n, 128)
            return pl.pallas_call(kern, grid=(n // b,),
                                  interpret=interp)(x)
    """, path=KERNELS)
    assert active == []


def test_p1_suppression():
    active, suppressed = lint("""\
        import jax.experimental.pallas as pl
        def launch(x):
            return pl.pallas_call(kern, grid=(4,), interpret=False,  # dcomlint: disable=P1
                                  )(x)
    """, path=KERNELS)
    assert active == [] and suppressed == ["P1"]


# ---------------------------------------------------------------- S1 ----

def test_s1_flags_shard_map_missing_out_specs():
    active, _ = lint("""\
        from jax.experimental.shard_map import shard_map
        g = shard_map(f, mesh=mesh, in_specs=(spec,))
    """)
    assert active == ["S1"]


def test_s1_flags_half_specified_jit_shardings():
    active, _ = lint("""\
        import jax
        g = jax.jit(f, in_shardings=(s,))
    """)
    assert active == ["S1"]


def test_s1_allows_both_or_neither():
    active, _ = lint("""\
        import jax
        from jax.experimental.shard_map import shard_map
        g1 = jax.jit(f, in_shardings=(s,), out_shardings=s)
        g2 = jax.jit(f)
        g3 = shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec)
    """)
    assert active == []


def test_s1_suppression():
    active, suppressed = lint("""\
        import jax
        g = jax.jit(f, in_shardings=(s,))  # dcomlint: disable=S1
    """)
    assert active == [] and suppressed == ["S1"]


# ------------------------------------------------------ framework -------

def test_syntax_error_becomes_e0_finding():
    active, _ = lint("def broken(:\n")
    assert active == ["E0"]


def test_line_suppression_is_line_scoped():
    active, _ = lint("""\
        h1 = hash(a)  # dcomlint: disable=D1
        h2 = hash(b)
    """)
    assert active == ["D1"]          # only the unsuppressed line


def test_disable_all_on_line():
    active, suppressed = lint(
        "h = hash(a)  # dcomlint: disable=all\n")
    assert active == [] and suppressed == ["D1"]


def test_parse_suppressions_shapes():
    per_line, per_file = parse_suppressions([
        "x = 1  # dcomlint: disable=D1,D2",
        "# dcomlint: disable-file=P1",
    ])
    assert per_line == {1: {"D1", "D2"}} and per_file == {"P1"}


def test_unknown_rule_id_raises():
    with pytest.raises(ValueError, match="unknown rule id"):
        run_paths([os.path.join(REPO, "src", "repro", "lint")],
                  select=["ZZ"])


def test_select_filters_rules():
    src = "import time\nh = hash(time.time())\n"
    assert lint(src, select=["D1"])[0] == ["D1"]
    assert lint(src, select=["D2"])[0] == ["D2"]


# ------------------------------------------------------------ CLI ------

def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return str(p)


def test_cli_exit_codes_and_json_report(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "h = hash(x)\n")
    good = _write(tmp_path, "good.py", "y = 1\n")
    report_path = str(tmp_path / "report.json")

    assert lint_main([bad, "--json", report_path]) == 1
    report = json.loads(open(report_path).read())
    assert report["schema"] == "repro.lint/v1"
    assert report["ok"] is False and report["counts"] == {"D1": 1}
    assert report["findings"][0]["rule"] == "D1"

    assert lint_main([good, "--json", report_path]) == 0
    report = json.loads(open(report_path).read())
    assert report["ok"] is True and report["findings"] == []

    assert lint_main([bad, "--select", "NOPE"]) == 2
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("D1", "D2", "D3", "F1", "J1", "J2", "O1", "P1", "S1"):
        assert rid in out


def test_cli_counts_suppressions(tmp_path, capsys):
    p = _write(tmp_path, "sup.py",
               "h = hash(x)  # dcomlint: disable=D1\n")
    assert lint_main([p]) == 0
    assert "(1 suppressed)" in capsys.readouterr().out


# ------------------------------------------------------- meta-test -----

def test_repo_tree_is_clean():
    """The acceptance gate: `python -m repro.lint src benchmarks` exits 0
    on this repo.  Every suppression in the tree is deliberate, so the
    suppressed count is also pinned here — raising it needs a justified
    diff to this test."""
    findings, suppressed, nfiles = run_paths(
        [os.path.join(REPO, "src"), os.path.join(REPO, "benchmarks")])
    assert findings == [], "\n".join(f.render() for f in findings)
    assert nfiles > 90          # the whole tree was actually walked
    # 3 sanctioned suppressions today: checkpoint gc_old epoch time (D2),
    # the two Engine._sample_host sampler readbacks (J2)
    assert len(suppressed) <= 6, \
        "\n".join(f.render() for f in suppressed)
