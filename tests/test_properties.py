"""Property-based invariants (hypothesis).

This module holds every hypothesis-driven case so the rest of the suite
imports without the dependency; the importorskip below skips the whole file
when hypothesis is absent (install via requirements-dev.txt).
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (decompose, decompose_weight, from_dense_svd,
                        lowrank_matmul, lowrank_x_lowrank_weight,
                        relative_error)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(12, 48), h=st.integers(12, 48), r=st.integers(1, 6))
def test_property_reconstruction_bounded(s, h, r):
    """‖X − X̂_r‖ ≤ ‖X‖ and ε decreases vs the oracle's tail energy."""
    a = jax.random.normal(jax.random.PRNGKey(s * 1000 + h), (s, h))
    lr = decompose(a, rank=r, iters=min(r + 6, min(s, h)))
    err = float(relative_error(lr, a))
    assert 0.0 <= err <= 1.0 + 1e-3
    # oracle tail: optimal error for the same rank (Eckart–Young)
    sv = np.linalg.svd(np.asarray(a), compute_uv=False)
    opt = float(np.sqrt((sv[r:] ** 2).sum() / (sv ** 2).sum()))
    assert err >= opt - 1e-3            # can't beat optimal
    assert err <= opt + 0.35            # near-optimal for random matrices


@settings(max_examples=12, deadline=None)
@given(s=st.integers(8, 40), h=st.sampled_from([16, 32, 48]),
       n=st.sampled_from([16, 24, 40]), r=st.integers(1, 8),
       bias=st.booleans())
def test_property_eq6_exactness(s, h, n, r, bias):
    """lowrank_matmul(lr, W) reconstructs to lr.reconstruct() @ W (+b) for
    arbitrary shapes/ranks/bias — the Eq. 6 invariant."""
    key = jax.random.PRNGKey(s * 10007 + h * 101 + n)
    lr = from_dense_svd(jax.random.normal(key, (s, h)), r)
    w = jax.random.normal(jax.random.PRNGKey(7), (h, n)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(8), (n,)) if bias else None
    y = lowrank_matmul(lr, w, bias=b)
    want = lr.reconstruct() @ w + (b if bias else 0.0)
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
    assert y.vt.shape[-1] == n                     # output stays factored
    assert y.u.shape[-2] == s


@settings(max_examples=10, deadline=None)
@given(s=st.integers(8, 32), h=st.sampled_from([16, 32]),
       r=st.integers(1, 6), p=st.integers(2, 8))
def test_property_eq7_exactness(s, h, r, p):
    """Input+weight preserved product equals the dense double product."""
    key = jax.random.PRNGKey(s * 31 + h * 7 + r)
    lr = from_dense_svd(jax.random.normal(key, (s, h)), r)
    w = jax.random.normal(jax.random.PRNGKey(5), (h, h)) * 0.2
    w_lr = decompose_weight(w, min(p, h))
    y = lowrank_x_lowrank_weight(lr, w_lr)
    want = lr.reconstruct() @ w_lr.reconstruct()
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
