"""Property-based invariants (hypothesis).

This module holds every hypothesis-driven case so the rest of the suite
imports without the dependency; the importorskip below skips the whole file
when hypothesis is absent (install via requirements-dev.txt).
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (decompose, decompose_weight, from_dense_svd,
                        lowrank_matmul, lowrank_x_lowrank_weight,
                        relative_error)
from repro.serving import Engine, Request, Scheduler


@settings(max_examples=15, deadline=None)
@given(s=st.integers(12, 48), h=st.integers(12, 48), r=st.integers(1, 6))
def test_property_reconstruction_bounded(s, h, r):
    """‖X − X̂_r‖ ≤ ‖X‖ and ε decreases vs the oracle's tail energy."""
    a = jax.random.normal(jax.random.PRNGKey(s * 1000 + h), (s, h))
    lr = decompose(a, rank=r, iters=min(r + 6, min(s, h)))
    err = float(relative_error(lr, a))
    assert 0.0 <= err <= 1.0 + 1e-3
    # oracle tail: optimal error for the same rank (Eckart–Young)
    sv = np.linalg.svd(np.asarray(a), compute_uv=False)
    opt = float(np.sqrt((sv[r:] ** 2).sum() / (sv ** 2).sum()))
    assert err >= opt - 1e-3            # can't beat optimal
    assert err <= opt + 0.35            # near-optimal for random matrices


@settings(max_examples=12, deadline=None)
@given(s=st.integers(8, 40), h=st.sampled_from([16, 32, 48]),
       n=st.sampled_from([16, 24, 40]), r=st.integers(1, 8),
       bias=st.booleans())
def test_property_eq6_exactness(s, h, n, r, bias):
    """lowrank_matmul(lr, W) reconstructs to lr.reconstruct() @ W (+b) for
    arbitrary shapes/ranks/bias — the Eq. 6 invariant."""
    key = jax.random.PRNGKey(s * 10007 + h * 101 + n)
    lr = from_dense_svd(jax.random.normal(key, (s, h)), r)
    w = jax.random.normal(jax.random.PRNGKey(7), (h, n)) * 0.2
    b = jax.random.normal(jax.random.PRNGKey(8), (n,)) if bias else None
    y = lowrank_matmul(lr, w, bias=b)
    want = lr.reconstruct() @ w + (b if bias else 0.0)
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
    assert y.vt.shape[-1] == n                     # output stays factored
    assert y.u.shape[-2] == s


@settings(max_examples=10, deadline=None)
@given(s=st.integers(8, 32), h=st.sampled_from([16, 32]),
       r=st.integers(1, 6), p=st.integers(2, 8))
def test_property_eq7_exactness(s, h, r, p):
    """Input+weight preserved product equals the dense double product."""
    key = jax.random.PRNGKey(s * 31 + h * 7 + r)
    lr = from_dense_svd(jax.random.normal(key, (s, h)), r)
    w = jax.random.normal(jax.random.PRNGKey(5), (h, h)) * 0.2
    w_lr = decompose_weight(w, min(p, h))
    y = lowrank_x_lowrank_weight(lr, w_lr)
    want = lr.reconstruct() @ w_lr.reconstruct()
    np.testing.assert_allclose(np.asarray(y.reconstruct()),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Serving-scheduler invariants (pure python — no device work)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(lens=st.lists(st.integers(1, 40), min_size=1, max_size=20),
       bucket=st.sampled_from([1, 4, 16]),
       max_admit=st.sampled_from([0, 2]),
       frees=st.lists(st.integers(0, 4), min_size=1, max_size=30))
def test_property_scheduler_fifo_within_bucket(lens, bucket, max_admit,
                                               frees):
    """Every submitted request is dispatched exactly once, each batch is a
    single prefill-length bucket capped at the free-slot count, and
    dispatch order within a bucket is submission (FIFO) order."""
    sched = Scheduler(bucket=bucket, max_admit=max_admit)
    reqs = [Request(uid=i, prompt=np.zeros(n, np.int32))
            for i, n in enumerate(lens)]
    for r in reqs:
        sched.submit(r)
    dispatched = []
    for f in frees + [4] * len(reqs):          # drain with full freedom
        batch = sched.next_batch(f)
        assert len(batch) <= f
        if max_admit:
            assert len(batch) <= max_admit
        assert len({sched.bucket_of(len(r.prompt)) for r in batch}) <= 1
        dispatched += batch
        if not len(sched):
            break
    assert sorted(r.uid for r in dispatched) == [r.uid for r in reqs]
    by_bucket = {}
    for r in dispatched:
        by_bucket.setdefault(sched.bucket_of(len(r.prompt)), []).append(r.uid)
    for uids in by_bucket.values():
        assert uids == sorted(uids), "FIFO violated within a bucket"


_MODEL = {}

# one reduced arch per serving family the engine properties draw from;
# MoE pins capacity_factor so the router is batch-size-invariant (a
# capacity-dropped token routes differently between interleavings by
# design — see test_serving_conformance._family_model)
_PROP_ARCHS = {"dense": "llama2-7b", "ssm": "mamba2-780m",
               "moe": "olmoe-1b-7b"}


def _family_model(family):
    if family not in _MODEL:
        import jax as _jax
        from repro.configs import all_archs
        from repro.models import model_fns
        cfg = all_archs()[_PROP_ARCHS[family]].reduced()
        if family == "moe":
            cfg = cfg.replace(capacity_factor=8.0)
        _MODEL[family] = (cfg,
                          model_fns(cfg).init(_jax.random.PRNGKey(0), cfg))
    return _MODEL[family]


def _dense_model():
    return _family_model("dense")


@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_property_engine_finishes_once_no_leaks_monotone(data):
    """Engine invariants under random arrivals FOR ANY SERVING FAMILY:
    every submitted request finishes exactly once, no slot leaks, and
    while a slot keeps its occupant its ``pos`` strictly advances and
    ``frozen_len`` never shrinks (per-slot monotonicity).  The dkv and
    paged layouts only exist for the dense family's KV cache."""
    family = data.draw(st.sampled_from(["dense", "ssm", "moe"]))
    cfg, params = _family_model(family)
    n = data.draw(st.integers(1, 5))
    lens = data.draw(st.lists(st.integers(1, 12), min_size=n, max_size=n))
    news = data.draw(st.lists(st.integers(1, 4), min_size=n, max_size=n))
    arrive = sorted(data.draw(st.lists(st.integers(0, 6), min_size=n,
                                       max_size=n)))
    dkv = family == "dense" and data.draw(st.booleans())
    paged = dkv and data.draw(st.booleans())
    kw = dict(decompose_kv_rank=6, dkv_tail=2, paged=paged) if dkv else {}
    eng = Engine(cfg, params, slots=2, max_len=48, **kw)
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab, l,
                                              dtype=np.int32),
                    max_new_tokens=m)
            for i, (l, m) in enumerate(zip(lens, news))]
    pending = list(zip(arrive, reqs))
    finished = []
    for step in range(300):
        while pending and pending[0][0] <= step:
            eng.submit(pending.pop(0)[1])
        occ = [id(r) if r is not None else None for r in eng.live]
        pos0, fr0 = eng.pos.copy(), eng.frozen_len.copy()
        finished += eng.step()
        for s in range(eng.slots):
            if occ[s] is not None and eng.live[s] is not None \
                    and id(eng.live[s]) == occ[s]:
                assert eng.pos[s] > pos0[s], "pos stalled on a live slot"
                assert eng.frozen_len[s] >= fr0[s], "frozen_len shrank"
        if not pending and not len(eng.sched) and not any(eng.live):
            break
    assert sorted(r.uid for r in finished) == list(range(n))
    assert all(r.done for r in finished)
    assert eng.live == [None] * eng.slots, "slot leak"
    assert eng.stats.prefills == n
    if paged:                        # every page returned after drain
        assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1
        assert eng.pager.talloc.free_pages == eng.pager.num_tail_pages - 1


@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_property_block_interleaving_token_exact(data):
    """ANY per-step interleaving of fused decode-block lengths yields
    byte-identical tokens to the single-step engine: the block length is
    pure execution strategy (how many rounds one launch covers), never
    semantics.  Exercises the dkv (and optionally paged) engine across
    fold boundaries and organic re-admissions (slots < requests)."""
    cfg, params = _dense_model()
    paged = data.draw(st.booleans())
    tail = data.draw(st.sampled_from([2, 4]))

    def serve(blocks=None):
        eng = Engine(cfg, params, slots=2, max_len=48,
                     decompose_kv_rank=6, dkv_tail=tail, paged=paged)
        rng = np.random.RandomState(0)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=rng.randint(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                               max_new_tokens=6))
        done = []
        for _ in range(300):
            if blocks is not None:
                # decode_block is re-readable every step: draw a fresh
                # length for each launch (capped at the fold horizon,
                # as Engine.__init__ does)
                eng.decode_block = min(tail, blocks.draw(
                    st.sampled_from([1, 2, 3, 4, 8])))
            done.extend(eng.step())
            if not any(eng.live) and not len(eng.sched):
                break
        assert sorted(r.uid for r in done) == [0, 1, 2]
        return {r.uid: r.out_tokens for r in done}

    base = serve(None)                   # decode_block=1 single-step
    assert serve(data) == base, "block interleaving changed tokens"


# ---------------------------------------------------------------------------
# Page-allocator invariants (pure python — no device work)
# ---------------------------------------------------------------------------

from repro.serving.paged import PageAllocator  # noqa: E402


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_property_page_allocator_refcounts_no_leaks(data):
    """Under random alloc/ref/release traffic: page 0 (the write sink) is
    never handed out, no page is ever handed to two owners at once,
    conservation holds (free + live == pool), releasing an unallocated
    page raises (double-free guard), and a full drain returns EVERY page
    to the free list."""
    n = data.draw(st.integers(2, 48))
    al = PageAllocator(n)
    total = n - 1
    held = []                     # (pages, extra_refs) per allocation
    for _ in range(data.draw(st.integers(1, 80))):
        op = data.draw(st.sampled_from(["alloc", "ref", "release",
                                        "release"]))
        if op == "alloc":
            k = data.draw(st.integers(0, total))
            got = al.alloc(k)
            if got is None:
                assert k > 0          # alloc(0) always succeeds
            else:
                assert len(got) == k and 0 not in got
                live = [p for pages, _ in held for p in pages]
                assert not set(got) & set(live), "page double-handed"
                held.append((got, 0))
        elif op == "ref" and held:
            i = data.draw(st.integers(0, len(held) - 1))
            pages, extra = held[i]
            if pages:
                al.ref(pages)
                held[i] = (pages, extra + 1)
        elif op == "release" and held:
            i = data.draw(st.integers(0, len(held) - 1))
            pages, extra = held[i]
            al.release(pages)
            if extra:
                held[i] = (pages, extra - 1)
            else:
                held.pop(i)
        live_count = len({p for pages, _ in held for p in pages})
        assert al.free_pages + live_count == total, "page conservation"
    # drain: release every remaining ref; the pool must come back whole
    for pages, extra in held:
        for _ in range(extra + 1):
            al.release(pages)
    assert al.free_pages == total, "leaked pages after drain"
    assert not al.live_refs
    with pytest.raises(ValueError):
        al.release([1])               # double free raises


@settings(max_examples=30, deadline=None)
@given(lens=st.lists(st.integers(5, 24), min_size=1, max_size=6),
       page=st.sampled_from([2, 4, 8]), cap=st.integers(1, 3))
def test_property_prefix_cache_capacity_and_refs(lens, page, cap):
    """PrefixCache never exceeds its capacity, holds exactly one ref per
    page of each live entry, and dropping every entry returns the pool to
    its pre-insert state."""
    from repro.serving.paged import PrefixCache
    al = PageAllocator(256)
    pc = PrefixCache(cap, page, al)
    slots = []
    rng = np.random.RandomState(0)
    for n in lens:
        toks = rng.randint(0, 100, n).astype(np.int32)
        pages = al.alloc(-(-n // page))
        pc.insert(toks, pages, None, None, r_eff=4)
        slots.append(pages)
    assert len(pc) <= cap
    want = sum(len(e.pages) for e in pc._entries.values())
    # slots still hold their own refs; entry refs are ON TOP of them
    over = sum(rc - 1 for rc in al.live_refs.values())
    assert over == want, "entries must hold exactly one ref per page"
    pc.drop_all()
    assert sum(rc - 1 for rc in al.live_refs.values()) == 0
    for pages in slots:
        al.release(pages)
    assert al.free_pages == 255


# ---------------------------------------------------------------------------
# Tuner cost-model invariant (pure python — no device work)
# ---------------------------------------------------------------------------

from repro import tune as _tune  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 8), s=st.integers(1, 700), h=st.integers(1, 700),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       kernel=st.sampled_from(["lanczos_reorth", "matvec_expand",
                               "lowrank_matmul", "dkv_attention"]),
       dev=st.sampled_from([_tune.V5E, _tune.CPU_INTERPRET]))
def test_property_cost_model_u_shaped_in_f(b, s, h, dtype, kernel, dev):
    """The predicted latency is U-shaped (unimodal) in the expansion
    factor along the power-of-two grid for EVERY shape/dtype/device:
    non-increasing up to its argmin, non-decreasing after.  This is the
    structural property the pruner relies on — a non-unimodal model could
    prune away the true optimum."""
    shape = {"lanczos_reorth": (b, s, h),
             "matvec_expand": (s, h),
             "lowrank_matmul": (max(1, 2 * b), s, h),
             "dkv_attention": (b, s, h)}[kernel]
    grid = sorted(_tune.get_space(kernel).param("expansion").choices)
    ts = [_tune.predict(kernel, shape, dtype, {"expansion": f}, dev)
          for f in grid]
    assert all(t > 0 for t in ts)
    i = min(range(len(ts)), key=ts.__getitem__)
    for j in range(i):
        assert ts[j] >= ts[j + 1] * (1 - 1e-9), \
            (grid, ts, "not non-increasing left of argmin")
    for j in range(i, len(ts) - 1):
        assert ts[j] <= ts[j + 1] * (1 + 1e-9), \
            (grid, ts, "not non-decreasing right of argmin")


# ---------------------------------------------------------------------------
# Sharding rule invariants (8-device host-serving mesh)
# ---------------------------------------------------------------------------

from jax.sharding import AbstractMesh, PartitionSpec as ShP

from repro.distributed import sharding as _sh

_SMESHES = [AbstractMesh((("data", 8), ("model", 1))),
            AbstractMesh((("data", 2), ("model", 4))),
            AbstractMesh((("pod", 2), ("data", 2), ("model", 2)))]


def _axis_sz(mesh, axis):
    return _sh.axis_size(mesh, axis)


@settings(max_examples=40, deadline=None)
@given(mesh_i=st.integers(0, len(_SMESHES) - 1),
       name=st.sampled_from(["k", "v", "k_u", "v_u", "k_vt", "v_vt",
                             "conv", "ssm"]),
       dims=st.lists(st.integers(1, 24), min_size=3, max_size=5))
def test_property_cache_spec_dims_always_divide(mesh_i, name, dims):
    """Every axis cache_pspec shards divides its mesh axis exactly — the
    divisibility guard holds for EVERY leaf family and ANY shape, so a
    mesh-serving engine can never be handed an unshardable cache."""
    mesh = _SMESHES[mesh_i]
    nd_min = {"k": 4, "v": 4, "ssm": 4}.get(name, 3)
    shape = tuple(dims[:max(nd_min, len(dims))])
    if len(shape) < nd_min:
        shape = shape + (8,) * (nd_min - len(shape))
    spec = _sh.cache_pspec(name, shape, mesh)
    assert len(spec) == len(shape)
    for dim, axis in zip(shape, spec):
        if axis is not None:
            assert dim % _axis_sz(mesh, axis) == 0, (name, shape, spec)


@settings(max_examples=40, deadline=None)
@given(mesh_i=st.integers(0, len(_SMESHES) - 1),
       b=st.integers(1, 32), t=st.integers(1, 64), r=st.integers(1, 16))
def test_property_dkv_u_time_axis_model_replicated(mesh_i, b, t, r):
    """k_u/v_u NEVER shard over "model" (the refuted §Perf C3 layout), and
    batch-1 caches shard time over "data" exactly when it divides."""
    mesh = _SMESHES[mesh_i]
    spec = _sh.cache_pspec("k_u", (4, b, t, r), mesh)
    assert "model" not in jax.tree_util.tree_leaves(list(spec))
    if b == 1:
        expect = "data" if t % mesh.shape["data"] == 0 else None
        assert spec[2] == expect
    dp_sz = _axis_sz(mesh, _sh.dp_axes(mesh))
    if b > 1 and b % dp_sz == 0:
        assert spec[1] == _sh.dp_name(mesh)


@settings(max_examples=40, deadline=None)
@given(mesh_i=st.integers(0, len(_SMESHES) - 1),
       dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       presharded=st.booleans())
def test_property_zero1_first_divisible_dim(mesh_i, dims, presharded):
    """_zero1 adds the DP axis to exactly the FIRST unsharded dim that
    divides the DP size (and is > 1); all other dims keep their spec."""
    mesh = _SMESHES[mesh_i]
    shape = tuple(dims)
    base = [None] * len(shape)
    if presharded and len(shape) and shape[-1] % mesh.shape["model"] == 0:
        base[-1] = "model"
    spec = _sh._zero1(ShP(*base), shape, mesh)
    dp = _sh.dp_axes(mesh)
    dp_sz = _axis_sz(mesh, dp)
    dp_entry = _sh.dp_name(mesh)
    expect_i = next((i for i, (d, s) in enumerate(zip(shape, base))
                     if s is None and d % dp_sz == 0 and d > 1), None)
    for i, (s0, s1) in enumerate(zip(base, spec)):
        if i == expect_i:
            assert s1 == dp_entry
        else:
            assert s1 == s0, (shape, base, spec)


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_property_async_engine_interleavings(data):
    """Arbitrary interleavings of submit / dispatch / defer / ready /
    stop events against the ASYNC serving engine (the defers arise
    organically from a deliberately tight page pool, the ready/splice
    timing from the ticket pool): slot and page conservation after
    drain, FIFO-per-bucket dispatch order, and token exactness vs the
    synchronous engine in deterministic ready-order mode.  The family
    draw runs the same interleavings through the O(1)-state SSM engine
    (no dkv, no pages — ticket/splice machinery is family-generic)."""
    family = data.draw(st.sampled_from(["dense", "ssm"]))
    cfg, params = _family_model(family)
    n = data.draw(st.integers(1, 5))
    lens = data.draw(st.lists(st.integers(1, 20), min_size=n, max_size=n))
    news = data.draw(st.lists(st.integers(1, 4), min_size=n, max_size=n))
    arrive = sorted(data.draw(st.lists(st.integers(0, 6), min_size=n,
                                       max_size=n)))
    paged = family == "dense" and data.draw(st.booleans())
    mode = data.draw(st.sampled_from(["deterministic", "ready"]))
    block = data.draw(st.sampled_from([1, 3]))

    from repro.engine import DecomposeEngine, EngineConfig

    def build(**extra):
        # dkv_tail=8 > max_new keeps folds out of the picture so the
        # tight pool (kv_pool_pages=3: two real pages) produces DEFER
        # events, never fold-exhaustion; sched_max_admit=1 keeps every
        # single batch satisfiable (a lone bucket-32 prompt needs both
        # pages), so a defer always resolves when a slot frees
        deng = DecomposeEngine(EngineConfig(
            kv_rank=6, kv_tail=8, kv_page=16,
            kv_pool_pages=3 if paged else 0, sched_max_admit=1,
            decode_block=block))
        # an explicit rank-0 keeps the SSM engine on its family cache
        # (the engine config still supplies sched/block knobs)
        fam_kw = {} if family == "dense" else dict(decompose_kv_rank=0)
        return Engine(cfg, params, slots=2, max_len=48, paged=paged,
                      decompose_engine=deng, **fam_kw, **extra)

    def drive(eng):
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab, l,
                                                  dtype=np.int32),
                        max_new_tokens=m)
                for i, (l, m) in enumerate(zip(lens, news))]
        pending = list(zip(arrive, reqs))
        out = {}
        for step in range(400):
            while pending and pending[0][0] <= step:
                eng.submit(pending.pop(0)[1])
            for r in eng.step():
                out[r.uid] = list(r.out_tokens)
            if not pending and not eng._occupied() and not len(eng.sched):
                break
        return out

    sync = drive(build())
    eng = build(prefill_async=True, ready_order=mode)
    got = drive(eng)
    assert sorted(got) == sorted(sync) == list(range(n))
    if mode == "deterministic":
        assert got == sync, "det mode must be byte-identical to sync"
    # conservation after drain: no ticket, no reserved slot, no leaked page
    assert not eng._pool and not eng._reserved.any()
    assert eng.live == [None] * eng.slots
    if paged:
        assert eng.pager.alloc.free_pages == eng.pager.num_pages - 1
        assert eng.pager.talloc.free_pages == eng.pager.num_tail_pages - 1
    # dispatch order is FIFO within each prompt-length bucket
    sched = eng.sched
    by_bucket = {}
    uid_len = {i: l for i, l in enumerate(lens)}
    for uid in eng.admit_log:
        by_bucket.setdefault(sched.bucket_of(uid_len[uid]), []).append(uid)
    for uids in by_bucket.values():
        assert uids == sorted(uids), "dispatch order broke bucket FIFO"


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_property_histogram_quantiles_within_bucket_error(data):
    """The log-bucketed streaming histogram's quantiles match
    numpy.percentile(inverted_cdf) to within half a bucket of relative
    error (10^(1/(2·BPD)) − 1 ≈ 5.9%) on ANY positive sample set —
    arbitrary scale, arbitrary skew, duplicates, single elements."""
    from repro.obs import BUCKETS_PER_DECADE
    from repro.obs.registry import Histogram
    qerr = 10.0 ** (0.5 / BUCKETS_PER_DECADE) - 1.0
    scale = data.draw(st.sampled_from([1e-6, 1e-3, 1.0, 1e3]),
                      label="scale")
    xs = data.draw(st.lists(
        st.floats(min_value=1e-3, max_value=1e3, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=300), label="samples")
    xs = [v * scale for v in xs]
    h = Histogram("x")
    for v in xs:
        h.observe(v)
    q = data.draw(st.floats(min_value=0.01, max_value=1.0), label="q")
    got = h.quantile(q)
    exact = float(np.percentile(np.asarray(xs), 100.0 * q,
                                method="inverted_cdf"))
    # the +1e-9·exact ULP slack covers samples landing EXACTLY on a
    # bucket edge, where the error ties qerr·exact to the last bit
    assert abs(got - exact) <= (qerr + 1e-9) * exact + 1e-15, \
        f"q={q}: hist {got} vs exact {exact} (n={len(xs)})"
    # quantiles are monotone in q and clamped to the observed range
    # (q=1.0 is the top bucket's midpoint: ≤ max, within qerr below it)
    assert h.min - 1e-15 <= h.quantile(0.0)
    assert h.max * (1 - qerr) - 1e-15 <= h.quantile(1.0) <= h.max + 1e-15
    qs = [h.quantile(t / 10) for t in range(11)]
    assert all(a <= b + 1e-15 for a, b in zip(qs, qs[1:]))
