"""PowerSGD gradient compression: exactness limits, error feedback, ratio,
cross-process Q-init determinism."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (PowerSGDConfig, _path_seed,
                                           compress_decompress,
                                           compression_ratio, init_state)


def test_exact_for_rank_le_r():
    """A rank-2 gradient compresses exactly at r >= 2 (after power step)."""
    cfg = PowerSGDConfig(rank=4, min_elems=0)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 2)) @
              jax.random.normal(jax.random.PRNGKey(1), (2, 48))}
    st = init_state(g, cfg)
    out, st = compress_decompress(g, st, cfg)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-3, atol=1e-3)


def test_error_feedback_accumulates():
    cfg = PowerSGDConfig(rank=1, min_elems=0)
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (32, 32))}
    st = init_state(g, cfg)
    out, st = compress_decompress(g, st, cfg)
    # residual = what compression lost; stored for the next step
    resid = np.asarray(g["w"] - out["w"], np.float32)
    np.testing.assert_allclose(np.asarray(st["w"]["e"]), resid, atol=1e-4)
    assert np.abs(resid).max() > 0


def test_error_feedback_sgd_converges():
    """The EF guarantee: SGD with EF-compressed gradients reaches the
    optimum of a quadratic; dropping the feedback memory stalls higher."""
    target = jax.random.normal(jax.random.PRNGKey(3), (16, 16))

    def run(use_ef: bool, steps=150, lr=0.2):
        cfg = PowerSGDConfig(rank=1, min_elems=0)
        w = jnp.zeros((16, 16))
        st = init_state({"w": w}, cfg)
        for _ in range(steps):
            g = {"w": w - target}                 # grad of 0.5*|w - A|^2
            out, st = compress_decompress(g, st, cfg)
            if not use_ef:
                st["w"]["e"] = jnp.zeros_like(st["w"]["e"])
            w = w - lr * out["w"]
        return float(jnp.linalg.norm(w - target) / jnp.linalg.norm(target))

    err_ef = run(True, steps=600)
    assert err_ef < 0.05


def test_small_tensors_passthrough():
    cfg = PowerSGDConfig(rank=2, min_elems=10_000)
    g = {"b": jnp.ones((8,))}
    st = init_state(g, cfg)
    out, _ = compress_decompress(g, st, cfg)
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones((8,)))


def test_compression_ratio():
    cfg = PowerSGDConfig(rank=4, min_elems=0)
    params = {"w": jnp.zeros((4096, 4096))}
    r = compression_ratio(params, cfg)
    assert r > 400       # 4096^2 / (4*(4096+4096)) = 512


# ---------------------------------------------------------------------------
# Cross-process determinism (the PYTHONHASHSEED regression)
# ---------------------------------------------------------------------------

_Q_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, sys
    from repro.distributed.compression import PowerSGDConfig, init_state
    params = {"layers": {"attn": {"wq": {"w": jnp.zeros((64, 1024))},
                                  "wo": {"w": jnp.zeros((64, 1024))}},
                         "mlp": [{"w": jnp.zeros((32, 2048))}]}}
    st = init_state(params, PowerSGDConfig(rank=2, min_elems=0))
    qs = [np.asarray(l["q"]) for l in jax.tree_util.tree_leaves(
              st, is_leaf=lambda x: isinstance(x, dict) and "q" in x)]
    np.save(sys.argv[1], np.concatenate([q.ravel() for q in qs]))
""")


def test_powersgd_q_init_bit_identical_across_processes(tmp_path):
    """Every DP worker is its own Python process with its own (randomized)
    PYTHONHASHSEED; PowerSGD's per-leaf Q inits MUST agree bit-for-bit
    across them or the implicit all-reduces average projections taken in
    different subspaces.  Two fresh interpreters under explicitly
    DIFFERENT hash seeds must write identical Q bytes (would fail with the
    old ``abs(hash(str(path)))`` fold-in)."""
    outs = []
    for i, seed in enumerate(("0", "12345")):
        out = tmp_path / f"q{i}.npy"
        env = dict(os.environ,
                   PYTHONHASHSEED=seed,
                   PYTHONPATH="src" + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        subprocess.run([sys.executable, "-c", _Q_SCRIPT, str(out)],
                       check=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
        outs.append(np.load(out))
    assert outs[0].shape[0] > 0
    np.testing.assert_array_equal(outs[0], outs[1])


def test_path_seed_is_stable_digest():
    """The fold-in seed is a pure function of the path string (crc32), not
    of Python's per-process string hashing."""
    path = jax.tree_util.tree_flatten_with_path(
        {"a": {"b": jnp.zeros((2, 2))}})[0][0][0]
    s = _path_seed(path)
    assert s == _path_seed(path)
    import zlib
    assert s == zlib.crc32(str(path).encode("utf-8")) % (2 ** 31)
