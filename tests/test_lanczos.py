"""Lanczos bidiagonalization vs the LAPACK oracle.

Property-based (hypothesis) cases live in test_properties.py, which skips
itself at module level when hypothesis is not installed — this module must
import cleanly with only the pinned requirements-dev.txt basics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decompose, lanczos_svd, relative_error


def lowrank_matrix(key, s, h, r, noise=0.0):
    a = jax.random.normal(key, (s, r)) @ \
        jax.random.normal(jax.random.PRNGKey(99), (r, h))
    if noise:
        a = a + noise * jax.random.normal(jax.random.PRNGKey(7), (s, h))
    return a


def test_exact_on_lowrank():
    a = lowrank_matrix(jax.random.PRNGKey(0), 128, 96, 6)
    u, s, vt = lanczos_svd(a, rank=6, iters=10)
    rec = (u * s) @ vt
    assert float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a)) < 1e-4


def test_matches_oracle_singular_values():
    a = lowrank_matrix(jax.random.PRNGKey(1), 96, 80, 10, noise=0.01)
    _, s_l, _ = lanczos_svd(a, rank=5, iters=14)
    s_o = jnp.linalg.svd(a, compute_uv=False)[:5]
    np.testing.assert_allclose(np.asarray(s_l), np.asarray(s_o), rtol=1e-3)


def test_error_decreases_with_rank():
    a = jax.random.normal(jax.random.PRNGKey(2), (64, 48))
    errs = []
    for r in (2, 8, 24):
        lr = decompose(a, rank=r, iters=r + 8)
        errs.append(float(relative_error(lr, a)))
    assert errs[0] > errs[1] > errs[2]


def test_batched_decompose_matches_loop():
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 40, 32))
    lr = decompose(x, rank=4, iters=8)
    for i in range(3):
        li = decompose(x[i], rank=4, iters=8)
        np.testing.assert_allclose(np.asarray(lr.reconstruct()[i]),
                                   np.asarray(li.reconstruct()),
                                   rtol=2e-2, atol=2e-2)


def test_orthonormal_factors():
    a = lowrank_matrix(jax.random.PRNGKey(4), 80, 60, 8, noise=0.01)
    u, s, vt = lanczos_svd(a, rank=8, iters=12)
    np.testing.assert_allclose(np.asarray(u.T @ u), np.eye(8), atol=1e-3)
    np.testing.assert_allclose(np.asarray(vt @ vt.T), np.eye(8), atol=1e-3)
