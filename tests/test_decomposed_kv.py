"""Decomposed KV cache: full-rank exactness + compression arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_archs
from repro.models import decomposed_kv as DK
from repro.models import model_fns
from repro.models import transformer as T


def _setup(seq=24):
    cfg = all_archs()["deepseek-7b"].reduced()
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0, cfg.vocab)
    return cfg, params, toks


def test_full_rank_matches_dense_decode():
    cfg, params, toks = _setup()
    seq = toks.shape[1]
    prefix = seq - 4
    # dense reference
    lg_d, cache_d = T.prefill(params, cfg, toks[:, :prefix], seq + 8)
    # decomposed cache at FULL rank (r = prefix) -> exact
    lg_k, cache_k = DK.prefill_dkv(params, cfg, toks[:, :prefix],
                                   rank=prefix, tail=8, exact=True)
    np.testing.assert_allclose(np.asarray(lg_k, np.float32),
                               np.asarray(lg_d, np.float32),
                               rtol=5e-2, atol=5e-1)
    for t in range(prefix, seq):
        pos = jnp.full((2,), t, jnp.int32)
        lg_d, cache_d = T.decode_step(params, cfg, toks[:, t], cache_d, pos)
        lg_k, cache_k = DK.decode_step_dkv(params, cfg, toks[:, t], cache_k,
                                           pos, frozen_len=prefix)
        np.testing.assert_allclose(np.asarray(lg_k, np.float32),
                                   np.asarray(lg_d, np.float32),
                                   rtol=5e-2, atol=5e-1)


def test_low_rank_is_finite_and_degrades_gracefully():
    cfg, params, toks = _setup()
    prefix = toks.shape[1] - 4
    errs = []
    lg_d, _ = T.prefill(params, cfg, toks[:, :prefix], prefix)
    for r in (2, 8, prefix):
        lg_k, cache_k = DK.prefill_dkv(params, cfg, toks[:, :prefix],
                                       rank=r, tail=8, exact=(r == prefix))
        pos = jnp.full((2,), prefix, jnp.int32)
        lg2, _ = DK.decode_step_dkv(params, cfg, toks[:, prefix], cache_k,
                                    pos, frozen_len=prefix)
        assert np.isfinite(np.asarray(lg2, np.float32)).all()
        errs.append(float(jnp.abs(lg_k.astype(jnp.float32)
                                  - lg_d.astype(jnp.float32)).max()))
    assert errs[0] >= errs[-1]           # more rank, closer to dense


def test_compress_tail_roundtrip():
    cfg, params, toks = _setup()
    prefix = toks.shape[1] - 4
    _, cache = DK.prefill_dkv(params, cfg, toks[:, :prefix],
                              rank=prefix, tail=8, exact=True)
    # write two tail tokens then compress
    for t in range(prefix, prefix + 2):
        pos = jnp.full((2,), t, jnp.int32)
        _, cache = DK.decode_step_dkv(params, cfg, toks[:, t], cache, pos,
                                      frozen_len=prefix)
    c2 = DK.compress_tail(cache, cfg, rank=prefix)
    assert c2["k_u"].shape[2] == cache["k_u"].shape[2] + 8
    assert float(jnp.abs(c2["tail"]["k"]).max()) == 0.0


def test_bytes_reduction_math():
    """Eq. 10 applied to KV: dense T·d_kv vs U(T·r) + Vt(r·d_kv)."""
    t, kvw, r = 32768, 4096, 64
    dense = t * kvw
    lowrank = t * r + r * kvw
    assert dense / lowrank > 50
