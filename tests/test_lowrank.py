"""LowRank pytree: reconstruction identities and rank algebra."""
import jax
import numpy as np

from repro.core import from_dense_svd, rank_concat, relative_error, retruncate
from repro.core.lowrank import add_bias_rank


def test_from_dense_roundtrip_fullrank():
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 24))
    lr = from_dense_svd(a, rank=24)
    np.testing.assert_allclose(np.asarray(lr.reconstruct()), np.asarray(a),
                               atol=1e-4)


def test_pytree_flatten_roundtrip():
    a = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
    lr = from_dense_svd(a, 4)
    leaves, treedef = jax.tree_util.tree_flatten(lr)
    lr2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(lr.u), np.asarray(lr2.u))


def test_rank_concat_is_exact_sum():
    a = jax.random.normal(jax.random.PRNGKey(2), (20, 16))
    b = jax.random.normal(jax.random.PRNGKey(3), (20, 16))
    la, lb = from_dense_svd(a, 5), from_dense_svd(b, 7)
    cc = rank_concat(la, lb)
    assert cc.rank == 12
    np.testing.assert_allclose(
        np.asarray(cc.reconstruct()),
        np.asarray(la.reconstruct() + lb.reconstruct()), atol=1e-4)


def test_retruncate_matches_svd():
    a = jax.random.normal(jax.random.PRNGKey(4), (24, 18))
    big = rank_concat(from_dense_svd(a, 9), from_dense_svd(a * 0.5, 9))
    tr = retruncate(big, 6)
    oracle = from_dense_svd(big.reconstruct(), 6)
    assert float(relative_error(tr, big.reconstruct())) <= \
        float(relative_error(oracle, big.reconstruct())) + 1e-4


def test_add_bias_rank():
    a = jax.random.normal(jax.random.PRNGKey(5), (10, 8))
    bias = jax.random.normal(jax.random.PRNGKey(6), (8,))
    lr = from_dense_svd(a, 8)
    lb = add_bias_rank(lr, bias)
    np.testing.assert_allclose(np.asarray(lb.reconstruct()),
                               np.asarray(a + bias), atol=1e-4)


def test_param_count_and_compression():
    a = jax.random.normal(jax.random.PRNGKey(7), (256, 128))
    lr = from_dense_svd(a, 4)
    assert lr.param_count() == 256 * 4 + 4 + 4 * 128
    assert lr.param_count() < a.size
