"""End-to-end training driver: ~100M-param dense LM for a few hundred steps
on the synthetic Markov stream, with checkpoints, the straggler watchdog,
and (optionally) PowerSGD low-rank gradient compression — the paper's
decomposer machinery applied to the communication channel.

  PYTHONPATH=src python examples/train_smoke.py --steps 300
  PYTHONPATH=src python examples/train_smoke.py --steps 50 --compress
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec, register
from repro.runtime.driver import train_loop

# ~100M params: 8 layers, d_model 768, vocab 16k
CFG_100M = register(ArchConfig(
    name="demo-100m", family="dense",
    num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
    d_ff=2048, vocab=16384, remat=False, dtype="float32",
))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--compress", action="store_true",
                    help="PowerSGD rank-4 gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_smoke")
    shape = ShapeSpec("train", args.seq_len, args.batch, "train")

    if args.compress:
        # wire the compressor as a grad transform through a custom loop
        from repro.data import DataConfig, SyntheticLM
        from repro.distributed.compression import (PowerSGDConfig,
                                                   compress_decompress,
                                                   compression_ratio,
                                                   init_state)
        from repro.optim import make_optimizer
        from repro.runtime import steps as steps_mod

        cfg = CFG_100M
        opt = make_optimizer(cfg)
        params, opt_state = steps_mod.init_train_state(
            cfg, jax.random.PRNGKey(0), opt)
        pcfg = PowerSGDConfig(rank=4)
        pstate = init_state(params, pcfg)
        print(f"[compress] dense/compressed payload = "
              f"{compression_ratio(params, pcfg):.1f}x")

        fns_step = steps_mod.make_train_step(cfg, opt, grad_transform=None)

        @jax.jit
        def step(params, opt_state, pstate, batch):
            from repro.models import api
            loss, grads = jax.value_and_grad(
                lambda p: api.model_fns(cfg).loss_fn(p, cfg, batch))(params)
            grads, pstate = compress_decompress(grads, pstate, pcfg)
            from repro.optim import clip_by_global_norm
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, pstate, loss

        src = SyntheticLM(cfg, shape, DataConfig())
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            params, opt_state, pstate, loss = step(params, opt_state,
                                                   pstate, batch)
            if i % 10 == 0:
                print(f"[compress-train] step {i} loss {float(loss):.4f}")
        print(f"final loss (compressed grads): {float(loss):.4f}")
        return

    res = train_loop(CFG_100M, shape, total_steps=args.steps,
                     ckpt_dir=ckpt_dir, ckpt_every=100, log_every=20)
    if not res.losses:
        print(f"already trained to step {res.step} (checkpoint resume); "
              f"use a fresh --ckpt-dir to retrain")
        return
    first, last = res.losses[0], res.losses[-1]
    print(f"loss {first:.3f} -> {last:.3f} over {res.step} steps "
          f"(restarts={res.restarts}, stragglers={res.straggler_flags})")
    if args.steps >= 100:
        assert last < first, "training must reduce loss on the Markov stream"


if __name__ == "__main__":
    main()
