"""Serving example: batched requests through the continuous-batching engine,
then the SAME model evaluated with the paper's decomposed execution —
showing the quality/compression dial end to end.

  PYTHONPATH=src python examples/serve_decomposed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.policy import DecompositionPolicy, PAPER_LAYER_CONFIGS
from repro.models import decomposed as D
from repro.models import model_fns
from repro.serving import Engine, Request

cfg = get_arch("llama2-7b").reduced().replace(num_layers=8)
fns = model_fns(cfg)
params = fns.init(jax.random.PRNGKey(0), cfg)

# --- 1. serve a batch of requests ------------------------------------------
eng = Engine(cfg, params, slots=4, max_len=64)
rng = np.random.RandomState(0)
for i in range(6):
    eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab, 12,
                                                 dtype=np.int32),
                       max_new_tokens=6))
done = eng.run()
for r in sorted(done, key=lambda r: r.uid):
    print(f"req {r.uid}: generated {r.out_tokens}")
s = eng.stats
print(f"engine: {s.prefills} prefills, {s.decode_steps} decode rounds, "
      f"{s.tokens_out} tokens, {s.tokens_out / max(s.wall_s, 1e-9):.1f} "
      f"tok/s (CPU)")

# --- 2. decomposed execution quality dial (paper Table 2 axes) -------------
tokens = jnp.asarray(rng.randint(0, cfg.vocab, (2, 64), dtype=np.int32))
print("\nrank  outlier%  logit-KL(vs dense)   per-layer FLOP cut (Eq.8)")
for rank in (1, 10, 20):
    for frac in (0.0, 0.03):
        pol = DecompositionPolicy.from_layer_list(
            cfg.num_layers, [0, 2, 4, 6], rank=min(rank, 24),
            outlier_frac=frac, iters=min(rank + 8, 48))
        kl = float(D.logit_kl(params, cfg, tokens,
                              D.DecomposedRuntime(policy=pol)))
        print(f"{rank:4d}  {frac:7.0%}  {kl:18.4f}   {64 // max(rank,1):12d}x")
print("\n(the paper's best config [10 layers, rank 20, ~3% outliers] trades "
      "~3% accuracy for 22% end-to-end latency — see benchmarks/table2)")
