"""Quickstart: the paper's technique in 60 lines.

Decompose an activation with Lanczos (+ channel outlier extraction), run a
linear layer in decomposition-preserved form (Eq. 6), chain a second matmul
without re-decomposition, and compare error/FLOPs against dense.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (attach_dense_outliers, decompose, extract,
                        lowrank_matmul, matmul_flops, relative_error)

S, H, N, RANK = 1024, 512, 512, 10

# --- a synthetic activation with outlier channels (like real LLM acts) ----
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (S, 24)) @ jax.random.normal(
    jax.random.PRNGKey(1), (24, H))
x = x.at[:, [7, 100, 300]].mul(20.0)          # spiky channels (paper Fig. 7)
w1 = jax.random.normal(jax.random.PRNGKey(2), (H, N)) * 0.05
w2 = jax.random.normal(jax.random.PRNGKey(3), (N, N)) * 0.05

# --- 1. multi-track decomposition (paper §4 + §2.3) ------------------------
base, outlier_vals, outlier_idx = extract(x, threshold=jnp.asarray(4.0),
                                          num_channels=16)
lr = decompose(base, rank=RANK, iters=RANK + 6)       # Lanczos bidiag
lr = attach_dense_outliers(lr, outlier_vals, outlier_idx)
print(f"decomposed [S={S}, H={H}] -> rank {RANK} + {outlier_idx.shape[0]} "
      f"outlier channels; rel err = {float(relative_error(lr, x)):.4f}")

# --- 2. decomposition-preserved matmuls (paper §3.2, Eq. 6) ---------------
y1 = lowrank_matmul(lr, w1)          # only Vt @ W computed — no S anywhere
y2 = lowrank_matmul(y1, w2)          # chains WITHOUT re-decomposition
y_ref = (x @ w1) @ w2
err = float(jnp.linalg.norm(y2.reconstruct() - y_ref)
            / jnp.linalg.norm(y_ref))
print(f"preserved 2-matmul chain rel err vs dense: {err:.4f}")

# --- 3. the arithmetic the paper banks on (Eq. 8) --------------------------
dense_flops = matmul_flops(S, H, N) + matmul_flops(S, N, N)
pres_flops = matmul_flops(RANK, H, N) + matmul_flops(RANK, N, N)
print(f"FLOPs: dense {dense_flops / 1e6:.1f}M vs preserved "
      f"{pres_flops / 1e6:.1f}M -> {dense_flops / pres_flops:.0f}x reduction"
      f" (Eq. 8 predicts S/r = {S / RANK:.0f}x)")

# --- 4. the D-com kernel (Pallas, interpret mode on CPU) -------------------
from repro.kernels import ops
z, nrm = ops.reorth_right(x.astype(jnp.float32),
                          jnp.ones((S,)) / S ** 0.5,
                          jnp.zeros((H, RANK)), expansion=8)
print(f"fused Pallas reorth step (f=8): z[:3] = {z[:3]}, |z|^2 = {nrm:.2f}")
print("OK")
