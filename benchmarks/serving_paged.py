"""Paged decomposed-KV serving A/B: block-table cache vs static slab, and
prefix-cache hit vs miss TTFT on a shared-system-prompt workload.

Two claims are measured (and the second ASSERTED):

1. **paged vs slot** — same staggered workload on both engines; the paged
   engine must match throughput (it replays the slab arithmetic through
   block tables) while referencing only the pages live sequences need —
   reported as resident cache bytes alongside tok/s / TTFT.

2. **prefix reuse** — requests sharing a frozen system prompt: the FIRST
   admission decomposes it (miss), every later one splices the cached
   pages by refcount and runs tail-only suffix prefill (hit).  A hit's
   TTFT must be strictly lower than the miss TTFT — the hit skips the
   prefix forward pass AND its Lanczos factorization.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_paged --quick \
      --json benchmarks/out/serving_paged.json
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .common import Row, write_json


def _mixed_arrivals(cfg, requests: int, stagger: int, max_new: int):
    from repro.serving import Request
    rng = np.random.RandomState(0)
    sched: Dict[int, list] = {}
    for i in range(requests):
        req = Request(uid=i,
                      prompt=rng.randint(0, cfg.vocab, 8 + 4 * (i % 3),
                                         dtype=np.int32),
                      max_new_tokens=max_new + (i % 3) * max_new // 2)
        sched.setdefault(i * stagger, []).append(req)
    return sched


def _resident_bytes(eng) -> int:
    """Cache bytes the engine is actually REFERENCING right now: the slab
    engine's whole [slots, …] allocation; the paged engine's allocated
    pages (+ per-slot Vᵀ)."""
    import jax
    if eng.pager is None:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(eng.cache)) \
            if eng.cache is not None else 0
    pg = eng.pager

    def page_bytes(pool):
        return pool.shape[0] * int(np.prod(pool.shape[2:])) \
            * pool.dtype.itemsize

    used_u = pg.num_pages - 1 - pg.alloc.free_pages
    used_t = pg.num_tail_pages - 1 - pg.talloc.free_pages
    vt = sum(x.size * x.dtype.itemsize
             for x in (pg.cache["k_vt"], pg.cache["v_vt"]))
    return 2 * (used_u * page_bytes(pg.cache["k_u_pages"])
                + used_t * page_bytes(pg.cache["tail"]["k_pages"])) + vt


def _simulate(eng, arrivals, total: int, max_steps: int = 5000):
    t0 = time.perf_counter()
    done: List = []
    step = peak = 0
    while len(done) < total and step < max_steps:
        for req in arrivals.get(step, []):
            eng.submit(req)
        done.extend(eng.step())
        peak = max(peak, _resident_bytes(eng))
        step += 1
    wall = time.perf_counter() - t0
    assert len(done) == total, f"only {len(done)}/{total} finished"
    return wall, step, {r.uid: r.out_tokens for r in done}, peak


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    import jax
    from repro.configs import all_archs
    from repro.engine import DecomposeEngine, EngineConfig
    from repro.models import model_fns
    from repro.obs import engine_snapshot
    from repro.serving import Engine, Request

    cfg = all_archs()["deepseek-7b"].reduced()
    params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    requests = 6 if quick else 10
    slots, max_len, max_new = 2 if quick else 4, 128, 12 if quick else 20
    rank, tail, page = 8, 8, 4
    stagger = 5

    rows: List[Row] = []
    report = {"arch": cfg.name, "slots": slots, "requests": requests,
              "kv_rank": rank, "page": page, "modes": {}}

    # ---- claim 1: paged vs slot on the same staggered schedule ----------
    toks_by_mode = {}
    for mode in ("slot", "paged"):
        mk = lambda: Engine(
            cfg, params, slots=slots, max_len=max_len,
            decompose_kv_rank=rank, dkv_tail=tail,
            decompose_engine=DecomposeEngine(EngineConfig(
                kv_rank=rank, kv_tail=tail, kv_page=page)),
            paged=(mode == "paged"))
        _simulate(mk(), _mixed_arrivals(cfg, requests, stagger, max_new),
                  requests)                       # jit warmup
        runs = []
        for _ in range(3):
            eng = mk()
            wall, steps, toks, peak = _simulate(
                eng, _mixed_arrivals(cfg, requests, stagger, max_new),
                requests)
            runs.append((wall, steps, toks, peak, eng))
        runs.sort(key=lambda t: t[0])
        wall, steps, toks, peak, eng = runs[len(runs) // 2]
        toks_by_mode[mode] = toks
        s = eng.stats
        # uniform repro.obs/v1 snapshot (adds the "paged" block — page
        # pool occupancy / prefix entry count — on the paged engine)
        report["modes"][mode] = engine_snapshot(
            eng, wall_s=wall, sched_steps=steps,
            peak_resident_cache_bytes=peak)
        rows.append((f"serving_paged/{mode}/r{requests}xs{slots}",
                     wall * 1e6,
                     f"tok_per_s={report['modes'][mode]['tokens_per_s']:.1f};"
                     f"ttft_ms={s.mean_ttft_s*1e3:.1f}"))
    assert toks_by_mode["paged"] == toks_by_mode["slot"], \
        "paged engine diverged from the slot engine"
    report["token_conformance"] = True

    # ---- claim 2: prefix-cache hit TTFT < miss TTFT ---------------------
    rng = np.random.RandomState(1)
    sys_prompt = rng.randint(0, cfg.vocab, 24, dtype=np.int32)
    n_users = 4 if quick else 8

    def prefix_engine():
        return Engine(
            cfg, params, slots=slots, max_len=max_len,
            decompose_kv_rank=rank, dkv_tail=8,
            decompose_engine=DecomposeEngine(EngineConfig(
                kv_rank=rank, kv_tail=8, kv_page=page,
                kv_prefix_cache=16)),
            paged=True)

    def shared_requests():
        r2 = np.random.RandomState(2)
        return [Request(uid=i, prompt=np.concatenate(
            [sys_prompt, r2.randint(0, cfg.vocab, 4, dtype=np.int32)]),
            max_new_tokens=4) for i in range(n_users)]

    def measure():
        eng = prefix_engine()
        ttfts = []
        for req in shared_requests():
            eng.submit(req)
            done: List = []
            while not done:
                done = eng.step()
            ttfts.append(req.t_first - req.t_submit)
        s = eng.stats
        assert s.prefix_misses >= 1 and s.prefix_hits >= n_users - 1, \
            f"expected 1 miss + hits, got {s.prefix_misses}/{s.prefix_hits}"
        return ttfts, eng

    measure()                                     # jit warmup (both paths)
    samples = [measure()[0] for _ in range(3)]
    med = lambda xs: sorted(xs)[len(xs) // 2]
    miss_ttft = med([t[0] for t in samples])
    hit_ttft = med([med(t[1:]) for t in samples])
    report["prefix"] = {
        "system_prompt_tokens": int(len(sys_prompt)),
        "users": n_users,
        "miss_ttft_s": miss_ttft,
        "hit_ttft_s": hit_ttft,
        "hit_speedup": miss_ttft / max(hit_ttft, 1e-9),
    }
    assert hit_ttft < miss_ttft, \
        f"prefix-cache hit TTFT {hit_ttft*1e3:.1f}ms not below miss " \
        f"{miss_ttft*1e3:.1f}ms"
    report["prefix"]["hit_beats_miss"] = True
    rows.append(("serving_paged/prefix_hit_vs_miss", 0.0,
                 f"miss_ttft_ms={miss_ttft*1e3:.1f};"
                 f"hit_ttft_ms={hit_ttft*1e3:.1f};"
                 f"speedup={report['prefix']['hit_speedup']:.2f}x"))

    if json_path:
        write_json(json_path, report, indent=2)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
