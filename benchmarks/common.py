"""Shared benchmark helpers: timing, CSV rows, v5e roofline cost model,
and the one sanctioned artifact writer."""
from __future__ import annotations

import time
from typing import Any, Callable, List, Tuple

import jax

from repro.ioutil import atomic_write_json

# TPU v5e constants (same as launch.dryrun)
PEAK_FLOPS = 197e12
HBM_BW = 819e9

Row = Tuple[str, float, str]      # (name, us_per_call, derived-info)


def wall(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def v5e_time(flops: float, bytes_moved: float) -> float:
    """Roofline latency model on one v5e chip: max(compute, memory)."""
    return max(flops / PEAK_FLOPS, bytes_moved / HBM_BW)


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


def write_json(path: str, obj: Any, **dump_kw: Any) -> None:
    """Write a benchmark report artifact.

    Every ``benchmarks/*.py`` report goes through here: atomic
    tmp+``os.replace`` via ``repro.ioutil`` (parent dirs created), so
    dcomlint rule D3 holds by construction — CI tailing an artifact mid
    re-write sees the previous complete report, never a truncated one.
    """
    atomic_write_json(path, obj, **dump_kw)
