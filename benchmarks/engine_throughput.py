"""Engine-level decomposition throughput: batched kernel vs vmap-of-scalar.

The tentpole claim of the unified DecomposeEngine: a [B, S, H] batch should
dispatch ONE fused Pallas launch per Lanczos pass (batch axis in the grid)
instead of a per-prompt vmap over pallas_call.  This benchmark measures the
three ways to run the same decomposition:

* ``reference``        — pure-jnp batched einsum pipeline (XLA fusion),
* ``pallas_batched``   — the engine's native batched kernel backend,
* ``pallas_vmap``      — the pre-engine scheme (vmap of the scalar kernel).

In interpreter mode (CPU container) absolute numbers are emulation-bound;
the interesting derived column is the batched/vmap launch count and the
trace-time amortization.  On TPU (interpret=False) the batched grid also
amortizes the per-launch fixed cost across prompts.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from .common import Row, wall


def run(quick: bool = False) -> List[Row]:
    from repro.engine import DecomposeEngine, EngineConfig

    b, s, h = (2, 32, 64) if quick else (4, 64, 128)
    rank = 4 if quick else 8
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, h), jnp.float32)

    rows: List[Row] = []
    engines = {
        "reference": DecomposeEngine(EngineConfig(backend="reference")),
        "pallas_batched": DecomposeEngine(
            EngineConfig(backend="pallas_interpret")),
        "pallas_vmap": DecomposeEngine(EngineConfig(backend="pallas_vmap")),
    }
    base = None
    for name, eng in engines.items():
        fn = jax.jit(lambda x, e=eng: e.decompose(x, rank).reconstruct())
        t = wall(fn, x, warmup=1, iters=3)
        # launches per Lanczos pass: 1 batched vs B under vmap
        launches = 1 if eng.backend.batched_launch else b
        rows.append((f"engine_decompose/{name}/B{b}xS{s}xH{h}r{rank}",
                     t * 1e6,
                     f"launches_per_pass={launches};"
                     f"prompts_per_launch={b if launches == 1 else 1}"))
        if name == "reference":
            base = t
        elif base:
            rows.append((f"engine_decompose/{name}_vs_reference",
                         t * 1e6, f"slowdown={t / base:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
