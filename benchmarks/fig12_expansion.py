"""Paper Fig. 12: decomposition latency vs expansion factor f.

Mechanistic model of the paper's OWN explanation (§5.3 + §6.4):

* Left of f*: the iterative vector chain is MEMORY-BOUND and expansion
  unlocks bandwidth — with f-way replication, f cluster-columns (each with
  a private bank) stream concurrently, so utilized bandwidth ≈ min(f/f_sat,
  1) of aggregate.  Latency falls ~1/f.
* Right of f*: the "next element-wise multiplication needs to be
  duplicated" — replicated compute grows ~linearly in f, and the final
  partial-result aggregation (blue arrows, Fig. 9b) grows with f.  The
  algorithm turns compute-bound and latency rises.

D-com scale (paper §5.1): 16×16 clusters × 8×8 FP16 MACs ⇒ f_sat = 8 at
their geometry (batch 64, S = H = 4096, rank 10).  The model reproduces
f* = 8 and the ~6.2× speedup over f = 1.

The TPU-native kernel realization of the same idea (grid-expanded reduction
with per-block VMEM tiles) is ``kernels/lanczos_reorth.py`` — validated for
numerical equivalence at every f in tests/test_kernels.py; the roofline
consequences on v5e are in fig11's modeled section.
"""
from __future__ import annotations

from typing import List

from .common import Row

S, H, K, BATCH = 4096, 4096, 10, 64

# --- D-com hardware model (paper §5) ---------------------------------------
CLUSTERS = 256                      # 16 × 16
MACS_PER_CLUSTER = 64               # 8 × 8 FP16
CLOCK = 1.0e9
PEAK_MAC = CLUSTERS * MACS_PER_CLUSTER * CLOCK          # 16.4 TMAC/s
BANK_BW_TOTAL = 2.0e12              # aggregate distributed-SRAM bandwidth
F_SAT = 8                           # banks engaged per vector chunk at sat.
COMBINE_LAT = 2e-6                  # global broadcast/aggregate per step


def reorth_latency(f: int) -> float:
    """One fused re-orthogonalization step of a [S, H] fp16 tile at
    expansion factor f (per prompt)."""
    a_bytes = S * H * 2
    # memory: expansion engages more banks until saturation
    bw = BANK_BW_TOTAL * min(f, F_SAT) / F_SAT
    t_mem = a_bytes / bw
    # compute: base matvec+CGS2 MACs, element-wise stage duplicated f-ways
    base_macs = 2 * S * H + 4 * (S + H) * K
    dup_macs = (f - 1) * (S + H) * K * 4
    t_comp = (base_macs + dup_macs) / PEAK_MAC
    # final aggregation of f partial correction vectors
    t_comb = COMBINE_LAT * (1 + (f.bit_length() - 1))
    return max(t_mem, t_comp) + t_comb


def batch_decomposition_latency(f: int) -> float:
    """Full batch: 2 reorth steps × K iterations × BATCH prompts (prompts
    pipeline through the cluster array; no batching shortcut in the
    iterative chain — paper decomposes prompts independently)."""
    return reorth_latency(f) * 2 * K * BATCH


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    best = (None, float("inf"))
    lat = {}
    for f in (1, 2, 4, 8, 16, 32, 64, 128):
        t = batch_decomposition_latency(f)
        lat[f] = t
        rows.append((f"fig12/f{f}", t * 1e6,
                     f"modeled_batch_decomp_s={t:.4f}"))
        if t < best[1]:
            best = (f, t)
    rows.append(("fig12/optimal_f", 0.0,
                 f"f*={best[0]} (paper: 8); latency={best[1] * 1e3:.2f}ms"))
    rows.append(("fig12/speedup_vs_f1", 0.0,
                 f"{lat[1] / best[1]:.2f}x (paper: 6.2x)"))
    assert best[0] == 8, "expansion model must reproduce the paper's f*"
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
