"""Paper Fig. 12: decomposition latency vs expansion factor f.

Two sections:

1. the mechanistic D-com hardware model (below) reproducing the paper's
   f* = 8 and ~6.2× speedup;
2. ``run_ab`` — tuner validation on the REAL kernel: sweep the expansion
   grid empirically (median-of-k through ``repro.tune.measure``), replay
   the tuner's production pruning against that same table, and A/B tuned
   vs the hard-coded default f = 8 vs the swept optimum.  The gate is
   non-vacuous: if cost-model pruning discards the true optimum, tuned
   lands on a worse survivor and the >5% assert fires.  The JSON
   artifact (``benchmarks/out/fig12_ab.json``) records every number and
   CI uploads it.

Mechanistic model of the paper's OWN explanation (§5.3 + §6.4):

* Left of f*: the iterative vector chain is MEMORY-BOUND and expansion
  unlocks bandwidth — with f-way replication, f cluster-columns (each with
  a private bank) stream concurrently, so utilized bandwidth ≈ min(f/f_sat,
  1) of aggregate.  Latency falls ~1/f.
* Right of f*: the "next element-wise multiplication needs to be
  duplicated" — replicated compute grows ~linearly in f, and the final
  partial-result aggregation (blue arrows, Fig. 9b) grows with f.  The
  algorithm turns compute-bound and latency rises.

D-com scale (paper §5.1): 16×16 clusters × 8×8 FP16 MACs ⇒ f_sat = 8 at
their geometry (batch 64, S = H = 4096, rank 10).  The model reproduces
f* = 8 and the ~6.2× speedup over f = 1.

The TPU-native kernel realization of the same idea (grid-expanded reduction
with per-block VMEM tiles) is ``kernels/lanczos_reorth.py`` — validated for
numerical equivalence at every f in tests/test_kernels.py; the roofline
consequences on v5e are in fig11's modeled section.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from .common import Row, write_json

AB_JSON = os.path.join(os.path.dirname(__file__), "out", "fig12_ab.json")

S, H, K, BATCH = 4096, 4096, 10, 64

# --- D-com hardware model (paper §5) ---------------------------------------
CLUSTERS = 256                      # 16 × 16
MACS_PER_CLUSTER = 64               # 8 × 8 FP16
CLOCK = 1.0e9
PEAK_MAC = CLUSTERS * MACS_PER_CLUSTER * CLOCK          # 16.4 TMAC/s
BANK_BW_TOTAL = 2.0e12              # aggregate distributed-SRAM bandwidth
F_SAT = 8                           # banks engaged per vector chunk at sat.
COMBINE_LAT = 2e-6                  # global broadcast/aggregate per step


def reorth_latency(f: int) -> float:
    """One fused re-orthogonalization step of a [S, H] fp16 tile at
    expansion factor f (per prompt)."""
    a_bytes = S * H * 2
    # memory: expansion engages more banks until saturation
    bw = BANK_BW_TOTAL * min(f, F_SAT) / F_SAT
    t_mem = a_bytes / bw
    # compute: base matvec+CGS2 MACs, element-wise stage duplicated f-ways
    base_macs = 2 * S * H + 4 * (S + H) * K
    dup_macs = (f - 1) * (S + H) * K * 4
    t_comp = (base_macs + dup_macs) / PEAK_MAC
    # final aggregation of f partial correction vectors
    t_comb = COMBINE_LAT * (1 + (f.bit_length() - 1))
    return max(t_mem, t_comp) + t_comb


def batch_decomposition_latency(f: int) -> float:
    """Full batch: 2 reorth steps × K iterations × BATCH prompts (prompts
    pipeline through the cluster array; no batching shortcut in the
    iterative chain — paper decomposes prompts independently)."""
    return reorth_latency(f) * 2 * K * BATCH


def run_ab(quick: bool = False, out_json: Optional[str] = AB_JSON
           ) -> Dict[str, object]:
    """Tuned-vs-default-vs-swept-optimum A/B on the real Fig. 12 kernel.

    ONE measured sweep over the full expansion grid, then the tuner's
    production path is replayed against that same table: the cost model
    ranks the grid, the top ``PRUNE`` survivors keep their measurements,
    and "tuned" is the measured winner AMONG THE SURVIVORS — exactly what
    ``tune(measure_candidates=True, prune=PRUNE)`` returns given these
    measurements.  The gate is therefore real: if the cost model prunes
    away the true optimum's f, tuned_vs_opt exceeds 1 and CI fails.
    Using one table for both sides removes timing noise from the ratio."""
    from repro import tune

    kernel = "matvec_expand"
    shape = (128, 256) if quick else (1024, 2048)
    fix = {"row_block": 512}             # 1-D sweep: f is the Fig. 12 axis
    reps = 3 if quick else 5
    res = tune.tune(kernel, shape, "float32", fix=fix,
                    measure_candidates=True, prune=None,
                    reps=reps, force=True, persist=False)

    # replay production pruning on the measured table (stable model order
    # and the same DEFAULT_PRUNE width as tune() itself)
    by_model = sorted(res.table, key=lambda row: row[1])
    survivors = by_model[:tune.DEFAULT_PRUNE]
    tuned_cand, _, tuned_s = min(survivors, key=lambda row: row[2])

    swept = {str(c["expansion"]): m for c, _, m in res.table}
    opt_cand, opt_s = res.swept_optimum()
    if tuned_s > 1.05 * opt_s and tuned_cand != opt_cand:
        # finalists head-to-head before the CI gate can fire: one sweep
        # sample per f is noise-prone, a deliberate re-measure at 3× reps
        # separates a genuine pruning miss from a scheduler hiccup
        tuned_s = tune.measure_candidate(kernel, res.shape, res.dtype,
                                         tuned_cand, reps=3 * reps)
        opt_s = tune.measure_candidate(kernel, res.shape, res.dtype,
                                       opt_cand, reps=3 * reps)
    default_s = swept[str(tune.get_space(kernel).param("expansion").default)]
    data = {
        "kernel": kernel,
        "shape": list(res.shape),
        "dtype": res.dtype,
        "device_kind": tune.device_kind(),
        "swept_s": swept,
        "prune": tune.DEFAULT_PRUNE,
        "pruned_fs": [int(c["expansion"]) for c, _, _ in survivors],
        "model_pick_f": int(by_model[0][0]["expansion"]),
        "tuned_f": int(tuned_cand["expansion"]),
        "tuned_s": tuned_s,
        "default_f": tune.get_space(kernel).param("expansion").default,
        "default_s": default_s,
        "opt_f": int(opt_cand["expansion"]),
        "opt_s": opt_s,
        "tuned_vs_opt": tuned_s / opt_s,
        "default_vs_opt": default_s / opt_s,
    }
    if out_json:
        write_json(out_json, data, indent=1, sort_keys=True)
    return data


def _ab_rows(quick: bool) -> List[Row]:
    data = run_ab(quick)
    rows: List[Row] = []
    for f, s in sorted(data["swept_s"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"fig12/measured_f{f}", s * 1e6, "swept_kernel_s"))
    rows.append(("fig12/ab_tuned", data["tuned_s"] * 1e6,
                 f"tuner_pick_f={data['tuned_f']} "
                 f"(pruned_to={data['pruned_fs']})"))
    rows.append(("fig12/ab_default", data["default_s"] * 1e6,
                 f"hardcoded_f={data['default_f']}"))
    rows.append(("fig12/ab_opt", data["opt_s"] * 1e6,
                 f"swept_optimum_f={data['opt_f']}"))
    rows.append(("fig12/tuned_vs_opt", 0.0,
                 f"{data['tuned_vs_opt']:.3f}x (acceptance: <= 1.05)"))
    assert data["tuned_vs_opt"] <= 1.05, \
        "tuned f must stay within 5% of the swept optimum"
    return rows


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    best = (None, float("inf"))
    lat = {}
    for f in (1, 2, 4, 8, 16, 32, 64, 128):
        t = batch_decomposition_latency(f)
        lat[f] = t
        rows.append((f"fig12/f{f}", t * 1e6,
                     f"modeled_batch_decomp_s={t:.4f}"))
        if t < best[1]:
            best = (f, t)
    rows.append(("fig12/optimal_f", 0.0,
                 f"f*={best[0]} (paper: 8); latency={best[1] * 1e3:.2f}ms"))
    rows.append(("fig12/speedup_vs_f1", 0.0,
                 f"{lat[1] / best[1]:.2f}x (paper: 6.2x)"))
    assert best[0] == 8, "expansion model must reproduce the paper's f*"
    rows.extend(_ab_rows(quick))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
