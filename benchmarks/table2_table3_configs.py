"""Paper Tables 2-3: decomposition-configuration sweep.

For each paper layer-config × rank, input-only (Table 2) and input+weight
(Table 3) modes: quality (logit KL on the reduced model), activation/weight
compression ratios (Eqs. 10/12 at the paper's 7B geometry), compute
reduction (Eqs. 8/9), and modeled end-to-end runtime ratio on v5e (layer
costs from the fig11 roofline model, decomposer on D-com).
"""
from __future__ import annotations

from typing import List

import jax

from repro.configs import all_archs
from repro.configs.base import ShapeSpec
from repro.core.policy import PAPER_LAYER_CONFIGS, DecompositionPolicy
from repro.core.preserved import (activation_compression_ratio,
                                  compute_reduction_ratio_input_only,
                                  compute_reduction_ratio_input_weight,
                                  weight_compression_ratio)
from repro.models import decomposed as D
from repro.models import make_fake_batch, model_fns
from .common import Row
from .fig11_layer_runtime import modeled_paper

S_PAPER, H_PAPER, LAYERS_7B = 4096, 4096, 32


def modeled_runtime_ratio(n_decomposed: int, mode: str) -> float:
    """End-to-end runtime ratio vs original (paper's 'Model Runtime' col).

    Decomposed layers run at the modeled C/A single-layer ratio (D-com
    overlapped); others at 1.0.  Input+weight shaves the preserved-GEMM
    term further but is memory-bound (paper §6.2 finds it not meaningfully
    better) — modeled via the same C/A with a 0.95 factor.
    """
    rows = {r[0]: r[1] for r in modeled_paper()}
    ratio_c = (rows["fig11/modeled_paper/C_dcom"]
               / rows["fig11/modeled_paper/A_dense"])
    if mode == "iw":
        ratio_c *= 0.95
    return (n_decomposed * ratio_c + (LAYERS_7B - n_decomposed)) / LAYERS_7B


def run(quick: bool = False) -> List[Row]:
    cfg = all_archs()["llama2-7b"].reduced().replace(num_layers=8)
    fns = model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    tokens = make_fake_batch(cfg, ShapeSpec("bench", 64, 2, "train"))["tokens"]

    configs = {"4layer": [0, 2, 4, 6]} if quick else {
        "4layer": [0, 2, 4, 6], "6layer": [0, 2, 3, 5, 6, 7],
        "8layer": list(range(8))}
    ranks = (10,) if quick else (1, 10, 20)

    rows: List[Row] = []
    for mode in ("input", "iw"):
        for cname, layers in configs.items():
            paper_layers = PAPER_LAYER_CONFIGS.get(cname, layers)
            for r in ranks:
                pol = DecompositionPolicy.from_layer_list(
                    cfg.num_layers, layers, rank=min(r, 24),
                    outlier_frac=0.03, iters=min(r + 8, 48),
                    decompose_weights=(mode == "iw"), weight_rank=96)
                wfac = D.decompose_layer_weights(params, cfg, pol) \
                    if mode == "iw" else None
                kl = float(D.logit_kl(params, cfg, tokens,
                                      D.DecomposedRuntime(policy=pol), wfac))
                mem = activation_compression_ratio(S_PAPER, H_PAPER, r, r)
                cr = compute_reduction_ratio_input_only(S_PAPER, r) \
                    if mode == "input" else \
                    compute_reduction_ratio_input_weight(
                        S_PAPER, H_PAPER, H_PAPER, r, r, r, r)
                rt = modeled_runtime_ratio(len(paper_layers), mode)
                extra = ""
                if mode == "iw":
                    extra = (f";w_compress="
                             f"{weight_compression_ratio(H_PAPER, H_PAPER, r, r):.0f}x")
                rows.append((f"table{'2' if mode == 'input' else '3'}/"
                             f"{cname}/rank{r}", 0.0,
                             f"logit_kl={kl:.4f};act_compress={mem:.0f}x;"
                             f"flop_reduction={cr:.0f}x;"
                             f"modeled_runtime={rt:.2f}x{extra}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
