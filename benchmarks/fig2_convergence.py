"""Paper Fig. 2: SVD algorithm convergence speed at small ranks.

Input matrix [4096, 468] (the paper's size).  For each rank we time our
Lanczos, QR/subspace iteration, and randomized SVD to reach within 2% of
the LAPACK-oracle truncation error, and report wall time + achieved error.
Expected ordering (the paper's motivation): Lanczos fastest at rank ≤ 20.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import lanczos_svd
from repro.core.svd_alt import (oracle_svd, qr_iteration_svd, randomized_svd,
                                reconstruction_error)
from .common import Row, wall


def make_activation(s=4096, h=468, decay=0.07):
    """Synthetic activation with exponentially-decaying spectrum (LLM-like)."""
    key = jax.random.PRNGKey(0)
    u = jnp.linalg.qr(jax.random.normal(key, (s, h)))[0]
    v = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (h, h)))[0]
    sv = jnp.exp(-decay * jnp.arange(h))
    return (u * sv) @ v.T


def run(quick: bool = False) -> List[Row]:
    a = make_activation(1024 if quick else 4096, 468)
    ranks = (1, 10, 20) if quick else (1, 10, 20, 50)
    rows: List[Row] = []
    for r in ranks:
        e_opt = float(reconstruction_error(a, *oracle_svd(a, r)))
        algos = {
            "lanczos": lambda: lanczos_svd(a, r, iters=min(r + 6, 468)),
            "qr_subspace": lambda: qr_iteration_svd(a, r, iters=8),
            "randomized": lambda: randomized_svd(a, r),
        }
        for name, fn in algos.items():
            t = wall(fn)
            e = float(reconstruction_error(a, *fn()))
            rows.append((f"fig2/{name}/rank{r}", t * 1e6,
                         f"err={e:.4f};opt={e_opt:.4f}"))
    # headline: wall time on 1-core CPU is dispatch-bound (Lanczos is a
    # sequential chain of small ops), so ALSO report the FLOP-model ratio
    # that governs accelerator latency (the paper's regime).
    lt = [r for r in rows if "lanczos/rank10" in r[0]][0][1]
    qt = [r for r in rows if "qr_subspace/rank10" in r[0]][0][1]
    s_dim, h_dim, r = a.shape[0], a.shape[1], 10
    fl_lanczos = (r + 6) * (4 * s_dim * h_dim
                            + 8 * (s_dim + h_dim) * (r + 6))
    fl_qr = 8 * (4 * s_dim * h_dim * r)
    rows.append(("fig2/lanczos_vs_qr_rank10", 0.0,
                 f"wall_ratio={qt / lt:.2f}x;"
                 f"flop_ratio={fl_qr / fl_lanczos:.2f}x (paper regime)"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
