"""Multi-family serving A/B: every registered ServingFamily on the ONE
generic engine — Mamba2 (O(1) conv/ssm state), MoE, hybrid, and the
dense-KV baseline — under the same staggered workload.

Two claims are measured (and the first ASSERTED):

1. **fused vs single-step** — per family, block-4 fused decode must
   produce byte-identical tokens to the single-step engine (execution
   strategy, never semantics) while launching fewer dispatches; tok/s
   and mean TTFT are reported for both.

2. **state footprint** — the per-family resident cache bytes (an SSM
   slot holds O(1) state vs the dense engine's O(max_len) KV slab) are
   reported so the family table's memory story is visible in CI.

CLI (writes the CI artifact):

  PYTHONPATH=src python -m benchmarks.serving_families --quick \
      --json benchmarks/out/serving_families.json
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .common import Row, write_json

# one reduced arch per family; MoE pins capacity_factor so the router is
# batch-size-invariant and fused-vs-single token conformance is a real
# engine invariant (see tests/test_serving_conformance._family_model)
FAMILY_ARCHS = (("dense", "llama2-7b"), ("ssm", "mamba2-780m"),
                ("moe", "olmoe-1b-7b"), ("hybrid", "zamba2-1.2b"))


def _arrivals(cfg, requests: int, stagger: int, max_new: int):
    from repro.serving import Request
    rng = np.random.RandomState(0)
    sched: Dict[int, list] = {}
    for i in range(requests):
        req = Request(uid=i,
                      prompt=rng.randint(0, cfg.vocab, 8 + 4 * (i % 3),
                                         dtype=np.int32),
                      max_new_tokens=max_new + (i % 3) * max_new // 2)
        sched.setdefault(i * stagger, []).append(req)
    return sched


def _cache_bytes(eng) -> int:
    import jax
    if eng.cache is None:
        return 0
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(eng.cache))


def _simulate(eng, arrivals, total: int, max_steps: int = 5000):
    t0 = time.perf_counter()
    done: List = []
    step = 0
    while len(done) < total and step < max_steps:
        for req in arrivals.get(step, []):
            eng.submit(req)
        done.extend(eng.step())
        step += 1
    wall = time.perf_counter() - t0
    assert len(done) == total, f"only {len(done)}/{total} finished"
    return wall, step, {r.uid: r.out_tokens for r in done}


def run(quick: bool = False, json_path: str = None) -> List[Row]:
    import jax
    from repro.configs import all_archs
    from repro.models import model_fns
    from repro.obs import engine_snapshot
    from repro.serving import Engine

    requests = 4 if quick else 8
    slots, max_len = 2 if quick else 4, 96
    max_new, block, stagger = 8 if quick else 14, 4, 5

    rows: List[Row] = []
    report = {"slots": slots, "requests": requests, "block": block,
              "families": {}}

    for fam, arch in FAMILY_ARCHS:
        cfg = all_archs()[arch].reduced()
        if fam == "moe":
            cfg = cfg.replace(capacity_factor=8.0)
        params = model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
        fam_report = {"arch": cfg.name, "modes": {}}
        toks_by_mode = {}
        for mode, blk in (("single", 1), ("fused", block)):
            mk = lambda: Engine(cfg, params, slots=slots, max_len=max_len,
                                decode_block=blk)
            _simulate(mk(), _arrivals(cfg, requests, stagger, max_new),
                      requests)                   # jit warmup
            runs = []
            for _ in range(3):
                eng = mk()
                wall, steps, toks = _simulate(
                    eng, _arrivals(cfg, requests, stagger, max_new),
                    requests)
                runs.append((wall, steps, toks, eng))
            runs.sort(key=lambda t: t[0])
            wall, steps, toks, eng = runs[len(runs) // 2]
            toks_by_mode[mode] = toks
            s = eng.stats
            # uniform repro.obs/v1 snapshot per family × mode
            fam_report["modes"][mode] = engine_snapshot(
                eng, wall_s=wall, sched_steps=steps,
                resident_cache_bytes=_cache_bytes(eng))
            rows.append((
                f"serving_families/{fam}/{mode}/r{requests}xs{slots}",
                wall * 1e6,
                f"tok_per_s="
                f"{fam_report['modes'][mode]['tokens_per_s']:.1f};"
                f"ttft_ms={s.mean_ttft_s*1e3:.1f};"
                f"blocks={s.blocks}"))
        assert toks_by_mode["fused"] == toks_by_mode["single"], \
            f"{fam}: fused decode diverged from single-step"
        fam_report["token_conformance"] = True
        report["families"][fam] = fam_report

    if json_path:
        write_json(json_path, report, indent=2)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(f"{r[0]},{r[1]:.3f},{r[2]}")
